"""TACZ region-of-interest decode latency vs full-file decode (ISSUE 2).

Writes a TAC+ snapshot to a TACZ container, then times ``read_roi`` for
boxes of varying volume fraction — at several placements per fraction,
since ROI cost depends on how the box lands on the partition (a box dead
on the refined halo touches more fine sub-blocks than one off to the
side) — against a full ``read``.  The acceptance bar: a ≤5 % box decodes
≥5× faster than the full file, mean over placements (the per-sub-block
index plus the prefix-stop entropy decode make ROI cost scale with the
codes the box needs, not with the file).
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro import io as tacz
from repro.core import hybrid

from .common import dataset, eb_for, timed, write_csv

# target box volume fractions (1 = full domain, for reference)
_FRACS = [0.01, 0.05, 0.125, 1.0]


def _boxes(shape, frac):
    """Same-size boxes at different placements: corner, center, off-center."""
    sides = [max(1, min(s, int(round(s * frac ** (1.0 / 3.0)))))
             for s in shape]
    placements = []
    for name, pos in [("corner", lambda s, side: 0),
                      ("center", lambda s, side: (s - side) // 2),
                      ("offcenter", lambda s, side: min(s - side, s // 8))]:
        placements.append((name, tuple(
            (pos(s, side), pos(s, side) + side)
            for s, side in zip(shape, sides))))
    return placements


def run(quick: bool = False):
    names = ["run1_z10"] if quick else ["run1_z10", "run2_t4"]
    rows = []
    speedup_5pct = None
    for name in names:
        ds = dataset(name)
        eb = eb_for(ds, 1e-3)
        res = hybrid.compress_amr(ds, eb=eb)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, name + ".tacz")
            _, t_write = timed(tacz.write, path, res)
            size = os.path.getsize(path)
            with tacz.TACZReader(path) as rd:
                n_sb = sum(len(e.subblocks) for e in rd.levels)
                _, t_full = timed(rd.read, repeat=2)
                for frac in _FRACS:
                    speedups = []
                    for place, box in _boxes(ds.finest_shape, frac):
                        _, t_roi = timed(rd.read_roi, box, repeat=3)
                        vol = np.prod([hi - lo for lo, hi in box])
                        act = float(vol / np.prod(ds.finest_shape))
                        speedup = t_full / max(t_roi, 1e-12)
                        speedups.append(speedup)
                        rows.append((name, frac, round(act, 4), place, n_sb,
                                     round(size / 1e3, 1),
                                     round(t_write * 1e3, 2),
                                     round(t_full * 1e3, 2),
                                     round(t_roi * 1e3, 3),
                                     round(speedup, 2)))
                    if name == names[0] and frac == 0.05:
                        speedup_5pct = float(np.mean(speedups))
    path = write_csv("roi_decode",
                     ["dataset", "box_frac", "box_frac_actual", "placement",
                      "n_subblocks", "file_kb", "write_ms", "full_decode_ms",
                      "roi_decode_ms", "speedup"],
                     rows)
    if speedup_5pct is not None and speedup_5pct < 5.0:
        raise AssertionError(
            f"ROI acceptance regressed: 5% box decode only "
            f"{speedup_5pct:.1f}x (mean over placements) faster than full "
            f"decode (need ≥5x)")
    return {"csv": path, "speedup_5pct_box": round(speedup_5pct or 0.0, 1)}


if __name__ == "__main__":
    print(run())
