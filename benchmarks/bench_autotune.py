"""Per-level eb autotuning vs the best uniform bound (ISSUE 9, paper
§IV-F).

The claim being tracked: on a multi-level AMR snapshot, tuning the
error bound *per level* against an application metric buys real bits
over the best *uniform* bound that meets the same target — coarse
levels tolerate looser bounds because their cells weigh less in the
stored-value metrics (and the paper's analysis metrics amplify fine
detail).

Setup: a three-level synthetic snapshot; target ``ps_error <= 0.01``
(max relative P(k) error below the paper's pass bar).  Both arms share
one :class:`~repro.tuning.AutoTuner` instance, so the uniform scan
reuses the tuner's per-(level, eb) compression memo — the comparison is
pure search policy, not cache luck.

Gate: the tuned per-level vector saves **≥10%** encoded bits over the
cheapest target-satisfying uniform bound on the tuner's own ladder.
Both arms' PSNRs are recorded alongside so the saving can't hide a
quality cliff; the per-point frontier lands in the CSV.
"""
from __future__ import annotations

from repro.core import amr
from repro.tuning import AutoTuner

from .common import write_csv

TARGET = "ps_error<=0.01"
SAVING_BAR_PCT = 10.0


def run(quick: bool = False):
    ds = amr.synthetic_amr((32, 32, 32), densities=[0.3, 0.5, 0.2],
                           refine_block=4, seed=5)
    steps = 4 if quick else 6
    tuner = AutoTuner(ds, steps_down=steps, steps_up=steps)

    tr = tuner.tune(TARGET)

    # uniform arm: the cheapest single eb (same bound at every level, on
    # the same ladder) that still meets the target
    ladder = [tuner.base_eb * tuner.factor ** k
              for k in range(-steps, steps + 1)]
    uniform = None
    for eb in sorted(ladder, reverse=True):       # loosest (cheapest) first
        bits, mets = tuner.evaluate([eb] * ds.n_levels)
        if tr.target.satisfies(mets):
            uniform = (eb, bits, mets)
            break
    assert uniform is not None, \
        f"no uniform bound on the ladder meets {TARGET}"
    ueb, ubits, umets = uniform

    saving_pct = 100.0 * (1.0 - tr.bits / ubits)
    rows = [(f"{p.bits}", f"{p.metrics.get('ps_error', ''):.6g}",
             f"{p.metrics.get('psnr', ''):.4f}",
             ";".join(f"{e:.6g}" for e in p.ebs))
            for p in tr.frontier.points]
    csv = write_csv("autotune_frontier",
                    ["bits", "ps_error", "psnr", "ebs"], rows)

    assert saving_pct >= SAVING_BAR_PCT, (
        f"per-level tuning saved only {saving_pct:.1f}% over the best "
        f"uniform bound (bar {SAVING_BAR_PCT}%): tuned {tr.bits} b "
        f"(ebs {tr.ebs}) vs uniform {ubits} b (eb {ueb:g})")

    return {"bits_saving_pct": round(saving_pct, 1),
            "threshold": SAVING_BAR_PCT,
            "tuned_bits": tr.bits, "uniform_bits": ubits,
            "tuned_psnr": round(tr.metrics["psnr"], 2),
            "uniform_psnr": round(umets["psnr"], 2),
            "tuned_ps_error": round(tr.metrics["ps_error"], 5),
            "uniform_ps_error": round(umets["ps_error"], 5),
            "evaluations": tr.evaluations, "csv": csv}
