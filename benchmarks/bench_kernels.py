"""Kernel-layer benchmark: Pallas (interpret) vs pure-jnp oracle vs the
numpy host path — correctness + CPU-side call timing.

Interpret-mode timings are *functional* only (the kernels target TPU v5e);
the derived column reports bytes-moved so the VMEM-roofline expectation
(tile bytes / 819 GB/s) can be compared on real hardware."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import sz
from repro.kernels import ops, ref

from .common import timed, write_csv


def run(quick: bool = False):
    rows = []
    shape = (8, 128, 128)
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(shape).astype(np.float32) * 5)
    eb = 1e-2

    codes, t_k = timed(lambda: ops.lorenzo3d_codes(x, eb=eb).block_until_ready(),
                       repeat=3)
    _, t_r = timed(lambda: np.asarray(
        ref.lorenzo3d_codes_ref(x, eb, tile=shape)), repeat=3)
    _, t_np = timed(lambda: sz.lorenzo_nd_codes(
        sz.prequant(np.asarray(x), eb)), repeat=3)
    nbytes = x.size * 4 + x.size * 4
    rows.append(("lorenzo3d_codes", round(t_k * 1e6, 1),
                 round(t_r * 1e6, 1), round(t_np * 1e6, 1),
                 round(nbytes / 819e9 * 1e6, 3)))

    codes_i = jnp.asarray(np.random.default_rng(1)
                          .integers(0, 1024, size=(65536,)), jnp.int32)
    _, t_k = timed(lambda: ops.hist(codes_i, n_bins=1024).block_until_ready(),
                   repeat=3)
    _, t_r = timed(lambda: ref.hist_ref(codes_i, 1024).block_until_ready(),
                   repeat=3)
    rows.append(("hist_1024", round(t_k * 1e6, 1), round(t_r * 1e6, 1),
                 "-", round(codes_i.size * 4 / 819e9 * 1e6, 3)))

    g = jnp.asarray(np.random.default_rng(2)
                    .standard_normal((1024, 1024)).astype(np.float32))
    _, t_k = timed(lambda: ops.group_quant(g, group=128)[0]
                   .block_until_ready(), repeat=3)
    _, t_r = timed(lambda: ref.group_quant_ref(g, 128)[0]
                   .block_until_ready(), repeat=3)
    rows.append(("group_quant", round(t_k * 1e6, 1), round(t_r * 1e6, 1),
                 "-", round(g.size * 5 / 819e9 * 1e6, 3)))

    path = write_csv("kernels",
                     ["kernel", "pallas_interp_us", "jnp_ref_us",
                      "numpy_us", "tpu_roofline_us"], rows)
    return {"csv": path, "n_kernels": len(rows)}


if __name__ == "__main__":
    print(run())
