"""Shared benchmark utilities: dataset cache, timing, CSV output, and
the machine-readable pass/fail summary (``bench_summary.json``)."""
from __future__ import annotations

import json
import os
import time
from functools import lru_cache

import numpy as np

from repro.core import amr

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")

#: One JSON file per bench run: ``[{name, metric, value, threshold,
#: higher_is_better, passed}, ...]`` — the artifact CI uploads so the
#: performance trajectory is diffable without parsing per-bench CSVs.
SUMMARY_NAME = "bench_summary.json"


@lru_cache(maxsize=None)
def dataset(name: str):
    return amr.load_preset(name)


def eb_for(ds, rel: float) -> float:
    rng = max(float(l.data.max()) for l in ds.levels) - \
        min(float(l.data.min()) for l in ds.levels)
    return rel * rng


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def write_csv(name: str, header: list[str], rows: list[tuple]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def record_summary(name: str, *, metric: str, value,
                   threshold: float | None = None,
                   higher_is_better: bool = True,
                   passed: bool | None = None) -> str:
    """Merge one benchmark verdict into ``bench_summary.json``.

    Entries are keyed by ``name`` (re-running a benchmark overwrites its
    row, so the file always reflects the latest run) and kept sorted.
    When ``passed`` is not given it is derived from ``threshold``:
    ``value >= threshold`` (or ``<=`` with ``higher_is_better=False``);
    with neither, the benchmark ran to completion and counts as passed.

    :returns: the summary file's path.
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, SUMMARY_NAME)
    entries: dict[str, dict] = {}
    if os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                entries = {e["name"]: e for e in json.load(f)}
        except (json.JSONDecodeError, KeyError, TypeError):
            entries = {}   # a corrupt summary never blocks a bench run
    if passed is None:
        if threshold is None or value is None:
            passed = True
        elif higher_is_better:
            passed = float(value) >= float(threshold)
        else:
            passed = float(value) <= float(threshold)
    entries[name] = {"name": name, "metric": metric, "value": value,
                     "threshold": threshold,
                     "higher_is_better": higher_is_better,
                     "passed": bool(passed)}
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(sorted(entries.values(), key=lambda e: e["name"]),
                  f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
    return path
