"""Shared benchmark utilities: dataset cache, timing, CSV output."""
from __future__ import annotations

import os
import time
from functools import lru_cache

import numpy as np

from repro.core import amr

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")


@lru_cache(maxsize=None)
def dataset(name: str):
    return amr.load_preset(name)


def eb_for(ds, rel: float) -> float:
    rng = max(float(l.data.max()) for l in ds.levels) - \
        min(float(l.data.min()) for l in ds.levels)
    return rel * rng


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def write_csv(name: str, header: list[str], rows: list[tuple]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
