"""SHE ablation (paper Figs. 15–16, Alg. 4): per-block prediction with one
shared Huffman tree vs (a) per-block trees and (b) merged-4D prediction —
plus the batched-pipeline speedup (ISSUE 1): sequential per-brick
compression vs the vectorized shape-grouped path, in the many-small-blocks
regime (≥ 256 sub-blocks) where per-launch overhead dominates."""
from __future__ import annotations

import time

import numpy as np

from repro.core import amr, she, sz
from repro.core.akdtree import akdtree_partition
from repro.core.blocks import extract_subblock, make_block_grid
from repro.core.opst import merge_subblocks

from .common import write_csv


def run(quick: bool = False):
    ds = amr.synthetic_amr((48, 48, 48), densities=[0.23, 0.77],
                           refine_block=4, seed=10)
    lvl = ds.levels[0]  # the z10-like 23%-density fine level of Fig. 15
    grid = make_block_grid(lvl.data, lvl.mask, unit=4)
    sbs = akdtree_partition(grid)
    bricks = [extract_subblock(grid, sb) for sb in sbs]
    rows = []
    rels = [6.7e-3, 4.8e-4] if not quick else [4.8e-4]
    for rel in rels:
        eb = rel * float(lvl.data.max() - lvl.data.min())
        n_values = sum(b.size for b in bricks)
        # (1) SHE: per-brick prediction + one shared tree
        enc = she.she_encode(bricks, eb, shared=True)
        # (2) per-block trees (the overhead SHE removes)
        sep = she.she_encode(bricks, eb, shared=False)
        # (3) TAC without SHE: merged 4D arrays, global prediction
        groups = merge_subblocks(grid, sbs)
        merged_bits = sum(sz.compress_lorenzo(arr, eb).total_bits
                          for arr in groups.values())
        for name, bits in (("SHE(shared)", enc.total_bits),
                           ("per-block-trees", sep.total_bits),
                           ("merged-4D", merged_bits)):
            rows.append((rel, name, round(n_values * 32 / bits, 2),
                         round(bits / n_values, 3), len(bricks)))
    path = write_csv("she_ablation",
                     ["rel_eb", "variant", "cr", "bit_rate", "n_blocks"],
                     rows)
    by = {r[1]: r[2] for r in rows if r[0] == rels[-1]}
    speed = run_batched_speedup(quick=quick)
    return {"csv": path, "cr": by,
            "she_gain_vs_per_block": round(
                by["SHE(shared)"] / by["per-block-trees"], 3),
            "she_gain_vs_merged": round(
                by["SHE(shared)"] / by["merged-4D"], 3),
            **{k: v for k, v in speed.items() if k != "csv"}}


def run_batched_speedup(quick: bool = False):
    """Sequential vs batched she_encode on a many-small-blocks level."""
    size = (64, 64, 64) if quick else (96, 96, 96)
    ds = amr.synthetic_amr(size, densities=[0.23, 0.77], refine_block=4,
                           seed=10)
    lvl = ds.levels[0]
    grid = make_block_grid(lvl.data, lvl.mask, unit=4)
    bricks = [extract_subblock(grid, sb) for sb in akdtree_partition(grid)]
    assert len(bricks) >= 256, len(bricks)
    eb = 4.8e-4 * float(lvl.data.max() - lvl.data.min())
    reps = 2 if quick else 3
    times = {}
    bits = {}
    for batched in (False, True):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            enc = she.she_encode(bricks, eb, shared=True, batched=batched)
            best = min(best, time.perf_counter() - t0)
        times[batched] = best
        bits[batched] = enc.total_bits
    assert bits[True] == bits[False], "batched path is not bit-identical"
    speedup = times[False] / times[True]
    rows = [(len(bricks), round(times[False], 4), round(times[True], 4),
             round(speedup, 2), bits[True])]
    path = write_csv("she_batched_speedup",
                     ["n_blocks", "seq_s", "batched_s", "speedup",
                      "total_bits"], rows)
    return {"csv": path, "n_blocks": len(bricks),
            "batched_speedup": round(speedup, 2)}


if __name__ == "__main__":
    print(run())
