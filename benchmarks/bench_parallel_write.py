"""Multi-part parallel write vs the single-file writer (ISSUE 5).

The claim being tracked: the write path — the bottleneck of in-situ AMR
compression (AMRIC) — scales with worker count.  A 4-worker
:class:`~repro.io.parallel.ParallelTACZWriter` (process mode: the
compression/entropy stages hold the GIL too finely for threads) must
beat one :class:`~repro.io.TACZWriter` streaming the same raw levels.

Both sides run the identical pipeline per brick (the batched compressor
is per-brick independent, so the outputs decode bit-identically — the
bench verifies that too); the parallel writer's edge is N workers
compressing and packing disjoint sub-block partitions concurrently.

**Gate.**  The target is ≥1.5× with 4 workers.  Raw multi-process
scaling varies wildly across CI containers (a throttled 2-vCPU box
physically cannot run 4 workers 1.5× faster — we measure ~1.4× scaling
for *pure numpy work* on such boxes), so the bench first measures the
machine's own 4-process scaling on a numpy kernel and gates against

    bar = min(1.5, max(0.8, 0.55 * hw_scaling))

— on any healthy multi-core runner (``hw_scaling ≥ ~2.7``) that is the
full 1.5× bar; on an oversubscribed container the bar degrades
proportionally instead of failing spuriously.  Both numbers land in the
CSV so the trajectory is visible either way.
"""
from __future__ import annotations

import multiprocessing
import os
import tempfile
import time

import numpy as np

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io.parallel import MultiPartReader, fork_safe, write_multipart

from .common import timed, write_csv

PASSES = 2
WORKERS = 4


def _hw_burn(n: int) -> None:
    x = np.random.default_rng(0).standard_normal(1 << 20)
    for _ in range(n):
        x = np.sqrt(np.abs(x * 1.0001) + 1e-6)


def measure_hw_scaling(workers: int = WORKERS, n: int = 120) -> float:
    """Measured speedup of ``workers`` processes over one process running
    the same numpy kernel — the machine's real parallel capacity."""
    ctx = multiprocessing.get_context(
        "fork" if "fork" in multiprocessing.get_all_start_methods()
        else "spawn")
    _hw_burn(n)                                    # warm
    _, t_serial = timed(_hw_burn, n * workers)

    def burst():
        ps = [ctx.Process(target=_hw_burn, args=(n,))
              for _ in range(workers)]
        for p in ps:
            p.start()
        for p in ps:
            p.join()

    _, t_par = timed(burst)
    return t_serial / max(t_par, 1e-9)


def _dataset(quick: bool):
    if quick:
        return amr.synthetic_amr((128, 128, 128),
                                 densities=[0.3, 0.3, 0.4],
                                 refine_block=4, seed=0), "synth128x3"
    return amr.synthetic_amr((192, 192, 192),
                             densities=[0.25, 0.25, 0.25, 0.25],
                             refine_block=8, seed=0), "synth192x4"


def run(quick: bool = False):
    ds, name = _dataset(quick)
    eb = 1e-4 * float(max(float(l.data.max()) for l in ds.levels)
                      - min(float(l.data.min()) for l in ds.levels))
    # warm the compression code paths on a small level without importing
    # jax (numpy engine keeps os.fork available for the worker pool)
    hybrid.compress_level(ds.levels[-1].data, ds.levels[-1].mask, eb=eb,
                          unit=2, lorenzo_engine="numpy")
    hw_scaling = measure_hw_scaling()

    with tempfile.TemporaryDirectory() as d:
        def parallel_write(tag):
            return write_multipart(
                os.path.join(d, f"p{tag}.taczd"), ds, parts=WORKERS,
                mode="process", eb=eb, lorenzo_engine="numpy")

        def single_write(tag):
            path = os.path.join(d, f"s{tag}.tacz")
            with tacz.TACZWriter(path, eb=eb,
                                 lorenzo_engine="numpy") as w:
                for lvl in ds.levels:
                    w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
            return path

        t_par = t_single = float("inf")
        parallel_write("warm")                      # worker-pool warm-up
        for i in range(PASSES):                     # best-of: CI boxes jitter
            mp_path, dt = timed(parallel_write, i)
            t_par = min(t_par, dt)
            sf_path, dt = timed(single_write, i)
            t_single = min(t_single, dt)

        # the two snapshots must decode bit-identically (the whole point
        # of sharing one partition + per-brick-independent compression)
        with MultiPartReader(mp_path) as mrd:
            for a, b in zip(tacz.read(sf_path), mrd.read()):
                np.testing.assert_array_equal(a, b)
            n_keys = len(mrd.subblock_keys())

        total_mb = sum(l.data.nbytes for l in ds.levels) / 1e6
        speedup = t_single / max(t_par, 1e-9)
        # the gate is about the writer, not about multiprocessing start
        # method overhead: when this host cannot fork (XLA backends
        # already initialized — spawn workers re-import the stack every
        # pass), record the numbers but don't assert against them
        gated = fork_safe()
        bar = min(1.5, max(0.8, 0.55 * hw_scaling)) if gated else 0.0
        rows = [(name, len(ds.levels), round(total_mb, 1), n_keys, WORKERS,
                 round(t_single, 3), round(t_par, 3), round(speedup, 2),
                 round(hw_scaling, 2), round(bar, 2),
                 "fork" if gated else "spawn-advisory")]

    path = write_csv("parallel_write",
                     ["dataset", "n_levels", "raw_mb", "subblock_keys",
                      "workers", "single_s", "parallel_s", "speedup",
                      "hw_scaling", "bar", "mode"],
                     rows)
    if gated and speedup < bar:
        raise AssertionError(
            f"parallel-write acceptance regressed: {WORKERS}-worker "
            f"multi-part write is only {speedup:.2f}x the single-writer "
            f"baseline (bar {bar:.2f}x at measured {hw_scaling:.2f}x "
            f"hardware scaling; target 1.5x on CI-class hardware)")
    return {"csv": path, "parallel_over_single": round(speedup, 2),
            "hw_scaling": round(hw_scaling, 2)}


if __name__ == "__main__":
    print(run(quick=True))
