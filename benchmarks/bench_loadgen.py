"""Zipf load harness over a live 2-shard fleet, gated by pinned SLOs
(ISSUE 8).

This is the closed-loop proof that the fleet observability plane works
end to end: a 2-shard fleet (+ a mounted router endpoint) serves
open-loop Zipf traffic from :class:`repro.serving.loadgen.
LoadGenerator` while a :class:`repro.obs.collect.FleetCollector`
scrapes all three endpoints, and an :class:`repro.obs.slo.SLOEngine`
renders the verdict against a **pinned** SLO set:

  * ``errors`` — windowed non-2xx share of ``tacz_http_requests_total``
    must stay below 0.1 % (in practice: zero — the load run also counts
    client-side errors and requires none);
  * ``tail_spread`` — windowed p99/p50 of ``tacz_server_request_seconds``
    stays bounded (a fleet whose tail detaches from its median by 150×
    on warm traffic is broken, whatever the absolute numbers on a noisy
    CI runner);
  * ``fleet_up`` — every endpoint up (scrape success + ``/v1/health``).

Bit-identity is enforced through the load generator itself: a sampled
fraction of responses is compared ``np.array_equal`` against a local
reader, and any mismatch fails the bench — a fleet that got fast by
corrupting crops cannot pass.

A second, gated scenario (ISSUE 10) **grows the fleet one shard while
the load runs**: at the halfway request a loadgen action starts a third
shard on the grown map, hands the moved decoded bricks over
(``/v1/cache/export`` → ``/v1/cache/import``), and swaps the router —
old owners ``reshard()`` only after the run.  Gates: zero failed
requests through the transition, some bricks actually handed off, the
old owners drop their moved keys, the post-swap warm hit rate does not
collapse (≥ pre-swap − 10 points), and the same pinned SLO set passes.

Artifacts: one CSV row per run configuration, the SLO verdict merged
into ``bench_summary.json`` (via the driver), and the collector's fleet
JSON snapshot (``loadgen_fleet.json``) — per-endpoint health + metrics
plus the fleet aggregate — which CI uploads next to the CSVs.
"""
from __future__ import annotations

import os
import tempfile
import threading

from repro import io as tacz
from repro import obs
from repro.core import hybrid
from repro.obs import FleetCollector, SLOEngine, SLORule
from repro.serving import (LoadGenerator, RegionClient,
                           ShardedRegionRouter, ShardMap, ZipfWorkload,
                           client_fetch, serve)

from .common import RESULTS_DIR, dataset, eb_for, write_csv

#: the pinned SLO set — loosen only with a written justification, this
#: is the bench's acceptance bar
SLO_RULES = [
    SLORule("errors", "error_rate", "<", 0.001,
            params={"metric": "tacz_http_requests_total"}),
    SLORule("tail_spread", "quantile_ratio", "<=", 150.0,
            params={"metric": "tacz_server_request_seconds",
                    "q_hi": 0.99, "q_lo": 0.50}),
    SLORule("fleet_up", "up", ">=", 1.0),
]


def run(quick: bool = False):
    obs.set_enabled(True)
    name = "run1_z10"
    ds = dataset(name)
    res = hybrid.compress_amr(ds, eb=eb_for(ds, 1e-3))
    rate = 50.0 if quick else 100.0
    n_requests = 80 if quick else 250
    population = 16 if quick else 32

    rows = []
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, name + ".tacz")
        tacz.write(path, res)
        m = ShardMap(["s0", "s1"], seed=7)
        servers = []

        def endpoint(**kw):
            httpd = serve(path, port=0, cache_bytes=64 << 20, **kw)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers.append(httpd)
            return f"http://127.0.0.1:{httpd.server_address[1]}"

        shard_httpds: dict[str, object] = {}

        def shard_endpoint(sid, smap):
            url = endpoint(shard_map=smap, shard_id=sid)
            shard_httpds[sid] = servers[-1]
            return url

        shard_urls = {sid: shard_endpoint(sid, m) for sid in m.shards}
        router = ShardedRegionRouter(
            path, m, {k: [v] for k, v in shard_urls.items()})
        try:
            urls = dict(shard_urls)
            urls["router"] = endpoint_url = \
                f"http://127.0.0.1:{serve_router(router, servers)}"
            client = RegionClient(endpoint_url)
            wl = ZipfWorkload(ds.finest_shape, levels=(0,),
                              population=population, seed=11)
            for q in wl.queries:          # warm pass: the SLO window
                client.regions([q.box], levels=list(q.levels))

            col = FleetCollector(urls, window=64)
            eng = SLOEngine(col, SLO_RULES)
            col.poll()                    # baseline scrape, post-warm-up
            with tacz.TACZReader(path) as rd:
                gen = LoadGenerator(
                    client_fetch(client), wl, rate=rate, concurrency=4,
                    verify_reader=rd, verify_fraction=0.2, seed=1)
                report = gen.run(n_requests)

                # -- scenario 2: grow s0,s1 -> s0,s1,s2 mid-run --------
                keys = list(rd.subblock_keys())
                new_map, moved = m.grow("s2", keys)
                grow_info = {"imported": 0}
                swap_stats: list[dict] = []

                def fleet_cache():
                    return [dict(h.region_server.cache.stats())
                            for h in shard_httpds.values()]

                def grow_fleet():
                    url2 = shard_endpoint("s2", new_map)
                    imported = 0
                    for sid in m.shards:      # old owners export
                        blob = RegionClient(
                            shard_urls[sid]).cache_export(moved)
                        imported += RegionClient(url2).cache_import(
                            blob)["imported"]
                    grow_info["imported"] = imported
                    router.apply_shard_map(
                        new_map, {**{k: [v] for k, v in
                                     shard_urls.items()},
                                  "s2": [url2]})
                    swap_stats.extend(fleet_cache())

                pre_stats = fleet_cache()
                grow_report = gen.run(
                    n_requests, actions={n_requests // 2: grow_fleet})
                post_stats = fleet_cache()
            col.poll()
            eng.evaluate()
            verdict = eng.verdict()

            # old owners drop moved keys only now that the router is on
            # the new map — resharding earlier would serve zeros
            dropped = sum(shard_httpds[sid].region_server.reshard(new_map)
                          for sid in m.shards)
            final = client.regions([wl.queries[0].box], levels=[0])
            assert final and final[0], "post-reshard fleet went dark"

            fleet_json = os.path.join(RESULTS_DIR, "loadgen_fleet.json")
            os.makedirs(RESULTS_DIR, exist_ok=True)
            col.dump_json(fleet_json)
            print(eng.report())
        finally:
            router.close()
            for httpd in servers:
                httpd.shutdown()
                httpd.server_close()
                httpd.region_server.close()

    pre_rate = _hit_rate(pre_stats, swap_stats[:len(pre_stats)])
    post_rate = _hit_rate(swap_stats, post_stats)
    for scenario, rep, n_ep in (("steady", report, 3),
                                ("grow", grow_report, 4)):
        d = rep.to_dict()
        rows.append((name, scenario, n_ep, d["offered_rate"],
                     d["achieved_rate"], d["requests"], d["errors"],
                     d["verified"], d["mismatches"], d["p50_ms"],
                     d["p90_ms"], d["p99_ms"], d["max_lag_ms"],
                     d["saturated"], verdict["passed"]))
    csv = write_csv("loadgen",
                    ["dataset", "scenario", "n_endpoints", "offered_rate",
                     "achieved_rate", "requests", "errors", "verified",
                     "mismatches", "p50_ms", "p90_ms", "p99_ms",
                     "max_lag_ms", "saturated", "slo_passed"],
                    rows)

    for scenario, rep in (("steady", report), ("grow", grow_report)):
        if rep.errors:
            raise AssertionError(
                f"loadgen acceptance failed ({scenario}): {rep.errors} "
                f"request error(s): {rep.error_messages[:3]}")
        if rep.verified == 0 or rep.mismatches:
            raise AssertionError(
                f"loadgen bit-identity failed ({scenario}): "
                f"verified={rep.verified} mismatches={rep.mismatches}")
    if not grow_info["imported"]:
        raise AssertionError(
            "grow scenario handed off zero warm bricks — the new shard "
            "came up cold")
    if not dropped:
        raise AssertionError(
            "old owners dropped nothing on reshard — the moved keys "
            "were never cached or the map did not change")
    if post_rate < pre_rate - 0.10:
        raise AssertionError(
            f"warm handoff failed: fleet hit rate fell from "
            f"{pre_rate:.2f} to {post_rate:.2f} across the reshard")
    if not verdict["passed"]:
        failing = {n: r for n, r in verdict["rules"].items()
                   if r["satisfied"] is False or r["state"] in
                   ("pending", "firing")}
        raise AssertionError(
            f"pinned SLO set failed under load: {failing}")
    d = report.to_dict()
    return {"csv": csv, "slo_passed": verdict["passed"],
            "p99_ms": d["p99_ms"], "achieved_rate": d["achieved_rate"],
            "grow_errors": grow_report.errors,
            "handoff_imported": grow_info["imported"],
            "reshard_dropped": dropped,
            "hit_rate_pre": round(pre_rate, 4),
            "hit_rate_post": round(post_rate, 4)}


def _hit_rate(before: list, after: list) -> float:
    """Fleet-wide cache hit rate over the window between two
    ``cache.stats()`` snapshots (servers added after ``before`` was
    taken count from zero)."""
    hits = misses = 0
    for i, b in enumerate(after):
        a = before[i] if i < len(before) else {"hits": 0, "misses": 0}
        hits += b["hits"] - a["hits"]
        misses += b["misses"] - a["misses"]
    total = hits + misses
    return hits / total if total else 1.0


def serve_router(router, servers) -> int:
    """Mount a router endpoint; returns its bound port."""
    httpd = serve(router, port=0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    servers.append(httpd)
    return httpd.server_address[1]


if __name__ == "__main__":
    print(run(quick=True))
