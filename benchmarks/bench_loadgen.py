"""Zipf load harness over a live 2-shard fleet, gated by pinned SLOs
(ISSUE 8).

This is the closed-loop proof that the fleet observability plane works
end to end: a 2-shard fleet (+ a mounted router endpoint) serves
open-loop Zipf traffic from :class:`repro.serving.loadgen.
LoadGenerator` while a :class:`repro.obs.collect.FleetCollector`
scrapes all three endpoints, and an :class:`repro.obs.slo.SLOEngine`
renders the verdict against a **pinned** SLO set:

  * ``errors`` — windowed non-2xx share of ``tacz_http_requests_total``
    must stay below 0.1 % (in practice: zero — the load run also counts
    client-side errors and requires none);
  * ``tail_spread`` — windowed p99/p50 of ``tacz_server_request_seconds``
    stays bounded (a fleet whose tail detaches from its median by 150×
    on warm traffic is broken, whatever the absolute numbers on a noisy
    CI runner);
  * ``fleet_up`` — every endpoint up (scrape success + ``/v1/health``).

Bit-identity is enforced through the load generator itself: a sampled
fraction of responses is compared ``np.array_equal`` against a local
reader, and any mismatch fails the bench — a fleet that got fast by
corrupting crops cannot pass.

Artifacts: one CSV row per run configuration, the SLO verdict merged
into ``bench_summary.json`` (via the driver), and the collector's fleet
JSON snapshot (``loadgen_fleet.json``) — per-endpoint health + metrics
plus the fleet aggregate — which CI uploads next to the CSVs.
"""
from __future__ import annotations

import os
import tempfile
import threading

from repro import io as tacz
from repro import obs
from repro.core import hybrid
from repro.obs import FleetCollector, SLOEngine, SLORule
from repro.serving import (LoadGenerator, RegionClient,
                           ShardedRegionRouter, ShardMap, ZipfWorkload,
                           client_fetch, serve)

from .common import RESULTS_DIR, dataset, eb_for, write_csv

#: the pinned SLO set — loosen only with a written justification, this
#: is the bench's acceptance bar
SLO_RULES = [
    SLORule("errors", "error_rate", "<", 0.001,
            params={"metric": "tacz_http_requests_total"}),
    SLORule("tail_spread", "quantile_ratio", "<=", 150.0,
            params={"metric": "tacz_server_request_seconds",
                    "q_hi": 0.99, "q_lo": 0.50}),
    SLORule("fleet_up", "up", ">=", 1.0),
]


def run(quick: bool = False):
    obs.set_enabled(True)
    name = "run1_z10"
    ds = dataset(name)
    res = hybrid.compress_amr(ds, eb=eb_for(ds, 1e-3))
    rate = 50.0 if quick else 100.0
    n_requests = 80 if quick else 250
    population = 16 if quick else 32

    rows = []
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, name + ".tacz")
        tacz.write(path, res)
        m = ShardMap(["s0", "s1"], seed=7)
        servers = []

        def endpoint(**kw):
            httpd = serve(path, port=0, cache_bytes=64 << 20, **kw)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers.append(httpd)
            return f"http://127.0.0.1:{httpd.server_address[1]}"

        urls = {sid: endpoint(shard_map=m, shard_id=sid)
                for sid in m.shards}
        router = ShardedRegionRouter(path, m,
                                     {k: [v] for k, v in urls.items()})
        try:
            urls["router"] = endpoint_url = \
                f"http://127.0.0.1:{serve_router(router, servers)}"
            client = RegionClient(endpoint_url)
            wl = ZipfWorkload(ds.finest_shape, levels=(0,),
                              population=population, seed=11)
            for q in wl.queries:          # warm pass: the SLO window
                client.regions([q.box], levels=list(q.levels))

            col = FleetCollector(urls, window=64)
            eng = SLOEngine(col, SLO_RULES)
            col.poll()                    # baseline scrape, post-warm-up
            with tacz.TACZReader(path) as rd:
                gen = LoadGenerator(
                    client_fetch(client), wl, rate=rate, concurrency=4,
                    verify_reader=rd, verify_fraction=0.2, seed=1)
                report = gen.run(n_requests)
            col.poll()
            eng.evaluate()
            verdict = eng.verdict()
            fleet_json = os.path.join(RESULTS_DIR, "loadgen_fleet.json")
            os.makedirs(RESULTS_DIR, exist_ok=True)
            col.dump_json(fleet_json)
            print(eng.report())
        finally:
            router.close()
            for httpd in servers:
                httpd.shutdown()
                httpd.server_close()
                httpd.region_server.close()

    d = report.to_dict()
    rows.append((name, len(urls), d["offered_rate"], d["achieved_rate"],
                 d["requests"], d["errors"], d["verified"],
                 d["mismatches"], d["p50_ms"], d["p90_ms"], d["p99_ms"],
                 d["max_lag_ms"], d["saturated"], verdict["passed"]))
    csv = write_csv("loadgen",
                    ["dataset", "n_endpoints", "offered_rate",
                     "achieved_rate", "requests", "errors", "verified",
                     "mismatches", "p50_ms", "p90_ms", "p99_ms",
                     "max_lag_ms", "saturated", "slo_passed"],
                    rows)

    if report.errors:
        raise AssertionError(
            f"loadgen acceptance failed: {report.errors} request "
            f"error(s) under Zipf load: {report.error_messages[:3]}")
    if report.verified == 0 or report.mismatches:
        raise AssertionError(
            f"loadgen bit-identity failed: verified={report.verified} "
            f"mismatches={report.mismatches}")
    if not verdict["passed"]:
        failing = {n: r for n, r in verdict["rules"].items()
                   if r["satisfied"] is False or r["state"] in
                   ("pending", "firing")}
        raise AssertionError(
            f"pinned SLO set failed under load: {failing}")
    return {"csv": csv, "slo_passed": verdict["passed"],
            "p99_ms": d["p99_ms"], "achieved_rate": d["achieved_rate"]}


def serve_router(router, servers) -> int:
    """Mount a router endpoint; returns its bound port."""
    httpd = serve(router, port=0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    servers.append(httpd)
    return httpd.server_address[1]


if __name__ == "__main__":
    print(run(quick=True))
