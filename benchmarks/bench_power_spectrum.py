"""Power-spectrum fidelity with adaptive per-level error bounds
(paper Fig. 30 + §IV-F): TAC+ uniform-eb vs adaptive-eb vs the 3D baseline
at (approximately) matched compression ratio."""
from __future__ import annotations

import numpy as np

from repro.core import baselines, hybrid, metrics
from repro.core.adaptive_eb import level_error_bounds
from repro.core.amr import uniform_resolution

from .common import dataset, eb_for, write_csv


def run(quick: bool = False):
    ds = dataset("run1_z2")  # the paper's choice: TAC+ ≈ 3D baseline here
    uni = uniform_resolution(ds)
    rows = []
    rel = 6.7e-3
    eb = eb_for(ds, rel)

    cases = {
        "3D-baseline": baselines.compress_3d_baseline(ds, eb),
        "TAC+(uniform)": hybrid.compress_amr(ds, eb=eb, unit=8, keep_artifacts=False),
        "TAC+(adaptive)": hybrid.compress_amr(
            ds, eb=level_error_bounds(eb * 1.5, ds.n_levels,
                                      metric="power_spectrum"), unit=8,
            keep_artifacts=False),
    }
    for name, res in cases.items():
        rec = metrics.reconstruct_uniform(ds, res)
        perr = metrics.power_spectrum_error(uni, rec, k_max=10)
        rows.append((name, round(res.compression_ratio(), 2),
                     f"{perr.max():.3e}", f"{perr.mean():.3e}"))
    path = write_csv("power_spectrum",
                     ["method", "cr", "max_ps_err_k<10", "mean_ps_err"],
                     rows)
    return {"csv": path, "rows": rows}


if __name__ == "__main__":
    print(run())
