"""Density sweep (paper Figs. 12–13): GSP vs OpST vs AKDTree compression
performance as a function of unit-block density — the measurements behind
the hybrid thresholds T0/T1/T2."""
from __future__ import annotations

import numpy as np

from repro.core import amr, hybrid, metrics

from .common import write_csv

DENSITIES = [0.1, 0.25, 0.4, 0.55, 0.7, 0.85, 0.95]


def _level_at_density(density: float, seed: int = 0, n: int = 48):
    ds = amr.synthetic_amr((n, n, n), densities=[density, 1 - density],
                           refine_block=4, seed=seed)
    return ds.levels[0]


def run(quick: bool = False):
    rows = []
    dens = DENSITIES[1::2] if quick else DENSITIES
    for d in dens:
        lvl = _level_at_density(d)
        eb = 6.7e-3 * float(lvl.data.max() - lvl.data.min() + 1e-9)
        for algorithm, she in (("lor_reg", True), ("interp", False)):
            for strategy in ("gsp", "opst", "akdtree"):
                res = hybrid.compress_level(lvl.data, lvl.mask, eb=eb,
                                            unit=4, algorithm=algorithm,
                                            she=she, strategy=strategy,
                                            keep_artifacts=False)
                n_values = int(lvl.mask.sum())
                br = res.total_bits / n_values
                err = lvl.data[lvl.mask] - res.recon[lvl.mask]
                rng = float(lvl.data[lvl.mask].max()
                            - lvl.data[lvl.mask].min())
                psnr = (20 * np.log10(rng)
                        - 10 * np.log10(np.mean(err.astype(np.float64) ** 2)
                                        + 1e-30))
                rows.append((round(d, 2), algorithm, she, strategy,
                             round(br, 3), round(psnr, 2)))
    path = write_csv("density_sweep",
                     ["density", "algorithm", "she", "strategy", "bit_rate",
                      "psnr"], rows)
    return {"csv": path, "n_rows": len(rows)}


if __name__ == "__main__":
    print(run())
