"""Halo-finder fidelity (paper Table II): average relative mass / cell-count
differences of the largest halos, 3D baseline vs TAC+ uniform vs adaptive."""
from __future__ import annotations

from repro.core import baselines, hybrid, metrics
from repro.core.adaptive_eb import level_error_bounds
from repro.core.amr import uniform_resolution

from .common import dataset, eb_for, write_csv

# synthetic fields have milder contrast than Nyx: use a threshold that
# yields a realistic handful of halos on the 64³ grids
_THRESH = 12.0


def run(quick: bool = False):
    ds = dataset("run1_z2")
    uni = uniform_resolution(ds)
    ref_halos = metrics.halo_finder(uni, threshold_factor=_THRESH,
                                    min_cells=8)
    rel = 6.7e-3
    eb = eb_for(ds, rel)
    cases = {
        "3D-baseline": baselines.compress_3d_baseline(ds, eb),
        "TAC+(uniform)": hybrid.compress_amr(ds, eb=eb, unit=8, keep_artifacts=False),
        "TAC+(adaptive)": hybrid.compress_amr(
            ds, eb=level_error_bounds(eb * 1.4, ds.n_levels,
                                      metric="halo_finder"), unit=8,
            keep_artifacts=False),
    }
    rows = []
    for name, res in cases.items():
        rec = metrics.reconstruct_uniform(ds, res)
        halos = metrics.halo_finder(rec, threshold_factor=_THRESH,
                                    min_cells=8)
        md, cd = metrics.halo_diff(ref_halos, halos, top=3)
        rows.append((name, round(res.compression_ratio(), 2),
                     f"{md:.3e}", f"{cd:.3e}", len(halos)))
    path = write_csv("halo_finder",
                     ["method", "cr", "avg_rel_mass_diff",
                      "avg_rel_cells_diff", "n_halos"], rows)
    return {"csv": path, "n_ref_halos": len(ref_halos), "rows": rows}


if __name__ == "__main__":
    print(run())
