"""Batched entropy engine vs the serial oracle (ISSUE 6).

The claim being tracked: the :mod:`repro.core.entropy` engines remove the
per-payload launch/walk overhead of the Huffman stage.

  * **Decode** — the serial oracle walks every payload bit by bit in a
    Python loop; the batched engine decodes all payloads of a level in
    lockstep (one vectorized step per emitted symbol).  Gate: at ≥256
    payloads under one shared codebook, batched decode is **≥3×** the
    serial per-payload walk.
  * **Encode** — the serial path scatters one payload per launch; the
    batched engine packs the whole payload list in one offset-scatter
    pass over the pooled stream.  Gate: batched whole-level encode beats
    the per-payload loop (≥1×; typically well above).

Both gates run on synthetic quantization-code payloads shaped like SHE
levels (geometric-ish code distribution around the zero bin).  The bench
also re-asserts bit-identity — batched encode bytes and decode arrays
must equal the oracle's exactly, payload by payload — so a speedup can
never come from drifting off the format.
"""
from __future__ import annotations

import numpy as np

from repro.core import huffman
from repro.core.entropy import BatchedEngine, NumpyEngine

from .common import timed, write_csv

DECODE_BAR = 3.0
ENCODE_BAR = 1.0


def _payloads(n_payloads: int, n_codes: int, seed: int = 0):
    """Synthetic per-brick code streams under one shared codebook —
    two-sided geometric around the zero bin, like Lorenzo residuals."""
    rng = np.random.default_rng(seed)
    mag = rng.geometric(0.35, size=(n_payloads, n_codes)) - 1
    sign = rng.choice((-1, 1), size=(n_payloads, n_codes))
    codes = (mag * sign).astype(np.int64)
    cb = huffman.build_codebook(codes.ravel())
    return cb, [codes[i] for i in range(n_payloads)]


def run(quick: bool = False):
    n_payloads = 256 if quick else 1024
    n_codes = 512                         # one 8**3 unit brick per payload
    cb, codes_list = _payloads(n_payloads, n_codes)
    serial = NumpyEngine()
    batched = BatchedEngine()

    # -- encode: one pooled offset-scatter pass vs one launch per payload
    enc_b, t_enc_b = timed(batched.encode_payloads, cb, codes_list,
                           repeat=3)
    enc_s, t_enc_s = timed(serial.encode_payloads, cb, codes_list)
    assert enc_b == enc_s, "batched encode drifted off the serial bytes"
    enc_speedup = t_enc_s / max(t_enc_b, 1e-9)

    # -- decode: lockstep canonical walk vs per-payload serial bit-walk
    payloads = [(blob, nbits, n_codes) for blob, nbits in enc_s]
    dec_b, t_dec_b = timed(batched.decode_payloads, cb, payloads,
                           repeat=3)
    dec_s, t_dec_s = timed(serial.decode_payloads, cb, payloads)
    for a, b, ref in zip(dec_b, dec_s, codes_list):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, ref)
    dec_speedup = t_dec_s / max(t_dec_b, 1e-9)

    total_bits = sum(nbits for _, nbits in enc_s)
    rows = [(n_payloads, n_codes, total_bits,
             round(t_enc_s, 4), round(t_enc_b, 4), round(enc_speedup, 2),
             round(t_dec_s, 4), round(t_dec_b, 4), round(dec_speedup, 2))]
    path = write_csv("entropy",
                     ["payloads", "codes_per_payload", "total_bits",
                      "encode_serial_s", "encode_batched_s",
                      "encode_speedup", "decode_serial_s",
                      "decode_batched_s", "decode_speedup"],
                     rows)
    if dec_speedup < DECODE_BAR:
        raise AssertionError(
            f"entropy acceptance regressed: batched decode is only "
            f"{dec_speedup:.2f}x the serial walk at {n_payloads} payloads "
            f"(bar {DECODE_BAR}x)")
    if enc_speedup < ENCODE_BAR:
        raise AssertionError(
            f"entropy acceptance regressed: batched whole-level encode is "
            f"{enc_speedup:.2f}x the per-payload loop (must beat it)")
    return {"csv": path, "decode_speedup": round(dec_speedup, 2),
            "encode_speedup": round(enc_speedup, 2)}


if __name__ == "__main__":
    print(run(quick=True))
