"""Rate-distortion curves (paper Figs. 20–27): TAC+/TAC vs the baselines
on the Table-I-like synthetic datasets, for both Lor/Reg and Interp."""
from __future__ import annotations

from repro.core import baselines, hybrid, metrics

from .common import dataset, eb_for, write_csv

DATASETS = ["run1_z10", "run1_z5", "run1_z2", "run2_t3", "run3_z1",
            "warpx_800", "iamr_90", "iamr_150"]
REL_EBS = [3e-2, 1e-2, 6.7e-3, 3e-3, 1e-3, 3e-4]

METHODS = {
    "TAC+":       lambda ds, eb: hybrid.compress_amr(ds, eb=eb, unit=8,
                                                     algorithm="lor_reg",
                                                     she=True,
                                                     keep_artifacts=False),
    "TAC/lorreg": lambda ds, eb: hybrid.compress_amr(ds, eb=eb, unit=8,
                                                     algorithm="lor_reg",
                                                     she=False,
                                                     keep_artifacts=False),
    "TAC/interp": lambda ds, eb: hybrid.compress_amr(ds, eb=eb, unit=8,
                                                     algorithm="interp",
                                                     she=False,
                                                     keep_artifacts=False),
    "1D":         lambda ds, eb: baselines.compress_1d_naive(ds, eb),
    "zMesh":      lambda ds, eb: baselines.compress_zmesh(ds, eb),
    "3D":         lambda ds, eb: baselines.compress_3d_baseline(ds, eb),
}


def run(quick: bool = False):
    rows = []
    names = DATASETS[:3] if quick else DATASETS
    rels = REL_EBS[1:5] if quick else REL_EBS
    for name in names:
        ds = dataset(name)
        for rel in rels:
            eb = eb_for(ds, rel)
            for mname, fn in METHODS.items():
                res = fn(ds, eb)
                rows.append((name, mname, rel,
                             round(res.bit_rate(), 4),
                             round(res.compression_ratio(), 2),
                             round(metrics.amr_psnr(ds, res), 2)))
    path = write_csv("rate_distortion",
                     ["dataset", "method", "rel_eb", "bit_rate", "cr",
                      "psnr"], rows)
    # headline: best TAC CR gain vs best 1D-family baseline per dataset
    gains = {}
    for name in names:
        for rel in rels:
            r = {m: next(x for x in rows if x[0] == name and x[1] == m
                         and x[2] == rel) for m in METHODS}
            best_tac = max(r["TAC+"][4], r["TAC/interp"][4])
            base = max(r["1D"][4], r["zMesh"][4])
            gains.setdefault(name, []).append(best_tac / base)
    summary = {k: round(max(v), 2) for k, v in gains.items()}
    return {"csv": path, "max_gain_vs_1d": summary}


if __name__ == "__main__":
    print(run())
