"""Sharded vs single-server region serving under a constrained cache
(ISSUE 4).

The claim being tracked: the point of sharding the read path is that
**aggregate cache capacity scales with the shard count**.  A workload
whose unique decoded working set exceeds one server's cache budget
thrashes the single server's LRU (cyclic re-decode of the bit-serial
Huffman payloads every pass), while the same budget *per shard* lets a
2-shard fleet hold the whole working set warm — each shard owns about
half the ``(level, sub_block)`` keys.

Setup: one TAC+ snapshot; a batch of boxes tiling the domain (every
sub-block is needed, so the working set is the full decoded size); every
server — the single baseline and both shards — gets the **same** cache
budget, sized between the largest shard's slice and the full working set
(so the fleet fits and the single server cannot).  Both sides are
measured over the PR 3 HTTP wire format: the baseline through one
endpoint + ``RegionClient``, the fleet through two shard-filtered
endpoints + ``ShardedRegionRouter``.

Acceptance bar (enforced, like the other serving benches): 2-shard
aggregate warm throughput must **exceed** the single-server baseline on
the first dataset — if it stops winning, either the shard filter stopped
confining caches or the router's scatter-gather overhead ate the win.
"""
from __future__ import annotations

import os
import tempfile
import threading

import numpy as np

from repro import io as tacz
from repro.core import hybrid
from repro.serving import (RegionClient, ShardedRegionRouter, ShardMap,
                           serve)
from repro.serving.regions import WHOLE_LEVEL, DecodePlanner

from .common import dataset, eb_for, timed, write_csv

PASSES = 3


def _workload(shape) -> list[tuple]:
    """Eight boxes tiling the domain (2x2x2 halves): every sub-block is
    part of the working set, so repeats thrash an undersized LRU."""
    h = [max(1, s // 2) for s in shape]
    boxes = []
    for ox in (0, h[0]):
        for oy in (0, h[1]):
            for oz in (0, h[2]):
                boxes.append(((ox, ox + h[0]), (oy, oy + h[1]),
                              (oz, oz + h[2])))
    return boxes


def _working_set(path, boxes) -> dict:
    """Unique decoded bytes the batch needs, total and per shard-key."""
    with tacz.TACZReader(path) as rd:
        plans = DecodePlanner(rd).plan(
            [(li, b) for b in boxes for li in range(rd.n_levels)])
        per_key: dict[tuple, int] = {}
        for p in plans:
            for li, sbi in p.keys():
                shape = (rd.levels[li].shape if sbi == WHOLE_LEVEL
                         else rd.subblock_shape(li, sbi))
                per_key[(li, sbi)] = int(np.prod(shape)) * 4
    return per_key


def _balanced_map(keys_bytes: dict, n_shards: int) -> ShardMap:
    """Pick the seed (0..15) whose largest shard slice is smallest, so the
    per-server budget can sit between one slice and the full set."""
    best = None
    for seed in range(16):
        m = ShardMap([f"s{i}" for i in range(n_shards)], seed=seed)
        slices: dict[str, int] = {}
        for key, nbytes in keys_bytes.items():
            slices[m.owner(key)] = slices.get(m.owner(key), 0) + nbytes
        worst = max(slices.values()) if len(slices) == n_shards else 1 << 62
        if best is None or worst < best[0]:
            best = (worst, m)
    return best[1]


def run(quick: bool = False):
    names = ["run1_z10"] if quick else ["run1_z10", "run2_t4"]
    rows = []
    headline = None
    for name in names:
        ds = dataset(name)
        # tighter bound than the single-host bench: more payload bits →
        # a heavier entropy walk, the cost the shard fleet's aggregate
        # cache absorbs and the thrashing single server pays every pass
        res = hybrid.compress_amr(ds, eb=eb_for(ds, 1e-4))
        boxes = _workload(ds.finest_shape)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, name + ".tacz")
            tacz.write(path, res)
            per_key = _working_set(path, boxes)
            ws = sum(per_key.values())
            m = _balanced_map(per_key, 2)
            largest = max(sum(b for k, b in per_key.items()
                              if m.owner(k) == sid) for sid in m.shards)
            # just over the largest shard slice: each shard's slice fits,
            # the single server holds barely half the working set
            budget = max(4096, int(1.05 * largest))

            servers = []

            def endpoint(**kw):
                httpd = serve(path, port=0, cache_bytes=budget, **kw)
                threading.Thread(target=httpd.serve_forever,
                                 daemon=True).start()
                servers.append(httpd)
                return f"http://127.0.0.1:{httpd.server_address[1]}"

            def replay(fetch):
                return fetch(boxes)

            try:
                single = RegionClient(endpoint())
                replay(single.regions)                      # warm-up pass
                _, t_single = timed(replay, single.regions, repeat=PASSES)
                s_single = single.stats()

                urls = {sid: endpoint(shard_map=m, shard_id=sid)
                        for sid in m.shards}
                with ShardedRegionRouter(path, m, urls) as router:
                    replay(router.get_regions)              # warm-up pass
                    _, t_shard = timed(replay, router.get_regions,
                                       repeat=PASSES)
                    fallbacks = router.counters["local_fallbacks"]
                shard_stats = [s.region_server.cache.stats()
                               for s in servers[1:]]
            finally:
                for httpd in servers:
                    httpd.shutdown()
                    httpd.server_close()
                    httpd.region_server.close()

            speedup = t_single / max(t_shard, 1e-12)
            rows.append((
                name, len(boxes), round(ws / 1e3, 1),
                round(budget / 1e3, 1), round(t_single * 1e3, 2),
                round(t_shard * 1e3, 2), round(speedup, 2),
                len(per_key), s_single["hits"], s_single["misses"],
                sum(s["hits"] for s in shard_stats),
                sum(s["misses"] for s in shard_stats), fallbacks))
            if name == names[0]:
                headline = speedup
    path = write_csv("sharded_serving",
                     ["dataset", "n_boxes", "working_set_kb", "budget_kb",
                      "single_warm_ms", "sharded_warm_ms", "agg_speedup",
                      "unique_subblocks", "single_hits", "single_misses",
                      "shard_hits", "shard_misses", "local_fallbacks"],
                     rows)
    if headline is not None and headline <= 1.0:
        raise AssertionError(
            f"sharded-serving acceptance regressed: 2-shard aggregate warm "
            f"throughput only {headline:.2f}x the single-server baseline "
            f"on a cache-constrained batch (need >1x)")
    return {"csv": path, "sharded_over_single": round(headline or 0.0, 2)}


if __name__ == "__main__":
    print(run())
