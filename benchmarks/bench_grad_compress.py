"""Beyond-paper: error-bounded gradient compression (DESIGN.md Plane B).

Measures (a) DCN transport bytes saved by int8+scales vs f32/bf16
all-reduce, (b) convergence of error-feedback SGD on a quadratic vs exact
gradients — the quantization bias is eliminated by the feedback loop."""
from __future__ import annotations

import numpy as np

from repro.optim.grad_compress import _dequant_leaf, _quant_leaf

from .common import write_csv


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    n = 4096
    # (a) transport accounting for a 1M-param gradient
    g = rng.standard_normal((1024, 1024)).astype(np.float32)
    q, s = _quant_leaf(g)
    bytes_f32 = g.size * 4
    bytes_int8 = q.size + s.size * 4
    # (b) EF-SGD on a quadratic: x* = argmin ||Ax - b||²
    A = rng.standard_normal((n, 256)).astype(np.float32) / np.sqrt(n)
    xstar = rng.standard_normal((256,)).astype(np.float32)
    b = A @ xstar
    results = {}
    for mode in ("exact", "int8", "int8+ef"):
        x = np.zeros(256, np.float32)
        e = np.zeros(256, np.float32)
        lr = 0.5
        for _ in range(60 if quick else 200):
            grad = A.T @ (A @ x - b)
            if mode == "exact":
                upd = grad
            else:
                gin = grad + (e if mode == "int8+ef" else 0)
                q1, s1 = _quant_leaf(gin.reshape(1, -1))
                upd = _dequant_leaf(q1, s1, (1, 256)).reshape(-1)
                if mode == "int8+ef":
                    e = gin - upd
            x = x - lr * upd
        results[mode] = float(np.linalg.norm(x - xstar))
    rows = [("transport_ratio_vs_f32", round(bytes_f32 / bytes_int8, 2)),
            *[(f"final_err_{k}", f"{v:.2e}") for k, v in results.items()]]
    path = write_csv("grad_compress", ["metric", "value"], rows)
    return {"csv": path,
            "transport_ratio": round(bytes_f32 / bytes_int8, 2),
            "final_errors": results,
            "ef_recovers_exact": results["int8+ef"] < 10 * results["exact"]
            + 1e-3}


if __name__ == "__main__":
    print(run())
