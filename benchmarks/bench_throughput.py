"""Compression throughput (paper Tables III–V): MB/s including pre-process,
per method × dataset × error bound."""
from __future__ import annotations

from repro.core import baselines, hybrid

from .common import dataset, eb_for, timed, write_csv

DATASETS = ["run1_z10", "run1_z2", "run3_z1", "warpx_800", "iamr_90"]
RELS = [1e-2, 1e-3]


def run(quick: bool = False):
    rows = []
    names = DATASETS[:2] if quick else DATASETS
    for name in names:
        ds = dataset(name)
        mb = ds.total_values() * 4 / 1e6
        for rel in (RELS[:1] if quick else RELS):
            eb = eb_for(ds, rel)
            cases = {
                "TAC+": lambda: hybrid.compress_amr(ds, eb=eb, unit=8,
                                                    algorithm="lor_reg",
                                                    she=True,
                                                    keep_artifacts=False),
                "TAC/interp": lambda: hybrid.compress_amr(
                    ds, eb=eb, unit=8, algorithm="interp", she=False,
                    keep_artifacts=False),
                "1D": lambda: baselines.compress_1d_naive(ds, eb),
                "3D": lambda: baselines.compress_3d_baseline(ds, eb),
            }
            for mname, fn in cases.items():
                res, dt = timed(fn)
                rows.append((name, rel, mname, round(mb / dt, 1),
                             round(res.compression_ratio(), 1)))
    path = write_csv("throughput",
                     ["dataset", "rel_eb", "method", "mb_per_s", "cr"], rows)
    return {"csv": path, "n_rows": len(rows)}


if __name__ == "__main__":
    print(run())
