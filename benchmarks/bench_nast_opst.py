"""NaST vs OpST (paper Fig. 9 + §III-B): the optimized sparse tensor's
larger sub-blocks should match-or-beat the naive per-unit-block packing on
both CR and PSNR — the motivation for the maximal-cube DP."""
from __future__ import annotations

import numpy as np

from repro.core import amr, she
from repro.core.blocks import extract_subblock, make_block_grid, SubBlock
from repro.core.opst import opst_partition

from .common import write_csv


def run(quick: bool = False):
    ds = amr.synthetic_amr((48, 48, 48), densities=[0.23, 0.77],
                           refine_block=4, seed=10)
    lvl = ds.levels[0]  # 23 %-density fine level, as in Fig. 9
    grid = make_block_grid(lvl.data, lvl.mask, unit=4)
    eb = 7.2e-4 * float(lvl.data.max() - lvl.data.min())  # Fig. 9's bound
    n_values = int(grid.occ.sum()) * grid.unit ** 3

    cases = {}
    # NaST: every non-empty unit block is its own brick
    nast_sbs = [SubBlock(origin=tuple(c), bsize=(1, 1, 1))
                for c in np.argwhere(grid.occ)]
    # OpST: maximal cubes
    opst_sbs = opst_partition(grid)
    rows = []
    for name, sbs in (("NaST", nast_sbs), ("OpST", opst_sbs)):
        bricks = [extract_subblock(grid, sb) for sb in sbs]
        enc = she.she_encode(bricks, eb, shared=True)
        bits = enc.total_bits + sum(sb.meta_bits() for sb in sbs)
        # PSNR over valid cells
        err2 = 0.0
        rng = float(lvl.data[lvl.mask].max() - lvl.data[lvl.mask].min())
        n = 0
        for sb, r in zip(sbs, enc.results):
            brick = extract_subblock(grid, sb)
            err2 += float(((r.recon - brick) ** 2).sum())
            n += brick.size
        psnr = 20 * np.log10(rng) - 10 * np.log10(err2 / n + 1e-30)
        rows.append((name, len(sbs), round(n_values * 32 / bits, 2),
                     round(psnr, 2)))
    path = write_csv("nast_opst", ["method", "n_blocks", "cr", "psnr"], rows)
    nast, opst = rows
    return {"csv": path,
            "opst_fewer_blocks": round(nast[1] / opst[1], 1),
            "cr": {r[0]: r[2] for r in rows},
            "psnr": {r[0]: r[3] for r in rows}}


if __name__ == "__main__":
    print(run())
