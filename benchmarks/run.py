"""Benchmark driver: one module per paper table/figure (DESIGN.md §7).

``python -m benchmarks.run [--quick]`` runs everything and prints a
``name,seconds,headline`` CSV summary; per-benchmark CSVs land in
``results/bench/``.
"""
from __future__ import annotations

import argparse
import json
import time

from . import (bench_autotune, bench_density_sweep, bench_distributed,
               bench_entropy,
               bench_grad_compress, bench_halo, bench_kernels,
               bench_loadgen, bench_nast_opst, bench_parallel_write,
               bench_partition_time, bench_power_spectrum,
               bench_rate_distortion, bench_region_serving,
               bench_roi_decode, bench_sharded_serving, bench_she,
               bench_throughput)
from .common import record_summary

BENCHES = [
    ("rate_distortion (Figs 20-27)", bench_rate_distortion),
    ("density_sweep (Figs 12-13)", bench_density_sweep),
    ("partition_time (Fig 14)", bench_partition_time),
    ("she_ablation (Figs 15-16)", bench_she),
    ("nast_vs_opst (Fig 9)", bench_nast_opst),
    ("throughput (Tables III-V)", bench_throughput),
    ("power_spectrum (Fig 30)", bench_power_spectrum),
    ("halo_finder (Table II)", bench_halo),
    ("distributed (SIII-F)", bench_distributed),
    ("grad_compress (beyond-paper)", bench_grad_compress),
    ("kernels (beyond-paper)", bench_kernels),
    ("roi_decode (TACZ container)", bench_roi_decode),
    ("region_serving (TACZ serving)", bench_region_serving),
    ("sharded_serving (TACZ serving)", bench_sharded_serving),
    ("parallel_write (TACZ multi-part)", bench_parallel_write),
    ("entropy (batched Huffman engines)", bench_entropy),
    ("loadgen (fleet SLO harness)", bench_loadgen),
    ("autotune (TAC+ §IV-F eb tuning)", bench_autotune),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,seconds,headline")
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            out = mod.run(quick=args.quick)
        except Exception as exc:
            record_summary(name, metric="error", value=str(exc)[:200],
                           passed=False)
            raise
        dt = time.perf_counter() - t0
        headline = {k: v for k, v in out.items() if k != "csv"}
        # one verdict row per benchmark: first headline metric + the
        # gate threshold when the module reports one (a raising gate is
        # recorded as failed above)
        key = next(iter(headline), None)
        record_summary(name, metric=key or "seconds",
                       value=headline.get(key, round(dt, 2)),
                       threshold=out.get("threshold"), passed=True)
        print(f"{name},{dt:.1f},\"{json.dumps(headline)[:160]}\"", flush=True)


if __name__ == "__main__":
    main()
