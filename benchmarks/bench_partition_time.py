"""Partition-time comparison (paper Fig. 14): OpST's O(N²·d) vs AKDTree's
O(N/3·logN) across densities — the motivation for threshold T0."""
from __future__ import annotations

from repro.core import amr
from repro.core.akdtree import akdtree_partition
from repro.core.blocks import make_block_grid
from repro.core.opst import opst_partition

from .common import timed, write_csv

DENSITIES = [0.1, 0.3, 0.5, 0.7, 0.9]


def run(quick: bool = False):
    rows = []
    n = 32 if quick else 48
    for d in (DENSITIES[::2] if quick else DENSITIES):
        ds = amr.synthetic_amr((n, n, n), densities=[d, 1 - d],
                               refine_block=4, seed=1)
        lvl = ds.levels[0]
        grid = make_block_grid(lvl.data, lvl.mask, unit=4)
        sbs_o, t_opst = timed(opst_partition, grid)
        sbs_a, t_akd = timed(akdtree_partition, grid)
        rows.append((round(d, 2), round(t_opst * 1e3, 2),
                     round(t_akd * 1e3, 2), len(sbs_o), len(sbs_a)))
    path = write_csv("partition_time",
                     ["density", "opst_ms", "akdtree_ms", "opst_blocks",
                      "akdtree_blocks"], rows)
    # the paper's claim: OpST time grows with density, AKDTree stays flat
    opst_growth = rows[-1][1] / max(rows[0][1], 1e-9)
    akd_growth = rows[-1][2] / max(rows[0][2], 1e-9)
    return {"csv": path, "opst_time_growth": round(opst_growth, 1),
            "akdtree_time_growth": round(akd_growth, 1)}


if __name__ == "__main__":
    print(run())
