"""Distributed compression (paper §III-F): embarrassingly-parallel per-shard
partitioning vs serial, and the GSP halo-exchange traffic.

The paper predicts: GSP parallelizes with a stencil-style boundary
exchange; OpST/AKDTree lose compression when each shard partitions
independently (smaller max sub-blocks).  We quantify both."""
from __future__ import annotations

import numpy as np

from repro.core import amr, she
from repro.core.akdtree import akdtree_partition
from repro.core.blocks import extract_subblock, make_block_grid

from .common import write_csv


def run(quick: bool = False):
    ds = amr.synthetic_amr((48, 48, 48), densities=[0.4, 0.6],
                           refine_block=4, seed=5)
    lvl = ds.levels[0]
    eb = 6.7e-3 * float(lvl.data.max() - lvl.data.min())
    rows = []
    for n_shards in (1, 2, 4, 8):
        # split the domain along x into shards; partition each independently
        xs = np.array_split(np.arange(lvl.data.shape[0]), n_shards)
        bits = 0
        blocks = 0
        n_values = 0
        for sl in xs:
            sub = lvl.data[sl]
            msk = lvl.mask[sl]
            grid = make_block_grid(sub, msk, unit=4)
            sbs = akdtree_partition(grid)
            bricks = [extract_subblock(grid, sb) for sb in sbs]
            enc = she.she_encode(bricks, eb, shared=True)
            bits += enc.total_bits + sum(sb.meta_bits() for sb in sbs)
            blocks += len(sbs)
            n_values += int(msk.sum())
        # GSP halo exchange: one boundary slice per internal face
        halo_bytes = (n_shards - 1) * lvl.data.shape[1] * \
            lvl.data.shape[2] * 4 * 2
        rows.append((n_shards, round(n_values * 32 / bits, 2), blocks,
                     halo_bytes))
    path = write_csv("distributed",
                     ["n_shards", "cr", "total_subblocks",
                      "gsp_halo_bytes"], rows)
    return {"csv": path,
            "cr_loss_8_shards": round(rows[0][1] / rows[-1][1], 3),
            "rows": rows}


if __name__ == "__main__":
    print(run())
