"""Warm-vs-cold TACZ region serving (ISSUE 3).

Writes a TAC+ snapshot, then replays an overlapping-ROI workload (the
AMReX-visualization access pattern: many region reads against one
snapshot, arXiv:2309.16980) through a :class:`RegionServer` whose
sub-block cache is budgeted at **25 % of the file's decoded level bytes**.
Measured: the cold pass (first batch — entropy decode + batched recon),
the warm pass (same batch again — cache hits only), and the uncached
``read_roi`` replay of the same boxes for reference.

Acceptance bars (enforced, like the ROI-decode bench):

  * the warm repeated batch must run **≥3× faster** than the cold batch
    — if the cache stops absorbing the bit-serial Huffman walks, serving
    regressed;
  * the fully instrumented warm path (metrics + tracing recording into
    ``repro.obs``) must stay **≥0.95×** the throughput of the same
    workload with the registry disabled — observability may not tax the
    hot path (ISSUE 7).
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro import io as tacz, obs
from repro.core import hybrid
from repro.serving.regions import RegionServer

from .common import dataset, eb_for, record_summary, timed, write_csv

#: instrumented warm throughput / uninstrumented warm throughput
OBS_OVERHEAD_FLOOR = 0.95


def _workload(shape) -> list[tuple]:
    """Overlapping boxes in one hot corner of the domain — each ~1/27 of
    the volume, stepping by half a box so neighbors share sub-blocks."""
    side = max(4, shape[0] // 3)
    step = max(2, side // 2)
    boxes = []
    for ox in (0, step, 2 * step):
        for oy in (0, step):
            boxes.append(((ox, ox + side), (oy, oy + side), (0, side)))
    return boxes


def _obs_overhead_ratio(srv, boxes, repeat: int = 10) -> float:
    """Instrumented / uninstrumented warm throughput on one server.

    Both passes hit the same warm cache; the only difference is whether
    the ``repro.obs`` registry records.  Ratio > 1 means the instrumented
    path was faster (noise); the gate only cares about the floor.
    """
    was = obs.is_enabled()
    try:
        obs.set_enabled(True)
        srv.get_regions(boxes)                      # make both passes warm
        _, t_on = timed(srv.get_regions, boxes, repeat=repeat)
        obs.set_enabled(False)
        _, t_off = timed(srv.get_regions, boxes, repeat=repeat)
    finally:
        obs.set_enabled(was)
    return t_off / max(t_on, 1e-12)


def run(quick: bool = False):
    names = ["run1_z10"] if quick else ["run1_z10", "run2_t4"]
    rows = []
    headline = None
    overhead = None
    for name in names:
        ds = dataset(name)
        res = hybrid.compress_amr(ds, eb=eb_for(ds, 1e-3))
        level_bytes = sum(int(np.prod(lr.recon.shape)) * 4
                          for lr in res.levels)
        budget = max(4096, level_bytes // 4)          # 25 %-of-level budget
        boxes = _workload(ds.finest_shape)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, name + ".tacz")
            tacz.write(path, res)
            with tacz.TACZReader(path) as rd:
                _, t_serial = timed(
                    lambda: [rd.read_roi(b) for b in boxes])
            with RegionServer(path, cache_bytes=budget) as srv:
                _, t_cold = timed(srv.get_regions, boxes)
                _, t_warm = timed(srv.get_regions, boxes, repeat=3)
                s = srv.cache.stats()
                ratio = _obs_overhead_ratio(srv, boxes)
            speedup = t_cold / max(t_warm, 1e-12)
            rows.append((name, len(boxes), round(level_bytes / 1e3, 1),
                         round(budget / 1e3, 1),
                         round(t_serial * 1e3, 2), round(t_cold * 1e3, 2),
                         round(t_warm * 1e3, 3), round(speedup, 2),
                         s["hits"], s["misses"], s["evictions"],
                         round(ratio, 3)))
            if name == names[0]:
                headline = speedup
                overhead = ratio
    path = write_csv("region_serving",
                     ["dataset", "n_boxes", "level_kb", "budget_kb",
                      "roi_serial_ms", "cold_ms", "warm_ms",
                      "warm_speedup", "hits", "misses", "evictions",
                      "obs_overhead_ratio"],
                     rows)
    record_summary("region_serving/warm_over_cold",
                   metric="warm_speedup", value=round(headline or 0.0, 2),
                   threshold=3.0)
    record_summary("region_serving/obs_overhead",
                   metric="instrumented_over_uninstrumented",
                   value=round(overhead or 0.0, 3),
                   threshold=OBS_OVERHEAD_FLOOR)
    if headline is not None and headline < 3.0:
        raise AssertionError(
            f"region-serving acceptance regressed: warm repeated ROI batch "
            f"only {headline:.1f}x faster than cold at a 25%-of-level "
            f"cache budget (need >=3x)")
    if overhead is not None and overhead < OBS_OVERHEAD_FLOOR:
        raise AssertionError(
            f"observability overhead regressed: instrumented warm serving "
            f"runs at {overhead:.2f}x the uninstrumented baseline "
            f"(floor {OBS_OVERHEAD_FLOOR}x)")
    return {"csv": path, "warm_over_cold": round(headline or 0.0, 1),
            "obs_overhead_ratio": round(overhead or 0.0, 3)}


if __name__ == "__main__":
    print(run())
