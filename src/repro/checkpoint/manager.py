"""Checkpointing: atomic, async, elastic, optionally TAC-compressed.

Design (DESIGN.md §6):
  * **Logical storage** — checkpoints hold full (unsharded) tensors keyed
    by tree path, so restore works on *any* mesh shape (elastic scaling:
    a 512-chip checkpoint restores onto 256 chips and vice versa).
  * **Atomicity** — write to ``step_XXXX.tmp`` then ``os.replace``; a
    manifest with CRCs makes truncated writes detectable.
  * **Async** — serialization happens on a writer thread; ``wait()``
    joins before shutdown.
  * **Lossy mode** — the paper's pipeline applied to weights: per-tensor
    value-range-relative error bound (the per-AMR-level adaptive bound of
    §IV-F mapped to per-layer), dual-quant Lorenzo codes, zstd entropy
    stage ("sz-light": the Huffman stage is skipped for decode speed; zstd
    on Lorenzo codes keeps ~the same ratio on weight tensors).  Optimizer
    moments stay lossless by default; ``eb_rel=0`` disables lossy entirely.
    Lossy tensors are stored as self-describing TACZ container blobs
    (``repro.io.tensor``) — the same framed/indexed/CRC'd format the AMR
    pipeline writes — instead of ad-hoc ``(blob, eb, dtype, shape)`` dicts;
    pre-TACZ manifests (no ``"format"`` field) still restore.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from dataclasses import dataclass

import jax
import ml_dtypes
import numpy as np

from repro.core import compat

__all__ = ["CheckpointManager"]

def _codec_decompress(blob: bytes, codec: str) -> bytes:
    """Legacy (pre-TACZ) lossy-blob codec — restore path only."""
    if codec == "zstd":
        return compat.zstd_decompress(blob)
    return zlib.decompress(blob)

# numpy's savez cannot round-trip ml_dtypes (bfloat16 etc.) — store them as
# same-width unsigned views and restore through the recorded dtype string.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(a: np.ndarray) -> np.ndarray:
    if a.dtype.name in _VIEW_AS:
        return a.view(_VIEW_AS[a.dtype.name])
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return a.view(getattr(ml_dtypes, dtype_name))
    return a.astype(np.dtype(dtype_name)) if a.dtype.name != dtype_name else a


def _flatten_with_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten_with_paths(tree[k], f"{prefix}/{k}"))
        return out
    out[prefix] = tree
    return out


def _unflatten_from_paths(flat):
    root: dict = {}
    for path, v in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def _lossy_encode(a: np.ndarray, eb_rel: float):
    """Error-bounded "sz-light" encoding into a TACZ tensor blob."""
    rng = float(np.abs(a).max())
    if rng == 0 or eb_rel <= 0:
        return None
    eb = eb_rel * rng
    from repro.io import tensor as tacz_tensor

    return {"blob": tacz_tensor.encode_tensor(a, eb), "eb": eb}


def _lossy_decode_legacy(entry, out_dtype) -> np.ndarray:
    """Decode pre-TACZ lossy entries (manifests without a "format" field)."""
    raw = _codec_decompress(entry["blob"], entry.get("codec", "zstd"))
    codes = np.frombuffer(raw, dtype=entry["dtype"]).astype(np.int64)
    codes = codes.reshape(entry["shape"])
    for ax in range(codes.ndim):
        codes = np.cumsum(codes, axis=ax)
    return (codes.astype(np.float64) * 2 * entry["eb"]).astype(out_dtype)


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    lossy_eb_rel: float = 0.0        # 0 → lossless; e.g. 1e-4 → lossy params

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------- save ---------------------------------

    def save(self, step: int, params, opt_state, extra=None, *,
             blocking: bool = False):
        """Snapshot to host memory now, write asynchronously."""
        host = {
            "params": jax.tree.map(np.asarray, jax.device_get(params)),
            "opt": jax.tree.map(np.asarray, jax.device_get(opt_state)),
            "extra": extra or {},
        }
        self.wait()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def _write(self, step: int, host):
        flat_p = _flatten_with_paths(host["params"], "params")
        flat_o = _flatten_with_paths(host["opt"], "opt")
        arrays, manifest = {}, {"step": step, "entries": {}, "lossy": {}}
        for path, a in {**flat_p, **flat_o}.items():
            a = np.asarray(a)
            key = path.replace("/", "__")
            lossy = None
            if (self.lossy_eb_rel > 0 and path.startswith("params")
                    and a.ndim >= 2 and a.size > 4096):
                lossy = _lossy_encode(
                    a.astype(np.float32), self.lossy_eb_rel)
            if lossy is not None:
                arrays[key] = np.frombuffer(lossy["blob"], dtype=np.uint8)
                manifest["lossy"][key] = {
                    "format": "tacz", "eb": lossy["eb"],
                    "out_dtype": str(a.dtype)}
            else:
                arrays[key] = _to_storable(a)
            manifest["entries"][key] = {
                "path": path, "shape": list(a.shape), "dtype": str(a.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(a).tobytes())
                if lossy is None else zlib.crc32(arrays[key].tobytes()),
            }
        manifest["extra"] = host["extra"]
        base = os.path.join(self.directory, f"step_{step:08d}")
        tmp_npz, tmp_json = base + ".npz.tmp", base + ".json.tmp"
        with open(tmp_npz, "wb") as f:
            np.savez(f, **arrays)
        with open(tmp_json, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp_npz, base + ".npz")
        os.replace(tmp_json, base + ".json")
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(
                        self.directory, f"step_{s:08d}{ext}"))
                except OSError:
                    pass

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------ restore --------------------------------

    def list_steps(self):
        steps = []
        for f in os.listdir(self.directory):
            if f.startswith("step_") and f.endswith(".json"):
                steps.append(int(f[5:-5]))
        return sorted(steps)

    def restore(self, step: int, *, mesh=None, shardings=None):
        """Load a checkpoint; reshard onto ``mesh`` if given (elastic)."""
        base = os.path.join(self.directory, f"step_{step:08d}")
        with open(base + ".json") as f:
            manifest = json.load(f)
        with np.load(base + ".npz") as z:
            flat = {}
            for key, meta in manifest["entries"].items():
                a = z[key]
                if zlib.crc32(np.ascontiguousarray(a).tobytes()) != meta["crc"]:
                    raise IOError(f"checkpoint corruption at {meta['path']}")
                if key in manifest["lossy"]:
                    li = manifest["lossy"][key]
                    if li.get("format") == "tacz":
                        from repro.io import tensor as tacz_tensor

                        a = tacz_tensor.decode_tensor(a.tobytes())
                    else:
                        a = _lossy_decode_legacy(
                            {"blob": a.tobytes(), "eb": li["eb"],
                             "dtype": li["codes_dtype"],
                             "shape": tuple(li["shape"]),
                             "codec": li.get("codec", "zstd")},
                            np.float32)
                    a = a.astype(getattr(ml_dtypes, li["out_dtype"])
                                 if li["out_dtype"] in _VIEW_AS
                                 else np.dtype(li["out_dtype"]))
                else:
                    a = _from_storable(a, meta["dtype"])
                flat[meta["path"]] = a
        tree = _unflatten_from_paths(flat)
        params, opt = tree["params"], tree["opt"]
        if mesh is not None and shardings is not None:
            flat_s = _flatten_with_paths(shardings, "params")
            params = _unflatten_from_paths({
                p: jax.device_put(a, flat_s[p]) if p in flat_s
                else jax.device_put(a)
                for p, a in _flatten_with_paths(params, "params").items()})
            params = params["params"]
            opt = jax.tree.map(jax.device_put, opt)
        # opt step counter is stored as 0-d array
        return params, opt, int(manifest["step"])

    def restore_latest(self, *, mesh=None, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], mesh=mesh, shardings=shardings)
