"""Atomic/async/elastic checkpointing, optional TAC-compressed (lossy)."""
