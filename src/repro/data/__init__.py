"""Deterministic host-sharded synthetic data pipelines."""
