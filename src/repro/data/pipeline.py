"""Deterministic, host-sharded synthetic data pipeline.

Every host computes its own shard of every global batch from
``(seed, step, host_id)`` alone — no coordination, bit-reproducible across
restarts (resuming at step k regenerates exactly the batches a failed run
saw), and elastic (re-sharding by ``n_hosts`` is a pure index change).

Streams:
  * :func:`lm_batches` — Zipf-distributed token sequences with a Markov
    bigram structure (so the loss actually falls during the examples).
  * :func:`embedding_batches` — frame/patch embedding stand-ins for the
    stub-frontend archs (vlm/audio).
  * :func:`amr_token_batches` — Plane A ↔ Plane B bridge: tokens are
    quantization codes of a synthetic AMR field (the paper's data feeding
    the framework's model).
"""
from __future__ import annotations

import numpy as np

__all__ = ["lm_batches", "embedding_batches", "amr_token_batches"]


def _host_slice(global_batch: int, host_id: int, n_hosts: int):
    per = global_batch // n_hosts
    return host_id * per, per


def lm_batches(cfg, shape, *, seed: int = 0, host_id: int = 0,
               n_hosts: int = 1):
    """Infinite {tokens, labels} iterator; labels are next-token ids."""
    start, per = _host_slice(shape.global_batch, host_id, n_hosts)
    V = cfg.vocab_size
    S = shape.seq_len
    step = 0
    while True:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, host_id]))
        # Markov structure: tokens drift within a band + Zipf jumps
        base = rng.zipf(1.5, size=(per, 1)).clip(max=V - 1)
        drift = rng.integers(-8, 9, size=(per, S)).cumsum(axis=1)
        toks = ((base + np.abs(drift)) % V).astype(np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((per, 1), -1, np.int32)], axis=1)
        yield {"tokens": toks, "labels": labels}
        step += 1


def embedding_batches(cfg, shape, *, seed: int = 0, host_id: int = 0,
                      n_hosts: int = 1):
    """{embeds, labels} for input_mode='embeddings' archs (stub frontend)."""
    start, per = _host_slice(shape.global_batch, host_id, n_hosts)
    S, d, V = shape.seq_len, cfg.d_model, cfg.vocab_size
    step = 0
    while True:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, host_id, 1]))
        emb = rng.standard_normal((per, S, d)).astype(np.float32) * 0.02
        labels = rng.integers(0, V, size=(per, S)).astype(np.int32)
        labels[:, -1] = -1
        yield {"embeds": emb, "labels": labels}
        step += 1


def amr_token_batches(cfg, shape, *, seed: int = 0, host_id: int = 0,
                      n_hosts: int = 1, eb_rel: float = 1e-3):
    """Tokens = clipped Lorenzo quantization codes of a synthetic AMR field.

    Bridges the planes: the LM learns the code statistics the paper's
    Huffman stage exploits.  Codes are offset/clipped into [0, vocab)."""
    from ..core import amr as amr_mod
    from ..core import sz

    start, per = _host_slice(shape.global_batch, host_id, n_hosts)
    V, S = cfg.vocab_size, shape.seq_len
    step = 0
    while True:
        ds = amr_mod.synthetic_amr((32, 32, 32), densities=[0.3, 0.7],
                                   refine_block=4,
                                   seed=seed + 31 * step + host_id)
        field = ds.levels[0].data
        eb = eb_rel * float(field.max() - field.min() + 1e-9)
        codes = sz.lorenzo_nd_codes(sz.prequant(field, eb)).ravel()
        toks_all = np.clip(codes + V // 2, 0, V - 1).astype(np.int32)
        need = per * (S + 1)
        reps = int(np.ceil(need / toks_all.size))
        toks = np.tile(toks_all, reps)[:need].reshape(per, S + 1)
        yield {"tokens": toks[:, :-1],
               "labels": toks[:, 1:].astype(np.int32)}
        step += 1
