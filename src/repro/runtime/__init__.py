"""Resilience runtime: preemption, watchdog/straggler detection, injection."""
