"""Fault-tolerance runtime: preemption handling, step watchdog / straggler
log, failure injection for tests.

At 1000+ nodes the assumptions are: (a) preemptions are routine (handle
SIGTERM by checkpointing and exiting cleanly), (b) stragglers are detected
by step-time outliers (the watchdog keeps an EWMA and flags steps that
exceed ``straggler_factor``× the typical time), (c) hard failures are
recovered by restart-from-latest-checkpoint (exercised by the integration
tests through :class:`FailureInjector`).
"""
from __future__ import annotations

import contextlib
import logging
import signal
import threading
import time
from dataclasses import dataclass, field

logger = logging.getLogger("repro.resilience")

__all__ = ["PreemptionGuard", "StepWatchdog", "FailureInjector",
           "SimulatedFailure"]


class PreemptionGuard:
    """SIGTERM/SIGINT → ``should_stop`` flag (checkpoint-and-exit)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._installed = []
        for sig in signals:
            try:
                prev = signal.signal(sig, self._handler)
                self._installed.append((sig, prev))
            except ValueError:
                pass  # not the main thread — tests

    def _handler(self, signum, frame):
        logger.warning("preemption signal %s received — will checkpoint "
                       "and stop after this step", signum)
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def trigger(self):
        """Programmatic preemption (tests)."""
        self._stop.set()


@dataclass
class StepWatchdog:
    """Times steps; flags stragglers; optional hard timeout logging."""

    timeout: float = 0.0                # 0 → no hard timeout
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    _ewma: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    stragglers: list = field(default_factory=list, init=False)
    durations: list = field(default_factory=list, init=False)

    @contextlib.contextmanager
    def step(self, step_idx: int):
        t0 = time.monotonic()
        yield
        dt = time.monotonic() - t0
        self.durations.append(dt)
        if self._n > 3 and dt > self.straggler_factor * self._ewma:
            self.stragglers.append((step_idx, dt, self._ewma))
            logger.warning("straggler: step %d took %.3fs (typical %.3fs)",
                           step_idx, dt, self._ewma)
        if self.timeout and dt > self.timeout:
            logger.error("step %d exceeded hard timeout (%.1fs > %.1fs)",
                         step_idx, dt, self.timeout)
        self._ewma = (dt if self._n == 0
                      else (1 - self.ewma_alpha) * self._ewma
                      + self.ewma_alpha * dt)
        self._n += 1


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically raise at a given step (restart-recovery tests)."""

    fail_at_step: int = -1
    armed: bool = True

    def check(self, step: int):
        if self.armed and step == self.fail_at_step:
            self.armed = False
            raise SimulatedFailure(f"injected failure at step {step}")
