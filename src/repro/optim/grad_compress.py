"""Error-bounded gradient compression for the slow inter-pod links.

The framework-plane reuse of the paper's quantization stage (DESIGN.md §4):
on a multi-pod mesh the ``pod`` axis crosses DCN, ~10–30× slower than ICI.
We reduce gradients hierarchically:

  1. full-precision ``psum`` *within* a pod (fast ICI, unchanged), then
  2. per-group int8 quantization (``repro.kernels.qdq`` semantics — groups
     are the unit blocks of TAC, scales its per-level error bounds), an
     **int8 all-gather across pods** (4× less DCN traffic than f32), local
     dequant + mean, and
  3. **error feedback**: the quantization residual is carried into the
     next step's gradient, so compression error does not bias convergence
     (EF-SGD/EF21 family).

The public entry is :func:`compress_pod_reduce`, used inside the
``shard_map``-based train step (manual over ``pod``/``data``, auto over
``model``).  On a single-pod mesh it degrades to the plain psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_tree", "dequantize_tree", "compress_pod_reduce",
           "init_error_feedback"]

_GROUP = 256


def _quant_leaf(g):
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _GROUP
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    grp = flat.reshape(-1, _GROUP)
    amax = jnp.max(jnp.abs(grp), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(grp / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_leaf(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def quantize_tree(grads):
    qs = jax.tree.map(lambda g: _quant_leaf(g), grads)
    return qs


def dequantize_tree(qs, shapes):
    return jax.tree.map(lambda qv, sh: _dequant_leaf(qv[0], qv[1], sh),
                        qs, shapes, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and hasattr(x[0], "dtype"))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_pod_reduce(grads, ef, *, pod_axis: str | None, n_pods: int):
    """Hierarchically reduce ``grads`` across pods with int8 transport.

    Called inside ``shard_map`` where ``pod_axis`` is a manual axis.  The
    within-pod (data-axis) reduction must already have happened.  Returns
    (reduced grads, new error-feedback state).
    """
    if pod_axis is None or n_pods <= 1:
        return grads, ef

    def one(g, e):
        gc = g.astype(jnp.float32) + e          # apply error feedback
        q, scale = _quant_leaf(gc)
        local_deq = _dequant_leaf(q, scale, g.shape)
        new_e = gc - local_deq                  # residual carried forward
        # int8 codes + f32 scales cross the DCN (4×/16× smaller than f32)
        q_all = jax.lax.all_gather(q, pod_axis)          # (pods, …)
        s_all = jax.lax.all_gather(scale, pod_axis)
        deq = jnp.mean(
            q_all.astype(jnp.float32) * s_all[..., None], axis=0)
        flat = deq.reshape(-1)
        n = 1
        for s in g.shape:
            n *= s
        return flat[:n].reshape(g.shape).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
