"""Adafactor (Shazeer & Stern, arXiv:1804.04235) — factored second moments.

The memory-bound cells (llama3-405b, internvl2-76b train) cannot afford
full Adam moments even in bf16 on a 256-chip v5e pod: params + m + v + grad
accumulator ≥ 12.7 GB/chip before a single activation.  Adafactor stores
the second moment of an (n, m) matrix as a row vector + column vector
(rank-1 reconstruction), reducing optimizer state from 2×params to
~params·(1/n + 1/m) (+ optional bf16 first moment).

Used by ``RunConfig.optimizer = "adafactor"``; same (init/update) interface
as :mod:`repro.optim.adamw`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .adamw import global_norm, lr_schedule, AdamWConfig

__all__ = ["AdafactorConfig", "adafactor_init", "adafactor_update"]


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 3e-4
    decay: float = 0.8            # v decay exponent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0   # update RMS clip (Adafactor §6)
    weight_decay: float = 0.0
    beta1: float = 0.0            # 0 → no first moment stored
    moments_dtype: str = "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000

    # mirror AdamWConfig's schedule interface
    @property
    def b1(self):
        return self.beta1


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params, cfg: AdafactorConfig):
    dt = jnp.dtype(cfg.moments_dtype)

    def v_state(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    state = {"v": jax.tree.map(v_state, params,
                               is_leaf=lambda x: hasattr(x, "shape")),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.beta1 > 0:
        state["mu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return state


def adafactor_update(params, grads, opt_state, cfg: AdafactorConfig):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    sched = AdamWConfig(lr=cfg.lr, warmup_steps=cfg.warmup_steps,
                        total_steps=cfg.total_steps)
    lr = lr_schedule(sched, step)
    gnorm = global_norm(grads)

    def upd(p, g, v, m):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if _factored(p.shape):
            vr = v["vr"] * beta2 + g2.mean(axis=-1) * (1 - beta2)
            vc = v["vc"] * beta2 + g2.mean(axis=-2) * (1 - beta2)
            new_v = {"vr": vr, "vc": vc}
            denom = (vr / jnp.maximum(
                vr.mean(axis=-1, keepdims=True), cfg.eps))[..., None] \
                * vc[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(denom, cfg.eps))
        else:
            nv = v["v"] * beta2 + g2 * (1 - beta2)
            new_v = {"v": nv}
            u = g * jax.lax.rsqrt(jnp.maximum(nv, cfg.eps))
        # RMS clip
        rms = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if m is not None:
            m32 = m.astype(jnp.float32) * cfg.beta1 + u * (1 - cfg.beta1)
            u = m32
            new_m = m32.astype(m.dtype)
        else:
            new_m = None
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, new_v, new_m

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_m = (tdef.flatten_up_to(opt_state["mu"])
              if "mu" in opt_state else [None] * len(flat_p))
    out = [upd(p, g, v, m)
           for p, g, v, m in zip(flat_p, flat_g, flat_v, flat_m)]
    new_state = {"v": tdef.unflatten([o[1] for o in out]), "step": step}
    if "mu" in opt_state:
        new_state["mu"] = tdef.unflatten([o[2] for o in out])
    return (tdef.unflatten([o[0] for o in out]), new_state,
            {"grad_norm": gnorm, "lr": lr})
