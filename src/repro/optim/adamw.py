"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule — built from scratch (no optax in this image).

Moments dtype is configurable (``RunConfig.optimizer_dtype``): bf16 moments
halve optimizer memory for the 405B cell; master params stay in the model
dtype with fp32 update math.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moments_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["mu"])
    flat_v = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, stats
