"""``repro.tuning`` — error-bound autotuning and variant-set production.

The closed loop the paper's §IV-F recipe implies but never automates:
search per-level error bounds for the fewest encoded bits that meet an
application-metric distortion target (PSNR, max abs error, power-
spectrum error), record the probed rate–distortion frontier into the
snapshot (``repro.io.frontier``), and — via :func:`write_variant_set` —
publish multi-variant snapshot sets the serving layer answers
distortion-target requests from (``repro.serving.variants``).

See ``docs/tuning.md`` for the loop, the frontier section spec, and the
distortion-target wire API.
"""
from .autotune import (AutoTuner, TuneResult, measure_metrics,
                       write_variant_set)

__all__ = ["AutoTuner", "TuneResult", "measure_metrics",
           "write_variant_set"]
