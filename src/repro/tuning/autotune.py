"""Per-level error-bound autotuning against application metrics (ISSUE 9,
paper §IV-F taken from recipe to closed loop).

``core/adaptive_eb`` gives the paper's *a-priori* fine:coarse eb ratios;
this module closes the loop: compress, measure the application metric on
the decoded snapshot, and search the per-level eb vector for the fewest
encoded bits that still meet a distortion target.  The search is
coordinate descent over a per-level log-spaced eb ladder with two memo
layers — per ``(level, eb)`` compression results (levels compress
independently, so moving one level's bound recompresses one level) and
per eb-vector metric evaluations — seeded at the adaptive-eb heuristic
vector.  Every evaluated point lands on the recorded rate–distortion
:class:`~repro.io.frontier.Frontier` (Pareto-pruned), which the writers
embed in the snapshot and the serving layer answers distortion-target
requests from.

:func:`write_variant_set` is the one-shot producer for the serving
half: tune once per named target, write one snapshot per variant, and
publish the ``variants.json`` catalog (``repro.io.variants``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import numpy as np

from repro.core import adaptive_eb, hybrid
from repro.core import metrics as core_metrics
from repro.core.amr import AMRDataset, uniform_resolution
from repro.io import frontier as frt
from repro.io import variants as vrt
from repro.io.parallel import write_multipart
from repro.io.writer import write as write_tacz

__all__ = ["AutoTuner", "TuneResult", "measure_metrics",
           "write_variant_set"]

#: Map the target-grammar metric names onto the adaptive-eb heuristic's
#: seed ratios (the paper tunes power-spectrum and halo-finder runs;
#: uniform-field metrics share the power-spectrum recipe — both amplify
#: coarse-level errors by the upsampling rate; stored-value targets seed
#: from the generic recipe).
_SEED_METRIC = {"ps_error": "power_spectrum", "psnr_u": "power_spectrum",
                "psnr": "generic", "max_abs_error": "generic"}

#: Power-spectrum error is evaluated for k < k_max, the paper's pass
#: criterion range.
PS_K_MAX = 10.0


def measure_metrics(ds: AMRDataset,
                    result: hybrid.AMRCompressionResult) -> dict:
    """All frontier metrics of a decoded snapshot, re-measured from its
    reconstruction: ``psnr`` (over stored values), ``psnr_u`` (over the
    uniform-resolution reconstruction, where coarse-level errors weigh
    ``ratio³``×), ``max_abs_error`` (worst absolute error over stored
    values), and ``ps_error`` (max relative P(k) error for
    ``k < PS_K_MAX`` on the uniform field)."""
    max_err = 0.0
    for lvl, lres in zip(ds.levels, result.levels):
        if lvl.mask.any():
            err = np.abs(lres.recon[lvl.mask]
                         - lvl.data[lvl.mask]).max()
            max_err = max(max_err, float(err))
    orig_u = uniform_resolution(ds)
    recon_u = core_metrics.reconstruct_uniform(ds, result)
    ps = core_metrics.power_spectrum_error(orig_u, recon_u, k_max=PS_K_MAX)
    return {"psnr": float(core_metrics.amr_psnr(ds, result)),
            "psnr_u": float(core_metrics.psnr(orig_u, recon_u)),
            "max_abs_error": max_err,
            "ps_error": float(ps.max()) if ps.size else 0.0}


@dataclass
class TuneResult:
    """Outcome of one :meth:`AutoTuner.tune` run."""

    target: frt.Target                  # the distortion target tuned for
    ebs: tuple[float, ...]              # chosen per-level bounds
    bits: int                           # encoded bits at the chosen ebs
    metrics: dict                       # measured metrics at the chosen ebs
    frontier: frt.Frontier              # every probed point, Pareto-pruned
    result: hybrid.AMRCompressionResult  # compressed at the chosen ebs
    evaluations: int                    # distinct eb vectors measured
    compressions: int                   # level compressions actually run


class AutoTuner:
    """Searches per-level error bounds for minimum bits at a target.

    One tuner instance amortizes its memo tables across :meth:`tune`
    calls — :func:`write_variant_set` tunes several targets against the
    same dataset through one tuner.

    :param ds: the AMR dataset to tune against.
    :param base_eb: the seed vector's finest-level absolute bound
        (default: ``1e-3`` of the finest level's value range).
    :param factor: ladder step — each candidate eb is ``factor×`` its
        neighbor (log-spaced grid).
    :param steps_down: ladder rungs tighter than the seed per level.
    :param steps_up: ladder rungs looser than the seed per level.
    :param compress_kwargs: forwarded to ``hybrid.compress_level``
        (``algorithm``, ``she``, ``strategy``, ``entropy_engine``, ...).
    """

    def __init__(self, ds: AMRDataset, *, base_eb: float | None = None,
                 factor: float = 2.0, steps_down: int = 6,
                 steps_up: int = 6, unit: int = 8, **compress_kwargs):
        self.ds = ds
        if base_eb is None:
            fin = ds.levels[0].data
            base_eb = 1e-3 * float(fin.max() - fin.min())
        self.base_eb = float(base_eb)
        if factor <= 1.0:
            raise ValueError("ladder factor must be > 1")
        self.factor = float(factor)
        self.steps_down = int(steps_down)
        self.steps_up = int(steps_up)
        self.unit = int(unit)
        self.compress_kwargs = dict(compress_kwargs)
        # memo layers: (level, eb) -> LevelResult, ebs-tuple -> metrics
        self._level_memo: dict[tuple[int, float], hybrid.LevelResult] = {}
        self._metric_memo: dict[tuple[float, ...], dict] = {}
        self.compressions = 0

    # ----------------------------- plumbing --------------------------------

    def _ladder(self, seed_eb: float) -> list[float]:
        """Log-spaced candidate bounds for one level, tightest first."""
        return [seed_eb * self.factor ** k
                for k in range(-self.steps_down, self.steps_up + 1)]

    def _compress_level(self, li: int, eb: float) -> hybrid.LevelResult:
        key = (li, float(eb))
        if key not in self._level_memo:
            lvl = self.ds.levels[li]
            lvl_unit = max(2, self.unit // lvl.ratio)
            self._level_memo[key] = hybrid.compress_level(
                lvl.data, lvl.mask, eb=float(eb), unit=lvl_unit,
                ratio=lvl.ratio, keep_artifacts=True,
                **self.compress_kwargs)
            self.compressions += 1
        return self._level_memo[key]

    def result_at(self, ebs) -> hybrid.AMRCompressionResult:
        """The (memoized) compression result at a per-level eb vector."""
        levels = [self._compress_level(li, eb) for li, eb in enumerate(ebs)]
        algo = self.compress_kwargs.get("algorithm", "lor_reg")
        she = self.compress_kwargs.get("she", True)
        name = "tac+" if (she and algo == "lor_reg") else "tac"
        return hybrid.AMRCompressionResult(levels=levels,
                                           method=f"{name}/{algo}")

    def evaluate(self, ebs) -> tuple[int, dict]:
        """(total bits, measured metrics) at a per-level eb vector."""
        ebs = tuple(float(e) for e in ebs)
        res = self.result_at(ebs)
        if ebs not in self._metric_memo:
            self._metric_memo[ebs] = measure_metrics(self.ds, res)
        return res.total_bits, self._metric_memo[ebs]

    # ------------------------------- search --------------------------------

    def tune(self, target: frt.Target | str, *,
             max_passes: int = 4) -> TuneResult:
        """Coordinate descent for the fewest bits meeting ``target``.

        The search seeds at the ``adaptive_eb`` heuristic vector,
        tightens uniformly until the target holds (the ladder's tight
        end bounds the search), then runs per-level loosening passes:
        each pass walks every level's bound up its ladder as far as the
        target keeps holding (looser bound → fewer bits), repeating
        until a full pass changes nothing or ``max_passes`` is hit.

        :raises repro.io.frontier.TargetUnsatisfiable: when even the
            tightest grid corner misses the target.
        """
        if isinstance(target, str):
            target = frt.parse_target(target)
        n = self.ds.n_levels
        seed = adaptive_eb.level_error_bounds(
            self.base_eb, n,
            metric=_SEED_METRIC.get(target.metric, "generic"))
        ladders = [self._ladder(e) for e in seed]
        pos = [self.steps_down] * n          # start at the seed rung
        probed: dict[tuple[int, ...], tuple[int, dict]] = {}

        def measure(p) -> tuple[int, dict]:
            key = tuple(p)
            if key not in probed:
                probed[key] = self.evaluate(
                    [ladders[li][k] for li, k in enumerate(key)])
            return probed[key]

        bits, mets = measure(pos)
        # phase 1: tighten uniformly until the target holds
        while not target.satisfies(mets) and any(k > 0 for k in pos):
            pos = [max(0, k - 1) for k in pos]
            bits, mets = measure(pos)
        if not target.satisfies(mets):
            raise frt.TargetUnsatisfiable(target, mets.get(target.metric))
        # phase 2: per-level loosening passes (coordinate descent)
        for _ in range(max_passes):
            changed = False
            for li in range(n):
                while pos[li] + 1 < len(ladders[li]):
                    trial = list(pos)
                    trial[li] += 1
                    tbits, tmets = measure(trial)
                    if not (target.satisfies(tmets) and tbits <= bits):
                        break
                    pos, bits, mets = trial, tbits, tmets
                    changed = True
            if not changed:
                break

        chosen = tuple(ladders[li][k] for li, k in enumerate(pos))
        frontier = self._build_frontier(target.metric, ladders, probed,
                                        tuple(pos))
        return TuneResult(target=target, ebs=chosen, bits=bits,
                          metrics=dict(mets), frontier=frontier,
                          result=self.result_at(chosen),
                          evaluations=len(probed),
                          compressions=self.compressions)

    def _build_frontier(self, metric: str, ladders, probed,
                        chosen: tuple[int, ...]) -> frt.Frontier:
        """Pareto-prune the probed points on (bits, metric) and keep the
        chosen point's index as the frontier default."""
        higher = frt.HIGHER_IS_BETTER.get(metric, False)
        pts = []
        for key, (bits, mets) in probed.items():
            ebs = tuple(ladders[li][k] for li, k in enumerate(key))
            pts.append((key, frt.FrontierPoint(ebs=ebs, bits=bits,
                                               metrics=dict(mets))))

        def dominated(a: frt.FrontierPoint) -> bool:
            va = a.metrics[metric]
            for _, b in pts:
                if b is a:
                    continue
                vb = b.metrics[metric]
                better = vb >= va if higher else vb <= va
                if b.bits <= a.bits and better and (
                        b.bits < a.bits
                        or (vb > va if higher else vb < va)):
                    return True
            return False

        kept = [(key, p) for key, p in pts
                if key == chosen or not dominated(p)]
        kept.sort(key=lambda kp: kp[1].bits)
        default = next(i for i, (key, _) in enumerate(kept)
                       if key == chosen)
        return frt.Frontier(metric=metric,
                            points=[p for _, p in kept], default=default)


def write_variant_set(path, ds: AMRDataset, targets: dict, *,
                      default: str | None = None, parts: int | None = None,
                      tuner: AutoTuner | None = None,
                      payload_codec: str = "auto",
                      **tuner_kwargs) -> str:
    """Tune and write one snapshot per named distortion target, bound by
    a ``variants.json`` catalog (the serving layer's variant set).

    :param path: variant-set directory (created if missing).
    :param targets: ``{variant name: target spec}``, e.g.
        ``{"hi": "psnr>=70", "lo": "psnr>=50"}``.
    :param default: variant served when a request names no target
        (default: the first ``targets`` key).
    :param parts: write each variant multi-part with this part count
        (default: single-file ``.tacz`` per variant).
    :param tuner: a prepared :class:`AutoTuner` to reuse (its memo
        carries across targets); default builds one from
        ``tuner_kwargs``.
    :returns: the variant-set directory path.
    :raises repro.io.frontier.TargetUnsatisfiable: if any target is out
        of the tuner's grid reach.
    """
    if not targets:
        raise ValueError("need at least one named target")
    names = list(targets)
    if default is None:
        default = names[0]
    if default not in targets:
        raise ValueError(f"default variant {default!r} not in targets")
    if tuner is None:
        tuner = AutoTuner(ds, **tuner_kwargs)
    path = os.fspath(path)
    os.makedirs(path, exist_ok=True)
    entries = []
    for name in names:
        tr = tuner.tune(targets[name])
        fname = f"{name}.taczd" if parts else f"{name}.tacz"
        dst = os.path.join(path, fname)
        if parts:
            write_multipart(dst, tr.result, parts=parts,
                            payload_codec=payload_codec,
                            frontier=tr.frontier)
        else:
            write_tacz(dst, tr.result, payload_codec=payload_codec,
                       frontier=tr.frontier)
        entries.append({"name": name, "file": fname,
                        "target": str(tr.target),
                        "ebs": [float(e) for e in tr.ebs],
                        "bits": int(tr.bits),
                        "metrics": {k: float(v)
                                    for k, v in sorted(tr.metrics.items())}})
    vrt.write_catalog(path, {"default": default, "variants": entries})
    return path
