"""Pallas TPU kernel: per-group symmetric int8 quantize / dequantize.

This is the framework-plane reuse of the paper's quantization stage
(DESIGN.md §2 Plane B): error-bounded gradient compression on the slow
inter-pod links and the compressed-KV-cache option both transport int8
codes + per-group scales.  The group structure mirrors TAC's unit blocks —
scales are the per-block "error bound", adapted to the local value range.

Layout: (rows, d) arrays, groups along the trailing dim (d % group == 0),
group default 128 = one VPU lane row.  The quant kernel emits codes and
scales in one pass; dequant is a fused multiply.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["group_quant", "group_dequant"]


def _quant_kernel(x_ref, q_ref, s_ref, *, group: int):
    x = x_ref[...]
    rows, d = x.shape
    g = x.reshape(rows, d // group, group)
    amax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(g / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, d).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref, *, group: int):
    q = q_ref[...]
    rows, d = q.shape
    g = q.reshape(rows, d // group, group).astype(jnp.float32)
    x_ref[...] = (g * s_ref[...][..., None]).reshape(rows, d)


@functools.partial(jax.jit, static_argnames=("group", "row_tile", "interpret"))
def group_quant(x: jnp.ndarray, *, group: int = 128, row_tile: int = 256,
                interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    n, d = x.shape
    row_tile = min(row_tile, n)
    if n % row_tile or d % group:
        raise ValueError(f"shape {x.shape} needs n%{row_tile}==0, d%{group}==0")
    grid = (n // row_tile,)
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, group=group),
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
                   pl.BlockSpec((row_tile, d // group), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((n, d // group), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return q, s


@functools.partial(jax.jit, static_argnames=("group", "row_tile", "interpret"))
def group_dequant(q: jnp.ndarray, scale: jnp.ndarray, *, group: int = 128,
                  row_tile: int = 256, interpret: bool = True) -> jnp.ndarray:
    n, d = q.shape
    row_tile = min(row_tile, n)
    if n % row_tile or d % group:
        raise ValueError(f"shape {q.shape} needs n%{row_tile}==0, d%{group}==0")
    grid = (n // row_tile,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
                  pl.BlockSpec((row_tile, d // group), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, scale.astype(jnp.float32))
