"""Pallas TPU kernels for the compression hot spots (validated via
interpret=True on CPU; TPU v5e is the target).

  lorenzo3d  fused prequant + 3D Lorenzo delta and its inverse (VPU)
  hist       quant-code histogram as one-hot MXU matmul
  huffdec    batched canonical-Huffman windows + decode walk
  qdq        per-group int8 quant/dequant (grad compression, KV cache)

ops.py — jit'd public wrappers;  ref.py — pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
