"""Pallas TPU kernel: quant-code histogram as a one-hot MXU matmul.

The Huffman stage (paper §II-A step 3) needs the symbol frequency table.
TPUs have no fast scatter-add; for the small quantization-code alphabets SZ
produces (codes cluster tightly around 0), the fastest TPU formulation is

    counts = ones(1, chunk) @ one_hot(codes, n_bins)

— an MXU matmul per VMEM chunk, accumulated across sequential grid steps
into one output block (DESIGN.md §3).  Codes outside [0, n_bins) fall into
the escape bin ``n_bins − 1`` (SZ's outlier path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hist_kernel", "hist"]


def hist_kernel(codes_ref, out_ref, *, n_bins: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = codes_ref[...].reshape(-1)
    c = jnp.clip(c, 0, n_bins - 1)
    # one_hot (chunk, n_bins) in f32; contraction over chunk on the MXU
    oh = (c[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
          ).astype(jnp.float32)
    counts = jnp.sum(oh, axis=0)  # lowered to a (1,chunk)x(chunk,bins) matmul
    out_ref[...] += counts.astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("n_bins", "chunk", "interpret"))
def hist(codes: jnp.ndarray, *, n_bins: int = 1024, chunk: int = 8192,
         interpret: bool = True) -> jnp.ndarray:
    """Histogram of int codes clipped to [0, n_bins)."""
    flat = codes.reshape(-1)
    pad = (-flat.shape[0]) % chunk
    if pad:
        # pad with the escape bin, then subtract the padding count
        flat = jnp.concatenate([flat, jnp.full((pad,), n_bins - 1, flat.dtype)])
    n_chunks = flat.shape[0] // chunk
    out = pl.pallas_call(
        functools.partial(hist_kernel, n_bins=n_bins),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins), jnp.int32),
        interpret=interpret,
    )(flat)[0]
    if pad:
        out = out.at[n_bins - 1].add(-pad)
    return out
