"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition* the kernel must match bit-for-bit
(integer codes) or to float tolerance (dequantized values).  Tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle with the kernels in
``interpret=True`` mode (this container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lorenzo3d_codes_ref", "lorenzo3d_recon_ref",
           "lorenzo3d_codes_batched_ref", "lorenzo3d_recon_batched_ref",
           "hist_ref", "group_quant_ref", "group_dequant_ref"]


def _tile_view(a: jnp.ndarray, tile: tuple[int, int, int]):
    gx, gy, gz = (s // t for s, t in zip(a.shape, tile))
    tx, ty, tz = tile
    return a.reshape(gx, tx, gy, ty, gz, tz), (gx, gy, gz)


def lorenzo3d_codes_ref(x: jnp.ndarray, eb: float,
                        tile: tuple[int, int, int] | None = None) -> jnp.ndarray:
    """Fused prequant + *tile-local* 3D Lorenzo delta (zero halo per tile).

    ``q = round(x · (1/2eb))`` (int32) — the same multiply-by-reciprocal
    form the kernel uses (an f32 divide would round differently at ties) —
    then the 3D Lorenzo delta: the alternating first difference along each
    axis with a zero halo at every tile's low faces, exactly the per-brick
    independence of ``repro.core.sz.compress_lor_reg``'s Lorenzo branch
    (DESIGN.md §3).  ``tile=None`` means one tile = the whole array.
    """
    q = jnp.rint(x * jnp.float32(1.0 / (2.0 * eb))).astype(jnp.int32)
    tile = tuple(min(t, s) for t, s in zip(tile or x.shape, x.shape))
    v, _ = _tile_view(q, tile)
    c = v
    for ax in (1, 3, 5):
        c = jnp.diff(c, axis=ax, prepend=jnp.zeros_like(
            jnp.take(c, jnp.array([0]), axis=ax)))
    return c.reshape(x.shape)


def lorenzo3d_recon_ref(codes: jnp.ndarray, eb: float,
                        tile: tuple[int, int, int] | None = None) -> jnp.ndarray:
    """Inverse: per-tile 3D inclusive prefix-sum, then dequantize."""
    tile = tuple(min(t, s) for t, s in zip(tile or codes.shape, codes.shape))
    v, _ = _tile_view(codes.astype(jnp.int32), tile)
    q = v
    for ax in (1, 3, 5):
        q = jnp.cumsum(q, axis=ax)
    return (q.astype(jnp.float32) * (2.0 * eb)).reshape(codes.shape)


def _batched_tile_view(a: jnp.ndarray, tile: tuple[int, int, int]):
    n = a.shape[0]
    gx, gy, gz = (s // t for s, t in zip(a.shape[1:], tile))
    tx, ty, tz = tile
    return a.reshape(n, gx, tx, gy, ty, gz, tz)


def lorenzo3d_codes_batched_ref(x: jnp.ndarray, eb: float,
                                tile: tuple[int, int, int] | None = None
                                ) -> jnp.ndarray:
    """Batched oracle: the 3D tile-local semantics applied per brick of a
    (N, X, Y, Z) stack — no value may cross the batch axis."""
    q = jnp.rint(x * jnp.float32(1.0 / (2.0 * eb))).astype(jnp.int32)
    tile = tuple(min(t, s) for t, s in zip(tile or x.shape[1:], x.shape[1:]))
    c = _batched_tile_view(q, tile)
    for ax in (2, 4, 6):
        c = jnp.diff(c, axis=ax, prepend=jnp.zeros_like(
            jnp.take(c, jnp.array([0]), axis=ax)))
    return c.reshape(x.shape)


def lorenzo3d_recon_batched_ref(codes: jnp.ndarray, eb: float,
                                tile: tuple[int, int, int] | None = None
                                ) -> jnp.ndarray:
    """Inverse batched oracle: per-(brick, tile) 3D inclusive prefix-sum."""
    tile = tuple(min(t, s)
                 for t, s in zip(tile or codes.shape[1:], codes.shape[1:]))
    q = _batched_tile_view(codes.astype(jnp.int32), tile)
    for ax in (2, 4, 6):
        q = jnp.cumsum(q, axis=ax)
    return (q.astype(jnp.float32) * (2.0 * eb)).reshape(codes.shape)


def hist_ref(codes: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """Histogram of codes clipped to [0, n_bins): the Huffman frequency
    table the host tree-builder consumes (codes are offset to be ≥ 0 by the
    caller; out-of-range codes count into the escape bin n_bins−1)."""
    c = jnp.clip(codes.reshape(-1), 0, n_bins - 1)
    return jnp.zeros((n_bins,), jnp.int32).at[c].add(1)


def group_quant_ref(x: jnp.ndarray, group: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-group symmetric int8 quantization.

    ``x``: (n, d) with d % group == 0.  Returns (int8 codes (n, d),
    float32 scales (n, d//group)).  scale = max|x| / 127 per group (zero
    groups get scale 1 to stay exact).
    """
    n, d = x.shape
    g = x.reshape(n, d // group, group)
    amax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(g / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(n, d), scale.astype(jnp.float32)


def group_dequant_ref(q: jnp.ndarray, scale: jnp.ndarray, group: int) -> jnp.ndarray:
    n, d = q.shape
    g = q.reshape(n, d // group, group).astype(jnp.float32)
    return (g * scale[..., None]).reshape(n, d)
