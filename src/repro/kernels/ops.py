"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True when no TPU is attached (this container is
CPU-only; the kernels target TPU v5e), and to False on real TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import hist as _hist
from . import huffdec as _huffdec
from . import lorenzo3d as _lorenzo3d
from . import qdq as _qdq

__all__ = ["lorenzo3d_codes", "lorenzo3d_recon",
           "lorenzo3d_codes_batched", "lorenzo3d_recon_batched", "hist",
           "huffdec_windows", "group_quant", "group_dequant",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lorenzo3d_codes(x, *, eb: float, tile=(8, 128, 128),
                    interpret: bool | None = None):
    return _lorenzo3d.lorenzo3d_codes(
        x, eb=eb, tile=tile,
        interpret=default_interpret() if interpret is None else interpret)


def lorenzo3d_recon(codes, *, eb: float, tile=(8, 128, 128),
                    interpret: bool | None = None):
    return _lorenzo3d.lorenzo3d_recon(
        codes, eb=eb, tile=tile,
        interpret=default_interpret() if interpret is None else interpret)


def lorenzo3d_codes_batched(x, *, eb: float, tile=(8, 128, 128),
                            interpret: bool | None = None):
    """Batched (N, X, Y, Z) fused prequant+Lorenzo — the SHE hot path."""
    return _lorenzo3d.lorenzo3d_codes_batched(
        x, eb=eb, tile=tile,
        interpret=default_interpret() if interpret is None else interpret)


def lorenzo3d_recon_batched(codes, *, eb: float, tile=(8, 128, 128),
                            interpret: bool | None = None):
    return _lorenzo3d.lorenzo3d_recon_batched(
        codes, eb=eb, tile=tile,
        interpret=default_interpret() if interpret is None else interpret)


def hist(codes, *, n_bins: int = 1024, chunk: int = 8192,
         interpret: bool | None = None):
    return _hist.hist(
        codes, n_bins=n_bins, chunk=chunk,
        interpret=default_interpret() if interpret is None else interpret)


def huffdec_windows(bits, *, maxlen: int, width: int, row_tile: int = 8,
                    interpret: bool | None = None):
    """Stacked maxlen-bit windows for batched canonical-Huffman decode."""
    return _huffdec.huffdec_windows(
        bits, maxlen=maxlen, width=width, row_tile=row_tile,
        interpret=default_interpret() if interpret is None else interpret)


def group_quant(x, *, group: int = 128, row_tile: int = 256,
                interpret: bool | None = None):
    return _qdq.group_quant(
        x, group=group, row_tile=row_tile,
        interpret=default_interpret() if interpret is None else interpret)


def group_dequant(q, scale, *, group: int = 128, row_tile: int = 256,
                  interpret: bool | None = None):
    return _qdq.group_dequant(
        q, scale, group=group, row_tile=row_tile,
        interpret=default_interpret() if interpret is None else interpret)
