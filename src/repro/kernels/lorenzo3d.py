"""Pallas TPU kernel: fused prequant + 3D Lorenzo delta (and its inverse).

This is the compression hot loop of the SZ pipeline (paper §II-A steps 1–2)
in its dual-quantization form (DESIGN.md §3): per element
``q = round(x·(1/2eb))`` followed by the integer 3D Lorenzo delta.  On the
TPU this is pure VPU element-wise work; the whole tile lives in VMEM.

Tiling contract: the kernel's grid tiles are *bricks* — each tile computes
a self-contained zero-halo Lorenzo, which is exactly the per-sub-block
independence the SHE pipeline requires (each partition sub-block predicted
on its own, paper Alg. 4 line 4).  Tile shape must therefore match the
brick shape the caller compresses; the default (8, 128, 128) fits
8·128·128·4 B · 3 buffers ≈ 1.6 MB of VMEM.

The inverse kernel reconstructs ``x̂ = 2eb · cumsum³(codes)`` — exact in
integers, so the error bound is the prequant bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lorenzo3d_codes_kernel", "lorenzo3d_recon_kernel",
           "lorenzo3d_codes", "lorenzo3d_recon",
           "lorenzo3d_codes_batched", "lorenzo3d_recon_batched"]


def lorenzo3d_codes_kernel(x_ref, codes_ref, *, inv_2eb: float):
    """One VMEM tile: prequant then zero-halo 3D Lorenzo delta."""
    x = x_ref[...]
    q = jnp.rint(x * inv_2eb).astype(jnp.int32)
    # alternating first differences with a zero halo on the low faces;
    # implemented as shift-and-subtract (VPU-only, no gathers)
    c = q
    for ax in range(3):
        shifted = jnp.pad(c, [(1, 0) if a == ax else (0, 0)
                              for a in range(3)])[
            tuple(slice(0, -1) if a == ax else slice(None) for a in range(3))]
        c = c - shifted
    codes_ref[...] = c


def lorenzo3d_recon_kernel(codes_ref, x_ref, *, two_eb: float):
    """Inverse tile: integer 3D inclusive scan, then dequantize."""
    q = codes_ref[...].astype(jnp.int32)
    for ax in range(3):
        q = jnp.cumsum(q, axis=ax)
    x_ref[...] = q.astype(jnp.float32) * two_eb


def _grid_and_specs(shape, tile):
    tile = tuple(min(t, s) for t, s in zip(tile, shape))
    if any(s % t for s, t in zip(shape, tile)):
        raise ValueError(f"shape {shape} not divisible by tile {tile}")
    grid = tuple(s // t for s, t in zip(shape, tile))
    spec = pl.BlockSpec(tile, lambda i, j, k: (i, j, k))
    return grid, spec, tile


@functools.partial(jax.jit, static_argnames=("eb", "tile", "interpret"))
def lorenzo3d_codes(x: jnp.ndarray, *, eb: float,
                    tile: tuple[int, int, int] = (8, 128, 128),
                    interpret: bool = True) -> jnp.ndarray:
    """Fused prequant + 3D Lorenzo codes for a 3D array (tile = brick)."""
    grid, spec, tile = _grid_and_specs(x.shape, tile)
    kernel = functools.partial(lorenzo3d_codes_kernel,
                               inv_2eb=float(1.0 / (2.0 * eb)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("eb", "tile", "interpret"))
def lorenzo3d_recon(codes: jnp.ndarray, *, eb: float,
                    tile: tuple[int, int, int] = (8, 128, 128),
                    interpret: bool = True) -> jnp.ndarray:
    grid, spec, tile = _grid_and_specs(codes.shape, tile)
    kernel = functools.partial(lorenzo3d_recon_kernel,
                               two_eb=float(2.0 * eb))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(codes.shape, jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32))


# ------------------------- batched (SHE) variants ---------------------------
#
# SHE stacks same-shape sub-blocks into a (N, X, Y, Z) batch and compresses
# the whole batch in one launch.  The grid grows a leading batch axis with
# block size 1 and each spatial tile keeps its own zero halo, so every
# (brick, tile) cell is predicted fully independently — the per-sub-block
# independence of Alg. 4 line 4 is preserved *by the tiling contract*, not
# by the kernel body (which is the 3D body on a leading-singleton block).


def lorenzo3d_codes_batched_kernel(x_ref, codes_ref, *, inv_2eb: float):
    """One (1, tx, ty, tz) VMEM tile: prequant + zero-halo Lorenzo delta."""
    x = x_ref[...]
    c = jnp.rint(x * inv_2eb).astype(jnp.int32)
    for ax in (1, 2, 3):
        shifted = jnp.pad(c, [(1, 0) if a == ax else (0, 0)
                              for a in range(4)])[
            tuple(slice(0, -1) if a == ax else slice(None) for a in range(4))]
        c = c - shifted
    codes_ref[...] = c


def lorenzo3d_recon_batched_kernel(codes_ref, x_ref, *, two_eb: float):
    q = codes_ref[...].astype(jnp.int32)
    for ax in (1, 2, 3):
        q = jnp.cumsum(q, axis=ax)
    x_ref[...] = q.astype(jnp.float32) * two_eb


def _batched_grid_and_specs(shape, tile):
    if len(shape) != 4:
        raise ValueError(f"expected (N, X, Y, Z) batch, got shape {shape}")
    tile = (1,) + tuple(min(t, s) for t, s in zip(tile, shape[1:]))
    if any(s % t for s, t in zip(shape, tile)):
        raise ValueError(f"shape {shape} not divisible by tile {tile}")
    grid = tuple(s // t for s, t in zip(shape, tile))
    spec = pl.BlockSpec(tile, lambda n, i, j, k: (n, i, j, k))
    return grid, spec, tile


@functools.partial(jax.jit, static_argnames=("eb", "tile", "interpret"))
def lorenzo3d_codes_batched(x: jnp.ndarray, *, eb: float,
                            tile: tuple[int, int, int] = (8, 128, 128),
                            interpret: bool = True) -> jnp.ndarray:
    """Fused prequant + Lorenzo codes for a (N, X, Y, Z) batch of bricks."""
    grid, spec, tile = _batched_grid_and_specs(x.shape, tile)
    kernel = functools.partial(lorenzo3d_codes_batched_kernel,
                               inv_2eb=float(1.0 / (2.0 * eb)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("eb", "tile", "interpret"))
def lorenzo3d_recon_batched(codes: jnp.ndarray, *, eb: float,
                            tile: tuple[int, int, int] = (8, 128, 128),
                            interpret: bool = True) -> jnp.ndarray:
    grid, spec, tile = _batched_grid_and_specs(codes.shape, tile)
    kernel = functools.partial(lorenzo3d_recon_batched_kernel,
                               two_eb=float(2.0 * eb))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(codes.shape, jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32))
