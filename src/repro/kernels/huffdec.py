"""Pallas TPU kernel + jitted scan for batched canonical-Huffman decode.

The batched decoder (``repro.core.entropy``) splits into two device
stages over a stack of payload bitstreams sharing one codebook:

  windows   ``W[a, t]`` = the ``maxlen``-bit window of stream ``a``
            starting at bit ``t`` — ``maxlen`` shift-or passes over the
            stacked 0/1 bit matrix (elementwise VPU work, gridded over
            row tiles);
  walk      a ``lax.scan`` advancing every stream in lockstep: gather
            each live stream's current window, one ``searchsorted`` over
            the left-justified canonical interval uppers yields the code
            length, then a table gather yields the codebook row index.

Windows are int32, so ``maxlen`` must stay ≤ 30 (the host engine guards
and falls back to the vectorized-numpy path).  The walk returns codebook
*row indices*, not symbol values — symbols are int64 and stay on the
host.  Error flags replicate the serial oracle exactly: 1 = truncated
(stream ends mid-codeword, or the codeword-free gap is hit with fewer
than ``maxlen + 1`` bits left), 2 = corrupt (gap hit with enough bits
left for the oracle's ``l > maxlen`` check to fire).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["windows_kernel", "huffdec_windows", "decode_walk"]

_LANE = 128


def windows_kernel(bits_ref, out_ref, *, maxlen: int):
    b = bits_ref[...]
    out_w = out_ref.shape[1]
    w = jnp.zeros(out_ref.shape, jnp.int32)
    for j in range(maxlen):
        w = (w << 1) | jax.lax.dynamic_slice_in_dim(b, j, out_w, axis=1)
    out_ref[...] = w


@functools.partial(jax.jit,
                   static_argnames=("maxlen", "width", "row_tile",
                                    "interpret"))
def huffdec_windows(bits: jnp.ndarray, *, maxlen: int, width: int,
                    row_tile: int = 8, interpret: bool = True
                    ) -> jnp.ndarray:
    """All ``maxlen``-bit windows of a stacked 0/1 bit matrix.

    ``bits`` is (A, ≥ width + maxlen - 1) uint8/int32 with zeros past
    each row's real bits; returns (A, width) int32 windows.
    """
    a, _ = bits.shape
    out_w = -(-width // _LANE) * _LANE
    in_w = -(-(out_w + maxlen) // _LANE) * _LANE
    a_pad = -(-a // row_tile) * row_tile
    b = jnp.zeros((a_pad, in_w), jnp.int32)
    b = b.at[:a, :bits.shape[1]].set(bits.astype(jnp.int32))
    out = pl.pallas_call(
        functools.partial(windows_kernel, maxlen=maxlen),
        grid=(a_pad // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, in_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, out_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a_pad, out_w), jnp.int32),
        interpret=interpret,
    )(b)
    return out[:a, :width]


@functools.partial(jax.jit, static_argnames=("maxlen", "steps"))
def decode_walk(wm: jnp.ndarray, nbits: jnp.ndarray, ncodes: jnp.ndarray,
                uppers: jnp.ndarray, lens_tab: jnp.ndarray,
                fc_tab: jnp.ndarray, fi_tab: jnp.ndarray, *,
                maxlen: int, steps: int):
    """Lockstep canonical walk over precomputed windows.

    Returns ``(sidx, err)``: (A, steps) int32 codebook row indices (0 in
    dead/error lanes) and the (A,) int32 per-stream error kind.
    """
    a = wm.shape[0]
    n_lens = uppers.shape[0]

    def step(carry, k):
        pos, err = carry
        act = (k < ncodes) & (err == 0)
        w = jnp.take_along_axis(wm, pos[:, None], axis=1)[:, 0]
        ii = jnp.searchsorted(uppers, w, side="right")
        valid = ii < n_lens
        l = lens_tab[jnp.minimum(ii, n_lens - 1)]
        rem = nbits - pos
        ok = act & valid & (l <= rem)
        corrupt = act & ~valid & (rem >= maxlen + 1)
        failed = act & ~ok
        sidx = fi_tab[l] + (w >> (maxlen - l)) - fc_tab[l]
        outk = jnp.where(ok, sidx, 0)
        err = jnp.where(failed, jnp.where(corrupt, 2, 1), err)
        pos = jnp.where(ok, pos + l, pos)
        return (pos, err), outk

    init = (jnp.zeros(a, jnp.int32), jnp.zeros(a, jnp.int32))
    (_, err), outs = jax.lax.scan(step, init, jnp.arange(steps))
    return outs.T, err
