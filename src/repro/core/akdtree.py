"""Adaptive k-D Tree (AKDTree) — paper §III-C, Algorithm 3, Figs. 10/11.

For *medium-density* levels, where OpST's O(N²·d) update cost bites:
recursively split the unit-block grid until every leaf is *empty or full*.

Faithful to the paper's dynamic splitting:

  1. **Pre-split**: while ``max(x,y,z)/min(x,y,z) ≥ 2``, split the largest
     dimension in half (keeps the data 3D instead of flattening it).
  2. **cube → flat → slim rotation**: a *cube* node is split along the axis
     with the maximum child-count difference ``diff_axis`` computed from its
     eight octant counts; the resulting *flat* node reuses four of those
     counts to pick between the two remaining axes; the *slim* node splits
     the single remaining axis; its children are cubes again.  Counting is
     only needed at cube nodes — one count per three tree levels, hence the
     paper's O(N/3 · log N).

Counts are O(1) range sums over a 3D summed-area table of the occupancy
grid (our TPU-era stand-in for the paper's streamed counting; the result
is identical).  Full leaves become :class:`SubBlock`\\ s; same-(sorted-)size
leaves are merged for compression exactly like OpST's output.
"""
from __future__ import annotations

import numpy as np

from .blocks import BlockGrid, SubBlock

__all__ = ["akdtree_partition"]


def _sat(occ: np.ndarray) -> np.ndarray:
    """3D summed-area table with a zero guard layer."""
    s = occ.astype(np.int64)
    for ax in range(3):
        s = np.cumsum(s, axis=ax)
    return np.pad(s, ((1, 0), (1, 0), (1, 0)))


def _count(sat: np.ndarray, lo, hi) -> int:
    """Number of non-empty unit blocks in [lo, hi) — O(1)."""
    x0, y0, z0 = lo
    x1, y1, z1 = hi
    return int(sat[x1, y1, z1] - sat[x0, y1, z1] - sat[x1, y0, z1]
               - sat[x1, y1, z0] + sat[x0, y0, z1] + sat[x0, y1, z0]
               + sat[x1, y0, z0] - sat[x0, y0, z0])


def _split(lo, hi, axis):
    mid = (lo[axis] + hi[axis]) // 2
    hi1 = list(hi); hi1[axis] = mid
    lo2 = list(lo); lo2[axis] = mid
    return (lo, tuple(hi1)), (tuple(lo2), hi)


def akdtree_partition(grid: BlockGrid) -> list[SubBlock]:
    sat = _sat(grid.occ)
    out: list[SubBlock] = []
    # stack items: (lo, hi, pending_axes) — pending_axes tracks the
    # cube→flat→slim rotation (None = cube: recount octants).
    stack = [((0, 0, 0), grid.bshape, None)]
    while stack:
        lo, hi, pending = stack.pop()
        dims = tuple(h - l for l, h in zip(lo, hi))
        if min(dims) == 0:
            continue
        vol = dims[0] * dims[1] * dims[2]
        cnt = _count(sat, lo, hi)
        if cnt == 0:
            continue                      # empty leaf — dropped
        if cnt == vol:
            out.append(SubBlock(origin=lo, bsize=dims))   # full leaf
            continue
        # pre-split of elongated boxes (Eq. 1): keep the data 3D
        mx, mn = max(dims), min(dims)
        if mn > 0 and mx / mn >= 2 and mx > 1:
            axis = int(np.argmax(dims))
            (a, b) = _split(lo, hi, axis)
            stack.append((a[0], a[1], None))
            stack.append((b[0], b[1], None))
            continue
        splittable = [ax for ax in range(3) if dims[ax] > 1]
        if not splittable:
            # 1×1×1 mixed is impossible (cnt==0 or cnt==vol above)
            continue
        if pending is None or not any(dims[ax] > 1 for ax in pending):
            pending = tuple(splittable)   # (re)enter cube state
        cand = [ax for ax in pending if dims[ax] > 1]
        # maxDiff choice over the candidate axes (cube: 3-way from octant
        # counts; flat: 2-way from the reused quadrant counts; slim: forced)
        best_ax, best_diff = cand[0], -1
        for ax in cand:
            (a, b) = _split(lo, hi, ax)
            d = abs(_count(sat, *a) - _count(sat, *b))
            if d > best_diff:
                best_ax, best_diff = ax, d
        remaining = tuple(ax for ax in pending if ax != best_ax)
        (a, b) = _split(lo, hi, best_ax)
        stack.append((a[0], a[1], remaining))
        stack.append((b[0], b[1], remaining))
    return out
