"""Evaluation metrics (paper §IV-B).

Metric 1  compression ratio / bit-rate   — from exact bit accounting.
Metric 2  PSNR                           — over the stored AMR values.
Metric 4  rate-distortion                — eb sweep → (bit-rate, PSNR).
Metric 5  matter power spectrum P(k)     — radially-binned |FFT|² of the
          uniform-resolution field; pass criterion: max relative error
          below a tolerance for k < k_max (paper: 1 %, near-lossless 0.01 %).
Metric 6  halo finder                    — threshold (81.66 × mean mass by
          default, [48]) + 6-connected components + minimum cell count;
          compares mass / cell counts of the largest halos.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
from scipy import ndimage

from .amr import AMRDataset, uniform_resolution
from .hybrid import AMRCompressionResult

__all__ = ["psnr", "amr_psnr", "power_spectrum", "power_spectrum_error",
           "Halo", "halo_finder", "halo_diff", "reconstruct_uniform"]


def psnr(orig: np.ndarray, recon: np.ndarray) -> float:
    orig = np.asarray(orig, dtype=np.float64).ravel()
    recon = np.asarray(recon, dtype=np.float64).ravel()
    rng = float(orig.max() - orig.min())
    mse = float(np.mean((orig - recon) ** 2))
    if mse == 0:
        return float("inf")
    return 20.0 * np.log10(rng) - 10.0 * np.log10(mse)


def amr_psnr(ds: AMRDataset, result: AMRCompressionResult) -> float:
    """PSNR over every *stored* value of the dataset (all levels)."""
    orig = np.concatenate([l.data[l.mask] for l in ds.levels])
    rec = np.concatenate([r.recon[l.mask]
                          for l, r in zip(ds.levels, result.levels)])
    return psnr(orig, rec)


def reconstruct_uniform(ds: AMRDataset, result: AMRCompressionResult) -> np.ndarray:
    """Uniform-resolution reconstruction for post-analysis (Fig. 2 right)."""
    out = np.zeros(ds.finest_shape, dtype=np.float32)
    for lvl, lres in zip(ds.levels, result.levels):
        r = lvl.ratio
        up = np.repeat(np.repeat(np.repeat(lres.recon, r, 0), r, 1), r, 2)
        um = np.repeat(np.repeat(np.repeat(lvl.mask, r, 0), r, 1), r, 2)
        out = np.where(um, up, out)
    return out


# ----------------------------- power spectrum ------------------------------


def power_spectrum(field: np.ndarray, n_bins: int | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Isotropic P(k): radial average of |FFT|² (Metric 5)."""
    field = np.asarray(field, dtype=np.float64)
    n = field.shape[0]
    fk = np.fft.rfftn(field) / field.size
    p3 = np.abs(fk) ** 2
    kx = np.fft.fftfreq(field.shape[0]) * field.shape[0]
    ky = np.fft.fftfreq(field.shape[1]) * field.shape[1]
    kz = np.fft.rfftfreq(field.shape[2]) * field.shape[2]
    kmag = np.sqrt(kx[:, None, None] ** 2 + ky[None, :, None] ** 2
                   + kz[None, None, :] ** 2)
    n_bins = n_bins or n // 2
    bins = np.arange(0.5, n_bins + 0.5)
    which = np.digitize(kmag.ravel(), bins)
    sums = np.bincount(which, weights=p3.ravel(), minlength=n_bins + 1)
    cnts = np.bincount(which, minlength=n_bins + 1)
    k = np.arange(1, n_bins + 1, dtype=np.float64)
    pk = sums[1:n_bins + 1] / np.maximum(cnts[1:n_bins + 1], 1)
    return k, pk


def power_spectrum_error(orig_field: np.ndarray, recon_field: np.ndarray,
                         k_max: float | None = None) -> np.ndarray:
    """Per-bin relative P(k) error |p'/p − 1| for k < k_max (paper: k<10)."""
    k, p = power_spectrum(orig_field)
    _, pr = power_spectrum(recon_field)
    sel = slice(None) if k_max is None else k < k_max
    return np.abs(pr[sel] / np.maximum(p[sel], 1e-300) - 1.0)


# ------------------------------- halo finder --------------------------------


@dataclass
class Halo:
    mass: float
    n_cells: int
    position: tuple[float, float, float]


def halo_finder(field: np.ndarray, *, threshold_factor: float = 81.66,
                min_cells: int = 8) -> list[Halo]:
    """FoF-like over-density finder (Metric 6, [48]).

    Candidate cells have value > threshold_factor × mean; candidates are
    grouped by 6-connectivity; groups below ``min_cells`` are dropped.
    Returns halos sorted by decreasing mass.
    """
    field = np.asarray(field, dtype=np.float64)
    thr = threshold_factor * field.mean()
    cand = field > thr
    structure = ndimage.generate_binary_structure(3, 1)  # 6-connectivity
    labels, n = ndimage.label(cand, structure=structure)
    halos: list[Halo] = []
    if n == 0:
        return halos
    counts = np.bincount(labels.ravel())
    masses = np.bincount(labels.ravel(), weights=field.ravel())
    coms = ndimage.center_of_mass(field, labels, index=range(1, n + 1))
    for i in range(1, n + 1):
        if counts[i] >= min_cells:
            halos.append(Halo(mass=float(masses[i]), n_cells=int(counts[i]),
                              position=tuple(float(c) for c in coms[i - 1])))
    halos.sort(key=lambda h: -h.mass)
    return halos


def halo_diff(orig: list[Halo], recon: list[Halo], top: int = 3
              ) -> tuple[float, float]:
    """(avg relative mass diff, avg relative cell-count diff) over the
    ``top`` largest original halos matched by position (Table II)."""
    if not orig:
        return 0.0, 0.0
    mass_d, cell_d, n = 0.0, 0.0, 0
    for h in orig[:top]:
        if not recon:
            mass_d += 1.0
            cell_d += 1.0
            n += 1
            continue
        # match to the nearest reconstructed halo
        d = [sum((a - b) ** 2 for a, b in zip(h.position, r.position))
             for r in recon]
        m = recon[int(np.argmin(d))]
        mass_d += abs(m.mass - h.mass) / abs(h.mass)
        cell_d += abs(m.n_cells - h.n_cells) / max(h.n_cells, 1)
        n += 1
    return mass_d / n, cell_d / n
