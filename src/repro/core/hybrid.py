"""Hybrid level-wise AMR compression — the TAC / TAC+ drivers (paper §III-E).

Per AMR level, pick the pre-process strategy from the level's unit-block
density:

  * **Lor/Reg + SHE (= TAC+)**: OpST+ below T0 = 50 %, AKDTree+ above.
    (GSP is dominated once SHE removes the partitioning penalty, Fig. 12.)
  * **Interp (= TAC)**:  OpST < T1 = 50 % ≤ AKDTree < T2 = 85 % ≤ GSP.
  * **Lor/Reg without SHE (= TAC)**: same thresholds as Interp.

The strategy output feeds the matching SZ path:

  * GSP        → padded full grid → one global compression.
  * OpST/AKD   → sub-blocks; with SHE: per-block Lor/Reg prediction + one
    shared Huffman tree; without SHE: same-size blocks merged into 4D
    arrays, each compressed globally (prediction crosses block boundaries —
    exactly the artifact the paper's Figs. 15/16 show SHE removing).

Level reconstructions are scattered back; empty regions are exact zeros.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .. import obs
from ..obs import metrics as obsm
from . import huffman
from .akdtree import akdtree_partition
from .amr import AMRDataset
from .blocks import BlockGrid, SubBlock, make_block_grid, extract_subblock
from .gsp import gsp_meta_bits, gsp_pad, gsp_unpad
from .opst import opst_partition
from .she import she_encode
from .sz import SZResult, compress_interp, compress_lorenzo, compress_lor_reg

__all__ = ["LevelArtifacts", "LevelResult", "AMRCompressionResult",
           "compress_level", "compress_amr", "choose_strategy",
           "partition_level", "T0", "T1", "T2"]

T0 = 0.50   # Lor/Reg+SHE: OpST+ vs AKDTree+ (Fig. 12 / Fig. 14)
T1 = 0.50   # Interp: OpST vs AKDTree (Fig. 13)
T2 = 0.85   # Interp: AKDTree vs GSP (Fig. 13)


@dataclass
class LevelArtifacts:
    """Serialization-grade level state the aggregate accounting drops.

    ``LevelResult`` carries bit totals and the reconstructed grid; the TACZ
    container (``repro.io``) additionally needs the raw code streams, the
    sub-block placement, and the shared codebook to emit real byte streams
    and decode them back.  Kept by default (the arrays referenced here were
    already materialized by the compressor — this holds references, it does
    not copy).
    """

    mask: np.ndarray              # validity mask at the level's orig shape
    orig_shape: tuple[int, ...]   # level shape before unit-block padding
    grid_shape: tuple[int, ...]   # padded block-grid data shape
    unit: int                     # unit-block edge (cells)
    sz_block: int                 # Lor/Reg regression block edge
    subblocks: list[SubBlock]     # placement (empty for gsp/global levels)
    results: list[SZResult]       # per-sub-block codes/branch/betas
    codebook: huffman.Codebook | None  # shared Huffman codebook (SHE levels)


@dataclass
class LevelResult:
    strategy: str
    algorithm: str
    she: bool
    payload_bits: int
    codebook_bits: int
    meta_bits: int
    recon: np.ndarray            # reconstructed level grid (exact zeros outside)
    n_values: int                # stored values at this level
    density: float
    eb: float
    n_subblocks: int = 0
    ratio: int = 1               # coarsening ratio vs the finest grid
    artifacts: LevelArtifacts | None = field(default=None, repr=False)

    @property
    def total_bits(self) -> int:
        return int(self.payload_bits + self.codebook_bits + self.meta_bits)


@dataclass
class AMRCompressionResult:
    levels: list[LevelResult]
    method: str

    @property
    def total_bits(self) -> int:
        return sum(l.total_bits for l in self.levels)

    @property
    def n_values(self) -> int:
        return sum(l.n_values for l in self.levels)

    def compression_ratio(self, dtype_bits: int = 32) -> float:
        return self.n_values * dtype_bits / max(self.total_bits, 1)

    def bit_rate(self, dtype_bits: int = 32) -> float:
        return self.total_bits / max(self.n_values, 1)


def choose_strategy(density: float, *, algorithm: str, she: bool) -> str:
    """§III-E hybrid policy on unit-block density."""
    if she and algorithm == "lor_reg":
        return "opst" if density < T0 else "akdtree"
    if density < T1:
        return "opst"
    if density < T2:
        return "akdtree"
    return "gsp"


def _global_compress(x: np.ndarray, eb: float, algorithm: str,
                     sz_block: int = 6,
                     entropy_engine: str = "auto") -> SZResult:
    if algorithm == "interp":
        return compress_interp(x, eb, entropy_engine=entropy_engine)
    if algorithm == "lorenzo":
        return compress_lorenzo(x, eb, entropy_engine=entropy_engine)
    if algorithm == "lor_reg":
        # the block edge must match what the level records (the TACZ index
        # stores sz_block and the decoder rebuilds the betas grid from it)
        return compress_lor_reg(x, eb, block=sz_block,
                                entropy_engine=entropy_engine)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def _merged_compress(groups: dict[tuple[int, ...], np.ndarray], eb: float,
                     algorithm: str) -> tuple[list[SZResult], dict[tuple[int, ...], np.ndarray]]:
    """TAC path: one global compression per same-size 4D group.

    For Lor/Reg-without-SHE the merged 4D array is compressed with the
    global (Lorenzo-branch) predictor — prediction runs across the block-
    stacking axis, reproducing the paper's boundary artifact.
    """
    results, recon = [], {}
    for shape, arr in groups.items():
        alg = "lorenzo" if algorithm == "lor_reg" else algorithm
        r = _global_compress(arr, eb, alg)
        results.append(r)
        recon[shape] = r.recon
    return results, recon


def partition_level(data: np.ndarray, mask: np.ndarray, *, unit: int = 8,
                    algorithm: str = "lor_reg", she: bool = True,
                    strategy: str | None = None,
                    ) -> tuple[BlockGrid, str, float, list[SubBlock]]:
    """Resolve one level's strategy and sub-block placement — without
    compressing anything.

    This is the global, deterministic prefix of :func:`compress_level`:
    the unit-block grid, the density-driven strategy choice, and (for
    SHE-style strategies) the partition into sub-blocks.  A parallel
    writer (``repro.io.parallel``) runs it once per level so N workers
    can compress disjoint slices of the *same* placement — every brick's
    codes are then bit-identical to the single-writer path, because the
    batched compressor is per-brick independent.

    :returns: ``(grid, strategy, density, subblocks)`` — ``subblocks``
        is empty for ``"gsp"`` (single global payload).
    :raises ValueError: on an unknown ``strategy``.
    """
    grid = make_block_grid(data, mask, unit=unit)
    density = grid.block_density
    if strategy is None:
        strategy = choose_strategy(density, algorithm=algorithm, she=she)
    if strategy == "gsp":
        return grid, "gsp", density, []
    if strategy == "opst":
        subblocks = opst_partition(grid)
    elif strategy == "akdtree":
        subblocks = akdtree_partition(grid)
    elif strategy == "nast":
        subblocks = [SubBlock(origin=tuple(c), bsize=(1, 1, 1))
                     for c in np.argwhere(grid.occ)]
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return grid, strategy, density, subblocks


def compress_level(data: np.ndarray, mask: np.ndarray, *, eb: float,
                   unit: int = 8, algorithm: str = "lor_reg",
                   she: bool = True, strategy: str | None = None,
                   sz_block: int = 6, batched: bool = True,
                   ratio: int = 1, keep_artifacts: bool = True,
                   lorenzo_engine: str = "auto",
                   entropy_engine: str = "auto") -> LevelResult:
    """One level end to end; records per-strategy wall time into
    ``tacz_compress_level_seconds`` (stage timings — prequant,
    branch_score, entropy — are recorded inside sz/she)."""
    with obs.trace("compress_level"):
        t0 = time.perf_counter()
        res = _compress_level(
            data, mask, eb=eb, unit=unit, algorithm=algorithm, she=she,
            strategy=strategy, sz_block=sz_block, batched=batched,
            ratio=ratio, keep_artifacts=keep_artifacts,
            lorenzo_engine=lorenzo_engine, entropy_engine=entropy_engine)
        obsm.COMPRESS_LEVEL_SECONDS.labels(res.strategy).observe(
            time.perf_counter() - t0)
        return res


def _compress_level(data: np.ndarray, mask: np.ndarray, *, eb: float,
                    unit: int = 8, algorithm: str = "lor_reg",
                    she: bool = True, strategy: str | None = None,
                    sz_block: int = 6, batched: bool = True,
                    ratio: int = 1, keep_artifacts: bool = True,
                    lorenzo_engine: str = "auto",
                    entropy_engine: str = "auto") -> LevelResult:
    grid, strategy, density, subblocks = partition_level(
        data, mask, unit=unit, algorithm=algorithm, she=she,
        strategy=strategy)

    orig_shape = data.shape

    if strategy == "gsp":
        padded, grid = gsp_pad(data, mask, unit=unit)
        r = _global_compress(padded, eb, algorithm, sz_block, entropy_engine)
        recon = gsp_unpad(r.recon, grid)[
            tuple(slice(0, s) for s in orig_shape)]
        art = None
        if keep_artifacts:
            art = LevelArtifacts(mask=np.asarray(mask, dtype=bool),
                                 orig_shape=tuple(orig_shape),
                                 grid_shape=tuple(grid.data.shape),
                                 unit=unit, sz_block=sz_block,
                                 subblocks=[], results=[r], codebook=None)
        return LevelResult(strategy="gsp", algorithm=algorithm, she=False,
                           payload_bits=r.payload_bits,
                           codebook_bits=r.codebook_bits,
                           meta_bits=r.meta_bits + gsp_meta_bits(grid),
                           recon=recon, n_values=int(mask.sum()),
                           density=density, eb=eb, ratio=ratio,
                           artifacts=art)

    sb_meta = sum(sb.meta_bits() for sb in subblocks)
    u = grid.unit

    if she and algorithm == "lor_reg":
        bricks = [extract_subblock(grid, sb) for sb in subblocks]
        enc = she_encode(bricks, eb, block=sz_block, shared=True,
                         batched=batched, lorenzo_engine=lorenzo_engine,
                         entropy_engine=entropy_engine)
        recon = np.zeros(grid.data.shape, dtype=np.float32)
        for sb, r in zip(subblocks, enc.results):
            ox, oy, oz = sb.cell_origin(u)
            sx, sy, sz = sb.cell_size(u)
            recon[ox:ox + sx, oy:oy + sy, oz:oz + sz] = r.recon
        recon = recon[tuple(slice(0, s) for s in orig_shape)]
        recon = np.where(mask, recon, 0.0).astype(np.float32)
        art = None
        if keep_artifacts:
            art = LevelArtifacts(mask=np.asarray(mask, dtype=bool),
                                 orig_shape=tuple(orig_shape),
                                 grid_shape=tuple(grid.data.shape),
                                 unit=grid.unit, sz_block=sz_block,
                                 subblocks=subblocks, results=enc.results,
                                 codebook=enc.codebook)
        return LevelResult(strategy=strategy, algorithm=algorithm, she=True,
                           payload_bits=enc.payload_bits,
                           codebook_bits=enc.codebook_bits,
                           meta_bits=enc.meta_bits + sb_meta,
                           recon=recon, n_values=int(mask.sum()),
                           density=density, eb=eb,
                           n_subblocks=len(subblocks), ratio=ratio,
                           artifacts=art)

    # TAC path: merge same-size blocks into 4D arrays, compress each group
    groups: dict[tuple[int, ...], list[tuple[SubBlock, np.ndarray]]] = {}
    for sb in subblocks:
        brick = extract_subblock(grid, sb)
        order = tuple(np.argsort(brick.shape)[::-1])
        brick_t = np.transpose(brick, order)
        groups.setdefault(brick_t.shape, []).append((sb, order, brick_t))
    payload = cb_bits = 0
    recon = np.zeros(grid.data.shape, dtype=np.float32)
    n_groups = 0
    for shape, items in groups.items():
        arr = np.stack([b for _, _, b in items])
        alg = "lorenzo" if algorithm == "lor_reg" else algorithm
        r = _global_compress(arr, eb, alg, entropy_engine=entropy_engine)
        payload += r.payload_bits
        cb_bits += r.codebook_bits
        n_groups += 1
        for i, (sb, order, _) in enumerate(items):
            inv_order = tuple(np.argsort(order))
            back = np.transpose(r.recon[i], inv_order)
            ox, oy, oz = sb.cell_origin(u)
            sx, sy, sz = sb.cell_size(u)
            recon[ox:ox + sx, oy:oy + sy, oz:oz + sz] = back
    recon = recon[tuple(slice(0, s) for s in orig_shape)]
    recon = np.where(mask, recon, 0.0).astype(np.float32)
    # merged-4D (non-SHE) groups interleave many sub-blocks into one code
    # stream — no per-sub-block payload exists, so no TACZ artifacts.
    return LevelResult(strategy=strategy, algorithm=algorithm, she=False,
                       payload_bits=payload, codebook_bits=cb_bits,
                       meta_bits=sb_meta + n_groups * 64,
                       recon=recon, n_values=int(mask.sum()),
                       density=density, eb=eb, n_subblocks=len(subblocks),
                       ratio=ratio)


def compress_amr(ds: AMRDataset, *, eb: float | list[float],
                 unit: int = 8, algorithm: str = "lor_reg",
                 she: bool = True, strategy: str | None = None,
                 sz_block: int = 6, batched: bool = True,
                 keep_artifacts: bool = True,
                 lorenzo_engine: str = "auto",
                 entropy_engine: str = "auto") -> AMRCompressionResult:
    """Level-wise TAC/TAC+ over a whole AMR dataset.

    ``eb`` may be a scalar (uniform bound) or per-level list — the paper's
    adaptive-error-bound mode (§IV-F).  ``unit`` is the finest level's unit
    block edge; coarser levels use ``max(2, unit / ratio)`` so the unit
    block tracks the refinement granularity (the paper's 16³ unit blocks
    are likewise fixed in *domain* units, not in per-level cells).

    ``keep_artifacts=True`` (default) retains the per-sub-block code
    streams, placement, and shared codebook on each level so the result
    can be serialized to a TACZ container (``repro.io.write``).  That
    pins roughly 3× the level data in memory (int64 codes dominate) —
    accounting-only callers that never serialize should pass
    ``keep_artifacts=False``.

    ``lorenzo_engine`` is forwarded to the batched Lor/Reg compressor:
    ``"auto"`` uses the Pallas kernel on TPU (float32 fast path),
    ``"numpy"`` forces the bit-exact float64 host oracle on any backend.
    ``entropy_engine`` is forwarded to the :mod:`repro.core.entropy`
    stage the same way; entropy engines are bit-identical, so it only
    affects speed.
    """
    ebs = eb if isinstance(eb, (list, tuple)) else [eb] * ds.n_levels
    if len(ebs) != ds.n_levels:
        raise ValueError("need one error bound per level")
    levels = []
    for lvl, e in zip(ds.levels, ebs):
        lvl_unit = max(2, unit // lvl.ratio)
        levels.append(compress_level(lvl.data, lvl.mask, eb=float(e),
                                     unit=lvl_unit, algorithm=algorithm,
                                     she=she, strategy=strategy,
                                     sz_block=sz_block, batched=batched,
                                     ratio=lvl.ratio,
                                     keep_artifacts=keep_artifacts,
                                     lorenzo_engine=lorenzo_engine,
                                     entropy_engine=entropy_engine))
    name = "tac+" if (she and algorithm == "lor_reg") else "tac"
    return AMRCompressionResult(levels=levels, method=f"{name}/{algorithm}")
