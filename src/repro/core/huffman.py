"""Canonical Huffman codec over integer symbol streams.

This is the lossless-encoding stage of SZ (paper §II-A step 3) and the
substrate for Shared Huffman Encoding (paper §III-D).  Tree construction and
canonical code assignment run on the host (NumPy/heapq) — entropy coding is
irreducibly bit-serial, so in a production TPU deployment this stage lives on
the host while predict/quantize run on-device (see DESIGN.md §3).  Encoding
is vectorized bit-packing; decoding walks the canonical-code table.
"""
from __future__ import annotations

import heapq
import struct
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Codebook",
    "build_codebook",
    "encode",
    "decode",
    "encoded_size_bits",
    "symbol_indices",
    "code_lengths_for",
    "codebook_size_bits",
    "serialize_codebook",
    "deserialize_codebook",
]


@dataclass
class Codebook:
    """Canonical Huffman codebook.

    symbols are arbitrary (possibly negative) int64 values; internally we
    operate on the sorted unique alphabet.
    """

    symbols: np.ndarray          # unique symbols, sorted by (length, symbol)
    lengths: np.ndarray          # code length per symbol (same order)
    codes: np.ndarray            # canonical codeword per symbol (same order)
    # Decode acceleration tables (canonical decode):
    first_code: np.ndarray = field(default=None)   # per length L: first codeword
    first_index: np.ndarray = field(default=None)  # per length L: index of first symbol
    count: np.ndarray = field(default=None)        # per length L: #codes of that length
    _enc_map: dict = field(default=None, repr=False)

    @property
    def max_length(self) -> int:
        return int(self.lengths.max(initial=0))

    def encoder_map(self) -> dict:
        if self._enc_map is None:
            self._enc_map = {
                int(s): (int(c), int(l))
                for s, c, l in zip(self.symbols, self.codes, self.lengths)
            }
        return self._enc_map


def _code_lengths_from_hist(symbols: np.ndarray, freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths via the standard two-queue/heap construction."""
    n = len(symbols)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if n == 1:
        return np.ones(1, dtype=np.int64)
    # heap items: (freq, tiebreak, node). Leaves are ints, internal = list of leaf ids.
    heap = [(int(f), i, [i]) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    lengths = np.zeros(n, dtype=np.int64)
    tiebreak = n
    while len(heap) > 1:
        f1, _, l1 = heapq.heappop(heap)
        f2, _, l2 = heapq.heappop(heap)
        for leaf in l1:
            lengths[leaf] += 1
        for leaf in l2:
            lengths[leaf] += 1
        heapq.heappush(heap, (f1 + f2, tiebreak, l1 + l2))
        tiebreak += 1
    return lengths


def _canonicalize(symbols: np.ndarray, lengths: np.ndarray) -> Codebook:
    """Canonical code assignment from (symbol, length) pairs.

    The (length, symbol) order fully determines the canonical codes, so this
    is the shared tail of :func:`build_codebook` and
    :func:`deserialize_codebook` — a codebook round-trips through
    serialization bit-identically because both paths end here.
    """
    order = np.lexsort((symbols, lengths))
    symbols, lengths = symbols[order], lengths[order]
    maxlen = int(lengths.max(initial=0))
    codes = np.zeros(len(symbols), dtype=np.int64)
    count = np.zeros(maxlen + 1, dtype=np.int64)
    for l in lengths:
        count[l] += 1
    first_code = np.zeros(maxlen + 2, dtype=np.int64)
    first_index = np.zeros(maxlen + 2, dtype=np.int64)
    code = 0
    idx = 0
    for l in range(1, maxlen + 1):
        first_code[l] = code
        first_index[l] = idx
        code = (code + count[l]) << 1
        idx += count[l]
    next_code = first_code.copy()
    for i, l in enumerate(lengths):
        codes[i] = next_code[l]
        next_code[l] += 1
    return Codebook(symbols=symbols, lengths=lengths, codes=codes,
                    first_code=first_code, first_index=first_index,
                    count=count)


def build_codebook(data: np.ndarray | None = None, *,
                   symbols: np.ndarray | None = None,
                   freqs: np.ndarray | None = None) -> Codebook:
    """Build a canonical Huffman codebook from a symbol stream or histogram."""
    if data is not None:
        data = np.asarray(data).ravel()
        symbols, freqs = np.unique(data, return_counts=True)
    symbols = np.asarray(symbols, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    keep = freqs > 0
    symbols, freqs = symbols[keep], freqs[keep]
    lengths = _code_lengths_from_hist(symbols, freqs)
    return _canonicalize(symbols, lengths)


def serialize_codebook(cb: Codebook) -> bytes:
    """Canonical codebook → bytes: u32 count, u8 symbol width, symbols
    (i32 when they fit — the quantization-code common case — i64
    otherwise), u8 lengths.

    Only (symbol, length) pairs are stored — canonical codes are a pure
    function of those (the property canonical Huffman exists for).  The
    i32 fast path makes the wire cost match :func:`codebook_size_bits`'
    (32+8)-bits-per-symbol accounting (+5 header bytes).  Code lengths fit
    u8: depth L needs total frequency ≥ Fib(L+1), so int64 histograms cap
    depth well under 255.  Handles the degenerate empty and single-symbol
    codebooks (both appear constantly in per-sub-block container payloads:
    all-zero bricks quantize to a one-symbol alphabet).
    """
    symbols = np.ascontiguousarray(cb.symbols, dtype=np.int64)
    lengths = np.ascontiguousarray(cb.lengths, dtype=np.uint8)
    width = 8 if symbols.size and (int(symbols.min()) < -2 ** 31
                                   or int(symbols.max()) >= 2 ** 31) else 4
    return (struct.pack("<IB", len(symbols), width)
            + symbols.astype(f"<i{width}").tobytes() + lengths.tobytes())


def deserialize_codebook(buf: bytes) -> Codebook:
    """Inverse of :func:`serialize_codebook` (bit-identical codebook)."""
    if len(buf) < 5:
        raise ValueError("truncated codebook")
    n, width = struct.unpack_from("<IB", buf, 0)
    if width not in (4, 8):
        raise ValueError("corrupt codebook header")
    need = 5 + n * (width + 1)
    if len(buf) < need:
        raise ValueError("truncated codebook")
    symbols = np.frombuffer(buf, dtype=f"<i{width}", count=n,
                            offset=5).astype(np.int64)
    lengths = np.frombuffer(buf, dtype=np.uint8, count=n,
                            offset=5 + width * n).astype(np.int64)
    return _canonicalize(symbols, lengths)


def encoded_size_bits(cb: Codebook, data: np.ndarray | None = None, *,
                      symbols: np.ndarray | None = None,
                      freqs: np.ndarray | None = None) -> int:
    """Exact payload size in bits without materializing the bitstream."""
    if data is not None:
        return int(code_lengths_for(cb, data).sum())
    symbols = np.asarray(symbols, dtype=np.int64).ravel()
    freqs = np.asarray(freqs, dtype=np.int64).ravel()
    if symbols.size == 0:
        return 0
    idx = symbol_indices(cb, symbols)
    return int((cb.lengths[idx] * freqs).sum())


def symbol_indices(cb: Codebook, data: np.ndarray) -> np.ndarray:
    """Vectorized symbol → codebook-row lookup (searchsorted on a
    symbol-sorted view); raises on symbols outside the codebook."""
    sym_order = np.argsort(cb.symbols, kind="stable")
    sorted_syms = cb.symbols[sym_order]
    pos = np.searchsorted(sorted_syms, data)
    if (np.any(pos >= len(sorted_syms))
            or np.any(sorted_syms[np.minimum(pos, len(sorted_syms) - 1)] != data)):
        raise ValueError("symbol not in codebook")
    return sym_order[pos]


def code_lengths_for(cb: Codebook, data: np.ndarray) -> np.ndarray:
    """Vectorized per-occurrence code lengths for a symbol stream.

    ``code_lengths_for(cb, data).sum() == encode(cb, data)[1]`` exactly —
    this is how the batched SHE path prices per-block payloads under the
    shared codebook without materializing one bitstream per block.
    """
    data = np.asarray(data, dtype=np.int64).ravel()
    if data.size == 0:
        return np.zeros(0, dtype=np.int64)
    return cb.lengths[symbol_indices(cb, data)]


def codebook_size_bits(cb: Codebook) -> int:
    """Serialized codebook cost: (symbol int32 + length uint8) per entry.

    This is the per-tree header cost that makes many small Huffman trees
    expensive — the overhead SHE removes (paper §III-D).
    """
    return len(cb.symbols) * (32 + 8)


def encode(cb: Codebook, data: np.ndarray, *,
           indices: np.ndarray | None = None) -> tuple[np.ndarray, int]:
    """Encode a symbol stream.  Returns (packed uint8 bitstream, nbits).

    ``indices`` may carry a precomputed ``symbol_indices(cb, data)`` so
    callers that already priced the stream skip the second lookup pass.

    Deprecated as a batch surface: this is the single-stream serial
    oracle (``repro.core.entropy.encode_stream``).  Call sites packing
    many payloads under one codebook should go through
    ``entropy.get_engine(...).encode_payloads`` instead of looping here.
    """
    from . import entropy
    return entropy.encode_stream(cb, data, indices=indices)


def decode(cb: Codebook, packed: np.ndarray, nbits: int, n_symbols: int) -> np.ndarray:
    """Decode ``n_symbols`` symbols from a packed bitstream (canonical walk).

    Degenerate codebooks round-trip without caller-side special-casing:
    an empty codebook decodes only the empty stream (anything else raises),
    and a single-symbol alphabet (1 bit per symbol on the wire, matching
    :func:`encode` / :func:`code_lengths_for`) validates the advertised bit
    count instead of ignoring the stream.  A stream that ends mid-codeword
    raises ``ValueError`` rather than crashing, so truncated container
    payloads surface as clean corruption errors.

    Deprecated as a batch surface: this is the single-stream serial
    oracle (``repro.core.entropy.decode_stream``).  Call sites walking
    many payloads under one codebook should go through
    ``entropy.get_engine(...).decode_payloads`` instead of looping here.
    """
    from . import entropy
    return entropy.decode_stream(cb, packed, nbits, n_symbols)
