"""Ghost-Shell Padding (GSP) — paper §III-A, Algorithm 1.

For *high-density* levels: instead of filling empty regions with zeros
(which poisons SZ's predictor at the boundaries, Fig. 6a), pad each empty
unit block with ``m = min(unit/2, 4)`` layers of the *average boundary
slice* of each non-empty face neighbor.  Where pads from multiple neighbors
overlap (edges/corners of an empty block) the contributions are averaged —
the paper's ``pad/2`` and ``pad/3`` rules generalized to
``sum/contributor-count``.

Compression sends the padded full grid to SZ; decompression restores exact
zeros in empty blocks from the occupancy bitmap (``n_blocks`` bits of
metadata — "almost negligible for high-density data", §III-A).
"""
from __future__ import annotations

import numpy as np

from .blocks import BlockGrid, make_block_grid

__all__ = ["gsp_pad", "gsp_unpad", "gsp_meta_bits"]

_AXIS_OF_DIR = (0, 0, 1, 1, 2, 2)  # ±x, ±y, ±z


def _boundary_slice_mean(data: np.ndarray, unit: int, m: int, axis: int,
                         side: str) -> np.ndarray:
    """Per-block mean of the ``m`` boundary slices on ``side`` of ``axis``.

    Returns an array of shape (bx,by,bz, u, u): one 2D slice per block
    (the two non-``axis`` cell dims).
    """
    bx, by, bz = (s // unit for s in data.shape)
    blocks = (data.reshape(bx, unit, by, unit, bz, unit)
                  .transpose(0, 2, 4, 1, 3, 5))       # (bx,by,bz,u,u,u)
    ax = 3 + axis
    sl = [slice(None)] * 6
    sl[ax] = slice(0, m) if side == "lo" else slice(unit - m, unit)
    return blocks[tuple(sl)].mean(axis=ax)


def gsp_pad(data: np.ndarray, mask: np.ndarray | None = None, *,
            unit: int = 8) -> tuple[np.ndarray, BlockGrid]:
    """Algorithm 1.  Returns (padded grid, block grid)."""
    grid = make_block_grid(data, mask, unit=unit)
    data, occ, u = grid.data, grid.occ, grid.unit
    m = min(u // 2, 4)
    bx, by, bz = occ.shape

    acc = np.zeros_like(data, dtype=np.float64)
    cnt = np.zeros(data.shape, dtype=np.int32)

    # For every direction d: an empty block receives a pad from its
    # non-empty neighbor at +d placed in the m layers of the block adjacent
    # to that neighbor.
    for axis in range(3):
        for sign in (+1, -1):
            # neighbor occupancy shifted onto the current block position
            nocc = np.zeros_like(occ)
            src = [slice(None)] * 3
            dst = [slice(None)] * 3
            if sign > 0:
                src[axis] = slice(1, None); dst[axis] = slice(0, -1)
            else:
                src[axis] = slice(0, -1); dst[axis] = slice(1, None)
            nocc[tuple(dst)] = occ[tuple(src)]
            recv = (~occ) & nocc                      # empty blocks that receive
            if not recv.any():
                continue
            # neighbor's boundary slice facing us: if the neighbor sits at
            # +axis, we need its *low* m slices; at -axis, its *high* slices.
            side = "lo" if sign > 0 else "hi"
            bslice = _boundary_slice_mean(data, u, m, axis, side)  # (bx,by,bz,u,u)
            shifted = np.zeros_like(bslice)
            shifted[tuple(dst)] = bslice[tuple(src)]

            # scatter into the m layers of each receiving block next to n_j
            pad_block = np.zeros((bx, by, bz, u, u, u), dtype=np.float64)
            sl = [slice(None)] * 6
            sl[3 + axis] = (slice(u - m, u) if sign > 0 else slice(0, m))
            expand = np.expand_dims(shifted, 3 + axis)
            pad_block[tuple(sl)] = np.broadcast_to(
                expand, tuple(pad_block[tuple(sl)].shape))
            w = recv[..., None, None, None].astype(np.float64)
            onecnt = np.zeros((bx, by, bz, u, u, u), dtype=np.int32)
            onecnt[tuple(sl)] = 1
            pad_flat = (pad_block * w).transpose(0, 3, 1, 4, 2, 5).reshape(data.shape)
            cnt_flat = (onecnt * recv[..., None, None, None]).transpose(
                0, 3, 1, 4, 2, 5).reshape(data.shape)
            acc += pad_flat
            cnt += cnt_flat

    padded = data.astype(np.float64).copy()
    fill = cnt > 0
    padded[fill] = acc[fill] / cnt[fill]
    return padded.astype(np.float32), grid


def gsp_unpad(recon: np.ndarray, grid: BlockGrid) -> np.ndarray:
    """Restore exact zeros in empty unit blocks (decompression side)."""
    u = grid.unit
    occ_cells = np.repeat(np.repeat(np.repeat(grid.occ, u, 0), u, 1), u, 2)
    return np.where(occ_cells, recon, 0.0).astype(np.float32)


def gsp_meta_bits(grid: BlockGrid) -> int:
    """Occupancy bitmap + dims/eb header."""
    return grid.n_blocks + 3 * 32
