"""Optimized Sparse Tensor (OpST) — paper §III-B, Algorithm 2, Fig. 8.

For *low-density* levels: a 3D dynamic program finds, for every unit block,
the edge length ``BS(x,y,z)`` of the largest cube of non-empty unit blocks
whose bottom-right-rear corner is that block:

    BS = 0                         if block empty
    BS = 1                         on a boundary (x, y or z == 0)
    BS = 1 + min(7 lower neighbors) otherwise

Sub-blocks are extracted greedily scanning from the bottom-right-rear
corner to the top-left-front corner: at each non-empty corner a
``BS³``-unit cube is cut out, the occupancy and ``BS`` inside it are
zeroed, and ``BS`` is *partially* recomputed in a window bounded by
``maxSide`` (paper line 15 / `updateBs`) — which is what makes the method
O(N²·d): denser data → larger ``maxSide`` → bigger update windows.

Extracted cubes of the same size are merged into one 4D array for
compression (§III-B step 5).
"""
from __future__ import annotations

import numpy as np

from .blocks import BlockGrid, SubBlock

__all__ = ["compute_bs", "opst_partition", "merge_subblocks"]


def compute_bs(occ: np.ndarray) -> np.ndarray:
    """Full maximal-cube DP over the occupancy grid (Alg. 2 lines 1–10)."""
    bx, by, bz = occ.shape
    bs = np.zeros((bx, by, bz), dtype=np.int32)
    # vectorize over (y, z) planes; the x recurrence is sequential
    for x in range(bx):
        row = occ[x]
        if x == 0:
            bs[0] = row.astype(np.int32)
            continue
        prev = bs[x - 1]
        # min over the 4 neighbors in the x-1 plane
        m = prev.copy()
        m[1:, :] = np.minimum(m[1:, :], prev[:-1, :])
        m[:, 1:] = np.minimum(m[:, 1:], prev[:, :-1])
        m[1:, 1:] = np.minimum(m[1:, 1:], prev[:-1, :-1])
        # same-plane neighbors (x, y-1, z), (x, y, z-1), (x, y-1, z-1) must be
        # handled sequentially in y,z — do a small python loop over y with
        # vectorized z via running minima.
        plane = np.zeros_like(prev)
        for y in range(by):
            up = plane[y - 1] if y > 0 else None
            mrow = m[y]
            out = np.empty(bz, dtype=np.int32)
            for z in range(bz):
                if not row[y, z]:
                    out[z] = 0
                    continue
                if y == 0 or z == 0:
                    out[z] = 1
                    continue
                out[z] = 1 + min(mrow[z], up[z], out[z - 1],
                                 up[z - 1] if up is not None else 0)
            plane[y] = out
        # boundary x==... x>0 here; y==0 or z==0 handled above; empty → 0
        bs[x] = np.where(row, plane, 0)
    return bs


def _update_bs_window(bs: np.ndarray, occ: np.ndarray,
                      lo: tuple[int, int, int], hi: tuple[int, int, int]) -> None:
    """Recompute the DP inside [lo, hi) in forward order (Alg. 2 line 15).

    Values just outside the window's low faces are valid (extraction can
    only have affected blocks ≥ the removed cube's low corner per dim)."""
    for a in range(lo[0], hi[0]):
        for b in range(lo[1], hi[1]):
            for c in range(lo[2], hi[2]):
                if not occ[a, b, c]:
                    bs[a, b, c] = 0
                elif a == 0 or b == 0 or c == 0:
                    bs[a, b, c] = 1
                else:
                    bs[a, b, c] = 1 + min(
                        bs[a - 1, b, c], bs[a, b - 1, c], bs[a, b, c - 1],
                        bs[a - 1, b - 1, c], bs[a, b - 1, c - 1],
                        bs[a - 1, b, c - 1], bs[a - 1, b - 1, c - 1])


def opst_partition(grid: BlockGrid) -> list[SubBlock]:
    """Algorithm 2: extract maximal cubes, updating the DP after each cut."""
    occ = grid.occ.copy()
    bs = compute_bs(occ)
    max_side = int(bs.max(initial=0))
    bx, by, bz = occ.shape
    out: list[SubBlock] = []
    for x in range(bx - 1, -1, -1):
        for y in range(by - 1, -1, -1):
            for z in range(bz - 1, -1, -1):
                s = int(bs[x, y, z])
                if s < 1:
                    continue
                ox, oy, oz = x - s + 1, y - s + 1, z - s + 1
                out.append(SubBlock(origin=(ox, oy, oz), bsize=(s, s, s)))
                occ[ox:x + 1, oy:y + 1, oz:z + 1] = False
                bs[ox:x + 1, oy:y + 1, oz:z + 1] = 0
                # partial update bounded by maxSide (O(N²·d) total)
                lo = (ox, oy, oz)
                hi = (min(bx, x + max_side + 1), min(by, y + max_side + 1),
                      min(bz, z + max_side + 1))
                _update_bs_window(bs, occ, lo, hi)
    return out


def merge_subblocks(grid: BlockGrid, subblocks: list[SubBlock]
                    ) -> dict[tuple[int, int, int], np.ndarray]:
    """Group extracted sub-blocks by (sorted) size into 4D arrays.

    Same-size blocks are stacked into one ``(n, sx·u, sy·u, sz·u)`` array
    for joint compression (§III-B step 5); differently-oriented cuboids of
    equal sorted size are axis-aligned first (§III-C last paragraph — the
    paper tracks orientations instead of transposing; the bits on disk are
    identical either way).
    """
    u = grid.unit
    groups: dict[tuple[int, int, int], list[np.ndarray]] = {}
    for sb in subblocks:
        ox, oy, oz = sb.cell_origin(u)
        sx, sy, sz = sb.cell_size(u)
        brick = grid.data[ox:ox + sx, oy:oy + sy, oz:oz + sz]
        order = np.argsort(brick.shape)[::-1]
        brick = np.transpose(brick, order)  # align: largest dim first
        groups.setdefault(tuple(brick.shape), []).append(brick)
    return {k: np.stack(v) for k, v in groups.items()}
