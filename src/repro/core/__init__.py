"""TAC/TAC+ — the paper's contribution as a composable library.

Layers (paper section in brackets):
  amr         AMR data model + synthetic Nyx/WarpX/IAMR-like generator [§II-B]
  blocks      unit-block partitioning                                  [§III]
  gsp         ghost-shell padding                                      [§III-A]
  nast        naive sparse tensor                                      [§III-B]
  opst        optimized sparse tensor (maximal-cube DP)                [§III-B]
  akdtree     adaptive k-d tree                                        [§III-C]
  sz          SZ compression core (dual-quant Lorenzo / Lor-Reg / Interp)
  huffman     canonical Huffman codec                                  [§II-A]
  she         shared Huffman encoding                                  [§III-D]
  hybrid      density-adaptive TAC/TAC+ drivers                        [§III-E]
  baselines   naive-1D, zMesh, 3D-upsampling                           [§IV-A]
  metrics     CR/PSNR/power-spectrum/halo-finder                       [§IV-B]
  adaptive_eb per-level error bounds                                   [§IV-F]
"""
from . import (adaptive_eb, akdtree, amr, baselines, blocks, gsp, huffman,
               hybrid, metrics, nast, opst, she, sz)  # noqa: F401

from .amr import AMRDataset, AMRLevel, synthetic_amr, load_preset  # noqa: F401
from .hybrid import compress_amr, compress_level  # noqa: F401
from .sz import SZResult, compress_interp, compress_lor_reg, compress_lorenzo  # noqa: F401
