"""Comparison baselines (paper §IV-A): naive-1D, zMesh, 3D-upsampling.

* **1D baseline** — each AMR level's valid values are flattened row-major
  and compressed as a 1D stream (1D Lorenzo + Huffman): spatial information
  lost, one compressor launch per level.
* **zMesh [28]** — levels are traversed *together* in octree (z-) order:
  a coarse cell emits its value if stored at the coarse level, otherwise
  descends into its 2³ refined children.  On patch-based data this groups
  redundant co-located values and smooths the stream; on tree-based data
  (ours, and the paper's) it inserts cross-level jumps — which is exactly
  why the paper finds zMesh *slightly worse* than the 1D baseline
  (Fig. 28).
* **3D baseline** — upsample every coarse level to the finest resolution
  (piecewise-constant), compress the combined full-resolution field in 3D.
  The compression ratio is charged against the *original* AMR value count,
  so the 8×-per-level redundancy shows up as the paper's sub-optimal CR.
"""
from __future__ import annotations

import numpy as np

from .amr import AMRDataset, uniform_resolution
from .hybrid import AMRCompressionResult, LevelResult
from .sz import (SZResult, compress_interp, compress_lorenzo, compress_lor_reg,
                 entropy_bits, lorenzo_nd_codes, lorenzo_nd_recon, prequant,
                 dequant)

__all__ = ["compress_1d_naive", "compress_zmesh", "compress_3d_baseline",
           "zmesh_order"]


def _compress_1d_stream(values: np.ndarray, eb: float) -> SZResult:
    """1D dual-quant Lorenzo + Huffman on a flat stream."""
    q = prequant(values, eb)
    codes = lorenzo_nd_codes(q)
    payload, cb_bits = entropy_bits(codes)
    recon = dequant(lorenzo_nd_recon(codes), eb)
    return SZResult(recon=recon, codes=codes, payload_bits=payload,
                    codebook_bits=cb_bits, meta_bits=96, eb=eb, method="1d")


def compress_1d_naive(ds: AMRDataset, eb: float | list[float]) -> AMRCompressionResult:
    ebs = eb if isinstance(eb, (list, tuple)) else [eb] * ds.n_levels
    levels = []
    for lvl, e in zip(ds.levels, ebs):
        vals = lvl.data[lvl.mask]
        r = _compress_1d_stream(vals, float(e))
        recon = np.zeros_like(lvl.data)
        recon[lvl.mask] = r.recon
        levels.append(LevelResult(strategy="flatten", algorithm="1d",
                                  she=False, payload_bits=r.payload_bits,
                                  codebook_bits=r.codebook_bits,
                                  meta_bits=r.meta_bits, recon=recon,
                                  n_values=int(lvl.mask.sum()),
                                  density=lvl.density, eb=float(e)))
    return AMRCompressionResult(levels=levels, method="1d-naive")


def zmesh_order(ds: AMRDataset) -> tuple[np.ndarray, list[np.ndarray], np.ndarray]:
    """Octree traversal across levels (zMesh reordering, Fig. 28).

    Returns (reordered 1D value stream, per-level flat cell indices in
    traversal order, per-element level tags) — enough to invert exactly.
    """
    n_levels = ds.n_levels
    stream: list[float] = []
    tags: list[int] = []
    index_per_level: list[list[int]] = [[] for _ in range(n_levels)]
    masks = [l.mask for l in ds.levels]
    datas = [l.data for l in ds.levels]

    def descend(level: int, x: int, y: int, z: int) -> None:
        # level indexes ds.levels (0 = finest); cell (x,y,z) in that grid
        if masks[level][x, y, z]:
            flat = int(np.ravel_multi_index((x, y, z), masks[level].shape))
            index_per_level[level].append(flat)
            stream.append(float(datas[level][x, y, z]))
            tags.append(level)
            return
        if level == 0:
            raise AssertionError("tiling invariant violated in zmesh_order")
        for dx in (0, 1):
            for dy in (0, 1):
                for dz in (0, 1):
                    descend(level - 1, 2 * x + dx, 2 * y + dy, 2 * z + dz)

    cx, cy, cz = ds.levels[-1].shape
    for x in range(cx):
        for y in range(cy):
            for z in range(cz):
                descend(n_levels - 1, x, y, z)
    return (np.asarray(stream, dtype=np.float32),
            [np.asarray(ix, dtype=np.int64) for ix in index_per_level],
            np.asarray(tags, dtype=np.int32))


def compress_zmesh(ds: AMRDataset, eb: float) -> AMRCompressionResult:
    stream, idx, tags = zmesh_order(ds)
    r = _compress_1d_stream(stream, eb)
    recons = [np.zeros_like(l.data) for l in ds.levels]
    for lvl in range(ds.n_levels):
        recons[lvl].reshape(-1)[idx[lvl]] = r.recon[tags == lvl]
    levels = []
    for lvl, lev in enumerate(ds.levels):
        share = lev.mask.sum() / max(stream.size, 1)
        levels.append(LevelResult(
            strategy="zorder", algorithm="1d", she=False,
            payload_bits=int(r.payload_bits * share),
            codebook_bits=int(r.codebook_bits * share),
            meta_bits=int(r.meta_bits * share),
            recon=recons[lvl], n_values=int(lev.mask.sum()),
            density=lev.density, eb=eb))
    return AMRCompressionResult(levels=levels, method="zmesh")


def compress_3d_baseline(ds: AMRDataset, eb: float, *,
                         algorithm: str = "lor_reg") -> AMRCompressionResult:
    """Upsample-and-merge 3D baseline (§II-D 'High-dimensional')."""
    uni = uniform_resolution(ds)
    if algorithm == "interp":
        r = compress_interp(uni, eb)
    elif algorithm == "lorenzo":
        r = compress_lorenzo(uni, eb)
    else:
        r = compress_lor_reg(uni, eb)
    # reconstruct each level by sampling the corner cell of its footprint —
    # the upsampling was piecewise-constant, so this is decoder-exact and
    # keeps the per-value error within eb.
    levels = []
    for i, lvl in enumerate(ds.levels):
        ratio = lvl.ratio
        sampled = r.recon[::ratio, ::ratio, ::ratio]
        recon = np.where(lvl.mask, sampled, 0.0).astype(np.float32)
        share = (lvl.mask.sum() * ratio ** 3) / uni.size
        levels.append(LevelResult(
            strategy="upsample", algorithm=algorithm, she=False,
            payload_bits=int(r.payload_bits * share),
            codebook_bits=int(r.codebook_bits * share),
            meta_bits=int(r.meta_bits * share),
            recon=recon, n_values=int(lvl.mask.sum()),
            density=lvl.density, eb=eb))
    return AMRCompressionResult(levels=levels, method=f"3d-baseline/{algorithm}")
