"""Per-level adaptive error bounds (paper §IV-F).

Level-wise compression lets TAC/TAC+ give every AMR level its own error
bound — impossible for the 3D baseline, where upsampling flattens all
levels into one field.  The paper derives the fine:coarse ratio in three
steps:

  1. Start from the post-analysis metric's ideal ratio on the
     uniform-resolution data — 1:1 for the (global) power spectrum, 1:2 for
     the halo finder (fine level carries the halo candidates).
  2. Multiply the coarse level's bound down by the upsampling rate (2³ per
     level step): coarse-level errors are replicated 8× in post-analysis.
  3. Temper toward the rate-distortion sweet spot (Fig. 29: at large eb the
     fine level's bit-rate stops falling, so trade fine-level error back).
     The paper lands at 3:1 (power spectrum) and 2:1 (halo finder) for a
     2-level, ratio-8 dataset; we expose the tempering exponent that
     reproduces those numbers and extrapolate it to deeper hierarchies.
"""
from __future__ import annotations

import numpy as np

__all__ = ["level_error_bounds", "PAPER_RATIOS"]

# tempering exponents calibrated to the paper's landed ratios for a
# 2-level dataset: (8 * start)^alpha == landed
#   power_spectrum: start 1,  landed 3  →  alpha = ln3/ln8  ≈ 0.528
#   halo_finder:    start 1/2, landed 2 →  alpha = ln2/ln4  = 0.5
_ALPHA = {"power_spectrum": float(np.log(3) / np.log(8)),
          "halo_finder": 0.5,
          "generic": 0.5}
_START = {"power_spectrum": 1.0, "halo_finder": 0.5, "generic": 1.0}

PAPER_RATIOS = {"power_spectrum": 3.0, "halo_finder": 2.0}


def level_error_bounds(base_eb: float, n_levels: int, *,
                       metric: str = "power_spectrum",
                       upsample_rate: int = 8) -> list[float]:
    """Error bound per level (finest first).

    ``base_eb`` is the finest level's bound; each coarser level gets
    ``base_eb / ratio_step`` where the per-step ratio is the tempered
    ``(upsample_rate * start)^alpha`` of the paper's §IV-F recipe.
    """
    alpha = _ALPHA.get(metric, _ALPHA["generic"])
    start = _START.get(metric, 1.0)
    step = (upsample_rate * start) ** alpha
    return [float(base_eb / step ** i) for i in range(n_levels)]
