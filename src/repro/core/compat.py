"""Optional-dependency gates.

The container images this repo targets do not all ship ``zstandard``; the
SZ entropy stage treats the zstd pass as a *size-reducing option* (it only
ever tightens ``min(huffman_bits, zstd_bits)``), so a missing module
degrades gracefully to Huffman-only accounting instead of an ImportError.

``zstd_size_bits`` is the single choke point: every caller that previously
did ``len(ZstdCompressor().compress(buf)) * 8`` goes through here.
"""
from __future__ import annotations

__all__ = ["HAVE_ZSTD", "zstd_module", "zstd_size_bits",
           "zstd_compress", "zstd_decompress"]

try:
    import zstandard as _zstd

    HAVE_ZSTD = True
except ImportError:          # pragma: no cover - environment dependent
    _zstd = None
    HAVE_ZSTD = False


def zstd_module():
    """The ``zstandard`` module, or None when not installed."""
    return _zstd


def zstd_size_bits(buf: bytes, *, level: int = 3) -> int | None:
    """Size in bits of ``buf`` after a zstd pass, or None without zstd."""
    if _zstd is None:
        return None
    return len(_zstd.ZstdCompressor(level=level).compress(buf)) * 8


def zstd_compress(buf: bytes, *, level: int = 3) -> bytes:
    """zstd-compress ``buf``; raises if zstandard is unavailable."""
    if _zstd is None:
        raise ModuleNotFoundError("zstandard is not installed")
    return _zstd.ZstdCompressor(level=level).compress(buf)


def zstd_decompress(blob: bytes) -> bytes:
    if _zstd is None:
        raise ModuleNotFoundError("zstandard is not installed")
    return _zstd.ZstdDecompressor().decompress(blob)
