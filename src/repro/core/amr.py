"""AMR data model and synthetic Nyx-like dataset generator.

The paper (§II-B/II-C, Table I) works on tree-based patch AMR data from
AMReX (Nyx / WarpX / IAMR): each refinement level is a regular 3D grid at
its own resolution, and every spatial point's value lives at *exactly one*
level (tree-based, no cross-level redundancy — redundant patch copies are
discarded before compression, §II-C).

We reproduce that data model exactly:

  * ``AMRLevel``    — one refinement level: a dense 3D array at the level's
    resolution plus a boolean validity mask (True where the point is stored
    at this level).  Levels are kept finest-first; ``ratio`` is the
    coarsening ratio relative to the finest grid (1, 2, 4, ...).
  * ``AMRDataset``  — an ordered list of levels with the tiling invariant:
    the union of the levels' masks, upsampled to the finest resolution,
    covers the domain exactly once.

The synthetic generator mimics a Nyx baryon-density field: a Gaussian
random field with a power-law spectrum, exponentiated to a lognormal field
(dense "halos" on a smooth background), then refined block-wise by value —
exactly the refinement criterion sketched in the paper's Fig. 1 ("refine a
block when its maximum value is larger than a threshold").  Per-level
densities (Table I) are matched by quantile selection of refinement
blocks, so we can generate e.g. a z10-like (23% fine / 77% coarse) or a
Run2_T4-like (0.003% fine) dataset on demand.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "AMRLevel",
    "AMRDataset",
    "gaussian_random_field",
    "synthetic_amr",
    "uniform_resolution",
    "NYX_LIKE_PRESETS",
]


@dataclass
class AMRLevel:
    """One refinement level of a tree-based AMR dataset."""

    data: np.ndarray            # (nx, ny, nz) float32; 0 where mask is False
    mask: np.ndarray            # (nx, ny, nz) bool; True = stored at this level
    ratio: int                  # coarsening ratio vs. the finest grid (1, 2, 4, ..)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def n_valid(self) -> int:
        return int(self.mask.sum())

    @property
    def density(self) -> float:
        """Fraction of the *domain volume* stored at this level (Table I)."""
        covered = self.n_valid * self.ratio ** 3
        finest_cells = int(np.prod([s * self.ratio for s in self.data.shape]))
        return covered / finest_cells

    def valid_values(self) -> np.ndarray:
        return self.data[self.mask]


@dataclass
class AMRDataset:
    """Tree-based AMR dataset: finest level first."""

    levels: list[AMRLevel]
    name: str = "amr"

    @property
    def finest_shape(self) -> tuple[int, ...]:
        return self.levels[0].shape

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def total_values(self) -> int:
        """Number of stored values (= what the simulation writes to disk)."""
        return sum(l.n_valid for l in self.levels)

    def original_size_bits(self, dtype_bits: int = 32) -> int:
        return self.total_values() * dtype_bits

    def densities(self) -> list[float]:
        return [l.density for l in self.levels]

    def check_tiling(self) -> bool:
        """Tiling invariant: every finest-resolution cell stored exactly once."""
        cover = np.zeros(self.finest_shape, dtype=np.int32)
        for l in self.levels:
            up = np.repeat(
                np.repeat(np.repeat(l.mask, l.ratio, 0), l.ratio, 1), l.ratio, 2
            ).astype(np.int32)
            cover += up
        return bool((cover == 1).all())


def gaussian_random_field(shape: tuple[int, int, int], *, beta: float = 3.0,
                          smooth_sigma: float = 1.2,
                          seed: int = 0) -> np.ndarray:
    """Gaussian random field with isotropic power spectrum P(k) ~ k^-beta.

    This is the standard way to mock a cosmological density field: matter
    power spectra fall off as a power law over the scales we test
    (paper §IV-B, Metric 5).  ``smooth_sigma`` applies a Gaussian
    band-limit (in cells): simulation output is *resolved* at the grid
    scale (viscosity/pressure damp Nyquist-scale power), and without this
    cutoff a synthetic field is noise-dominated at the grid scale, which
    inverts the paper's central premise that high-dimensional prediction
    beats 1D prediction.
    """
    rng = np.random.default_rng(seed)
    white = rng.standard_normal(shape).astype(np.float64)
    fw = np.fft.rfftn(white)
    kx = np.fft.fftfreq(shape[0])[:, None, None]
    ky = np.fft.fftfreq(shape[1])[None, :, None]
    kz = np.fft.rfftfreq(shape[2])[None, None, :]
    k2 = kx * kx + ky * ky + kz * kz
    k2[0, 0, 0] = 1.0  # keep the DC mode finite
    amp = k2 ** (-beta / 4.0)  # sqrt of P(k) = k^-beta (k^2)^(−beta/4)
    amp[0, 0, 0] = 0.0
    if smooth_sigma > 0:
        # Gaussian low-pass in k-space (cells → radians/cell)
        amp = amp * np.exp(-2.0 * (np.pi * smooth_sigma) ** 2 * k2)
    field = np.fft.irfftn(fw * amp, s=shape, axes=(0, 1, 2))
    field /= field.std() + 1e-30
    return field.astype(np.float64)


def _assign_levels_by_quantile(interest: np.ndarray,
                               densities: list[float]) -> np.ndarray:
    """Assign each refinement block to a level (0=finest) by interest quantile.

    ``densities`` is the target fraction of domain volume per level,
    finest-first, summing to 1.  The most "interesting" blocks (largest
    values — the refinement criterion of Fig. 1) go to the finest level.
    """
    flat = interest.ravel()
    order = np.argsort(-flat, kind="stable")  # descending interest
    n = flat.size
    level_of_block = np.empty(n, dtype=np.int32)
    start = 0
    for lvl, frac in enumerate(densities):
        cnt = int(round(frac * n))
        if lvl == len(densities) - 1:
            cnt = n - start
        level_of_block[order[start:start + cnt]] = lvl
        start += cnt
    return level_of_block.reshape(interest.shape)


def synthetic_amr(finest_shape: tuple[int, int, int] = (64, 64, 64), *,
                  densities: list[float] | None = None,
                  refine_block: int = 8,
                  beta: float = 3.0,
                  smooth_sigma: float = 1.2,
                  lognormal_sigma: float = 1.8,
                  seed: int = 0,
                  name: str = "synthetic") -> AMRDataset:
    """Generate a Nyx-like tree-based AMR dataset.

    Parameters
    ----------
    finest_shape : resolution of the finest level.
    densities    : target fraction of the domain stored per level,
                   finest-first (must sum to ~1).  Default 2-level 23/77
                   (= Nyx Run1_Z10 in Table I).
    refine_block : refinement granularity in finest cells (AMReX
                   ``blocking_factor``).  Must be divisible by every
                   level's ratio.
    lognormal_sigma : contrast of the lognormal transform (bigger = spikier
                   halos = lower natural density at the finest level).
    """
    if densities is None:
        densities = [0.23, 0.77]
    n_levels = len(densities)
    ratios = [2 ** i for i in range(n_levels)]
    for s in finest_shape:
        if s % refine_block:
            raise ValueError(f"finest shape {finest_shape} not divisible by "
                             f"refine_block {refine_block}")
    if refine_block % ratios[-1]:
        raise ValueError(f"refine_block {refine_block} must be divisible by "
                         f"the coarsest ratio {ratios[-1]}")
    total = float(sum(densities))
    densities = [d / total for d in densities]

    g = gaussian_random_field(finest_shape, beta=beta,
                              smooth_sigma=smooth_sigma, seed=seed)
    field = np.exp(lognormal_sigma * g).astype(np.float64)
    # Normalize mean to 1 (density contrast convention; halo finder uses
    # multiples of the mean, paper Metric 6).
    field /= field.mean()
    field = field.astype(np.float32)

    # Block-wise interest = max value in the refinement block (Fig. 1).
    rb = refine_block
    bshape = tuple(s // rb for s in finest_shape)
    blocks = field.reshape(bshape[0], rb, bshape[1], rb, bshape[2], rb)
    interest = blocks.max(axis=(1, 3, 5))
    level_of_block = _assign_levels_by_quantile(interest, densities)

    levels: list[AMRLevel] = []
    for lvl, ratio in enumerate(ratios):
        lshape = tuple(s // ratio for s in finest_shape)
        # Average-pool the finest field down to this level's resolution —
        # the value an AMR code would carry on its coarse grid.
        pooled = field.reshape(lshape[0], ratio, lshape[1], ratio,
                               lshape[2], ratio).mean(axis=(1, 3, 5))
        # Mask: blocks assigned to this level, expanded to level cells.
        sel = (level_of_block == lvl)
        cells_per_block = rb // ratio
        mask = np.repeat(np.repeat(np.repeat(sel, cells_per_block, 0),
                                   cells_per_block, 1), cells_per_block, 2)
        data = np.where(mask, pooled, 0.0).astype(np.float32)
        levels.append(AMRLevel(data=data, mask=mask, ratio=ratio))
    ds = AMRDataset(levels=levels, name=name)
    assert ds.check_tiling(), "synthetic AMR violated the tiling invariant"
    return ds


def uniform_resolution(ds: AMRDataset) -> np.ndarray:
    """Up-sample every level to the finest resolution and combine (Fig. 2).

    This is the representation post-analysis runs on (power spectrum, halo
    finder) and the input of the 3D baseline compressor.
    """
    out = np.zeros(ds.finest_shape, dtype=np.float32)
    for l in ds.levels:
        up = np.repeat(np.repeat(np.repeat(l.data, l.ratio, 0), l.ratio, 1),
                       l.ratio, 2)
        upm = np.repeat(np.repeat(np.repeat(l.mask, l.ratio, 0), l.ratio, 1),
                        l.ratio, 2)
        out = np.where(upm, up, out)
    return out


# Table I datasets re-scaled to laptop-size grids: same level structure and
# per-level densities as the paper, smaller resolutions.
NYX_LIKE_PRESETS: dict[str, dict] = {
    # name                  finest     densities (fine→coarse)        sigma
    "run1_z10": dict(finest_shape=(64, 64, 64), densities=[0.23, 0.77],
                     lognormal_sigma=1.8, seed=10),
    "run1_z5": dict(finest_shape=(64, 64, 64), densities=[0.58, 0.42],
                    lognormal_sigma=1.4, seed=5),
    "run1_z2": dict(finest_shape=(64, 64, 64), densities=[0.63, 0.37],
                    lognormal_sigma=1.2, seed=2),
    "run2_t3": dict(finest_shape=(64, 64, 64),
                    densities=[0.0202, 0.0556, 0.9242],
                    lognormal_sigma=2.6, seed=3),
    "run2_t4": dict(finest_shape=(128, 128, 128),
                    densities=[0.004, 0.02, 0.022, 0.954],
                    lognormal_sigma=3.0, seed=4, refine_block=16),
    "run3_z1": dict(finest_shape=(64, 64, 64),
                    densities=[0.009, 0.147, 0.844],
                    lognormal_sigma=2.4, seed=1),
    "warpx_800": dict(finest_shape=(32, 32, 128), densities=[0.086, 0.914],
                      lognormal_sigma=2.2, seed=800, refine_block=8),
    "warpx_1600": dict(finest_shape=(32, 32, 128), densities=[0.02, 0.98],
                       lognormal_sigma=2.6, seed=1600, refine_block=8),
    "iamr_90": dict(finest_shape=(64, 64, 64),
                    densities=[0.006, 0.105, 0.889],
                    lognormal_sigma=2.5, seed=90),
    "iamr_150": dict(finest_shape=(64, 64, 64),
                     densities=[0.148, 0.309, 0.543],
                     lognormal_sigma=1.6, seed=150),
}


def load_preset(name: str) -> AMRDataset:
    if name not in NYX_LIKE_PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(NYX_LIKE_PRESETS)}")
    kw = dict(NYX_LIKE_PRESETS[name])
    return synthetic_amr(name=name, **kw)
