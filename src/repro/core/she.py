"""Shared Huffman Encoding (SHE) — paper §III-D, Algorithm 4.

The partition strategies can emit thousands of small sub-blocks.  Vanilla
SZ must then either (a) merge them into 4D arrays — prediction crosses
non-adjacent block boundaries and collapses (TAC's weakness) — or
(b) compress each block separately — one Huffman tree *per block*, whose
serialized codebooks dominate the output.

SHE does the paper's third thing: **predict and quantize every block
independently** (restoring Lorenzo/regression locality), then aggregate all
blocks' quantization codes and regression coefficients and encode them with
**one shared Huffman tree**.

``she_encode`` returns exact bit accounting for all three variants so the
benchmarks can reproduce Figs. 15/16:

  * ``shared``    — SHE (one codebook, per-block payload bits summed)
  * ``per_block`` — one codebook per block (the overhead SHE removes)
  * the caller gets per-block code streams back for the merged-4D
    comparison.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import huffman
from .sz import SZResult, compress_lor_reg

__all__ = ["SHEResult", "she_encode"]


@dataclass
class SHEResult:
    results: list[SZResult]       # per-brick prediction results (recon etc.)
    payload_bits: int             # Σ per-brick payloads under the codebook
    codebook_bits: int
    meta_bits: int                # per-brick prediction side info + counts
    codebook: huffman.Codebook

    @property
    def total_bits(self) -> int:
        return int(self.payload_bits + self.codebook_bits + self.meta_bits)


def she_encode(bricks: list[np.ndarray], eb: float, *, block: int = 6,
               shared: bool = True, use_zstd: bool = True) -> SHEResult:
    """Compress a list of 3D/4D bricks with per-brick Lor/Reg prediction.

    ``shared=True``  → Algorithm 4: one Huffman tree over all bricks, one
    encoder launch, one lossless (zstd) pass over the whole bitstream.
    ``shared=False`` → the per-block baseline SHE replaces: one tree, one
    bitstream, one lossless pass *per brick* (the per-launch overhead the
    paper measures against).
    """
    results = [compress_lor_reg(b, eb, block=block, count_entropy=False)
               for b in bricks]
    meta = sum(r.meta_bits for r in results)
    # stream-splitting info: #codes per brick (32 bit each)
    meta += 32 * len(results)
    if shared:
        all_codes = (np.concatenate([r.codes for r in results])
                     if results else np.zeros(0, dtype=np.int64))
        cb = huffman.build_codebook(all_codes)
        packed, nbits = huffman.encode(cb, all_codes)
        payload = nbits
        if use_zstd and nbits:
            import zstandard as zstd

            payload = min(payload,
                          len(zstd.ZstdCompressor(level=3)
                              .compress(packed.tobytes())) * 8)
        # per-brick payloads (diagnostics only; totals use the shared stream)
        for r in results:
            _, r.payload_bits = huffman.encode(cb, r.codes)
        cb_bits = huffman.codebook_size_bits(cb)
    else:
        payload = 0
        cb_bits = 0
        cb = None
        for r in results:
            rcb = huffman.build_codebook(r.codes)
            packed, nbits = huffman.encode(rcb, r.codes)
            bits = nbits
            if use_zstd and nbits:
                import zstandard as zstd

                bits = min(bits,
                           len(zstd.ZstdCompressor(level=3)
                               .compress(packed.tobytes())) * 8)
            payload += bits
            cb_bits += huffman.codebook_size_bits(rcb)
            r.payload_bits = bits
            r.codebook_bits = huffman.codebook_size_bits(rcb)
    return SHEResult(results=results, payload_bits=int(payload),
                     codebook_bits=int(cb_bits), meta_bits=int(meta),
                     codebook=cb)
