"""Shared Huffman Encoding (SHE) — paper §III-D, Algorithm 4.

The partition strategies can emit thousands of small sub-blocks.  Vanilla
SZ must then either (a) merge them into 4D arrays — prediction crosses
non-adjacent block boundaries and collapses (TAC's weakness) — or
(b) compress each block separately — one Huffman tree *per block*, whose
serialized codebooks dominate the output.

SHE does the paper's third thing: **predict and quantize every block
independently** (restoring Lorenzo/regression locality), then aggregate all
blocks' quantization codes and regression coefficients and encode them with
**one shared Huffman tree**.

Batched pipeline (the default, ``batched=True``): sub-blocks are grouped by
shape, each group stacked into a 4D batch and run through the vectorized
Lor/Reg compressor (:func:`repro.core.sz.compress_lor_reg_batched` — one
fused prequant+Lorenzo + one batched plane-fit per group instead of one
Python-level compressor call per brick), then a **single aggregated
histogram** over all bricks' codes feeds one shared codebook build.  The
sequential per-brick loop is kept as the reference oracle (``batched=False``)
and the two paths are bit-identical — same codes, same reconstructions,
same size accounting (property-tested in ``tests/test_she_batched.py``).

``she_encode`` returns exact bit accounting for all three variants so the
benchmarks can reproduce Figs. 15/16:

  * ``shared``    — SHE (one codebook, per-block payload bits summed)
  * ``per_block`` — one codebook per block (the overhead SHE removes)
  * the caller gets per-block code streams back for the merged-4D
    comparison.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import entropy, huffman
from .compat import HAVE_ZSTD, zstd_size_bits
from .sz import SZResult, compress_lor_reg, compress_lor_reg_batched
from ..obs import metrics as obsm

__all__ = ["SHEResult", "she_encode", "aggregate_histogram",
           "encode_brick_payloads", "decode_brick_payloads"]

# Above this code span the dense histogram would be larger than the unique
# pass it replaces; fall back to np.unique (outlier-heavy streams only).
_MAX_HIST_SPAN = 1 << 22
# The one-hot-matmul kernel materializes (chunk, span) tiles, so its span
# budget is far smaller than the dense bincount's; wider streams fall back.
_MAX_PALLAS_SPAN = 1 << 14


@dataclass
class SHEResult:
    results: list[SZResult]       # per-brick prediction results (recon etc.)
    payload_bits: int             # Σ per-brick payloads under the codebook
    codebook_bits: int
    meta_bits: int                # per-brick prediction side info + counts
    codebook: huffman.Codebook

    @property
    def total_bits(self) -> int:
        return int(self.payload_bits + self.codebook_bits + self.meta_bits)


def aggregate_histogram(codes: np.ndarray, *, engine: str = "numpy",
                        ) -> tuple[np.ndarray, np.ndarray]:
    """(symbols, freqs) of the pooled code stream — Alg. 4's one histogram.

    ``engine="numpy"`` uses a dense ``bincount`` over the shifted code range
    (host path).  ``engine="pallas"`` routes the counting through the
    one-hot-matmul histogram kernel (``repro.kernels.hist``) — the on-device
    formulation used when the prediction stage already ran on the TPU.
    Both return exactly what ``np.unique(codes, return_counts=True)`` would,
    so the downstream codebook is independent of the engine.
    """
    if engine not in ("numpy", "pallas"):
        raise ValueError(f"unknown histogram engine {engine!r}")
    codes = np.asarray(codes).ravel()
    if codes.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    lo = int(codes.min())
    span = int(codes.max()) - lo + 1
    if span > _MAX_HIST_SPAN:
        return np.unique(codes, return_counts=True)
    if engine == "pallas" and span <= _MAX_PALLAS_SPAN:
        from repro.kernels import ops

        n_bins = -(-span // 128) * 128  # pad: hist tiles are 128-lane wide
        counts = np.asarray(ops.hist((codes - lo).astype(np.int32),
                                     n_bins=n_bins)).astype(np.int64)
    else:
        counts = np.bincount(codes - lo, minlength=span)
    nz = np.flatnonzero(counts)
    return nz + lo, counts[nz]


def _shared_entropy_stage(results: list[SZResult], *, use_zstd: bool,
                          engine: str, entropy_engine: str = "auto",
                          ) -> tuple[int, int, huffman.Codebook]:
    """One histogram → one codebook → one encoder launch → one zstd pass.

    The Huffman payload is priced exactly from the per-occurrence code
    lengths (``sum == encode(...)[1]``); the packed bitstream is only
    materialized when a zstd pass will actually consume it.
    """
    with obsm.timed(obsm.COMPRESS_STAGE_SECONDS.labels("entropy"),
                    "entropy"):
        all_codes = (np.concatenate([r.codes for r in results])
                     if results else np.zeros(0, dtype=np.int64))
        symbols, freqs = aggregate_histogram(all_codes, engine=engine)
        cb = huffman.build_codebook(symbols=symbols, freqs=freqs)
        # one symbol-index pass prices the stream AND feeds the encoder
        idx = (huffman.symbol_indices(cb, all_codes.astype(np.int64))
               if all_codes.size else np.zeros(0, np.int64))
        lengths = cb.lengths[idx]
        payload = int(lengths.sum())
        if use_zstd and HAVE_ZSTD and payload:
            (blob, _), = entropy.get_engine(entropy_engine).encode_payloads(
                cb, [all_codes])
            zbits = zstd_size_bits(blob)
            if zbits is not None:
                payload = min(payload, zbits)
        # per-brick payloads (diagnostics only; totals use the shared
        # stream) — priced via the same vectorized lookup, split at brick
        # boundaries
        splits = np.cumsum([r.codes.size for r in results])[:-1]
        for r, chunk in zip(results, np.split(lengths, splits)):
            r.payload_bits = int(chunk.sum())
        return int(payload), huffman.codebook_size_bits(cb), cb


def encode_brick_payloads(cb: huffman.Codebook,
                          codes_list: list[np.ndarray], *,
                          engine: str = "auto") -> list[tuple[bytes, int]]:
    """One byte-aligned packed bitstream per brick under the shared codebook.

    This is the TACZ container's payload framing: every sub-block's code
    stream is encoded (and byte-padded) *separately* so any sub-block can be
    decoded without touching its neighbors — the random-access property the
    ROI reader builds on.  Returns ``(payload bytes, nbits)`` per brick;
    ``nbits`` is exactly ``code_lengths_for(cb, codes).sum()``.

    Thin wrapper (kept for compatibility) over
    ``repro.core.entropy.EntropyEngine.encode_payloads`` — the batched
    engines pack the whole brick list in one offset-scatter pass; output
    bytes are identical for every ``engine``.
    """
    return entropy.get_engine(engine).encode_payloads(cb, codes_list)


def decode_brick_payloads(cb: huffman.Codebook,
                          payloads: list[tuple[bytes, int, int]], *,
                          engine: str = "auto") -> list[np.ndarray]:
    """Inverse of :func:`encode_brick_payloads` for a batch of bricks.

    ``payloads`` is a list of ``(payload bytes, nbits, n_codes)`` triples,
    all under the same shared codebook; returns the int64 code stream per
    brick; pair the recovered streams with ``sz.decode_codes_batched`` for
    vectorized reconstruction.

    Thin wrapper (kept for compatibility) over
    ``repro.core.entropy.EntropyEngine.decode_payloads`` — the batched
    engines replace the per-brick serial bit-walk with one lockstep
    canonical decode; outputs and error behavior match the serial oracle
    exactly for every ``engine``.
    """
    return entropy.get_engine(engine).decode_payloads(cb, payloads)


def she_encode(bricks: list[np.ndarray], eb: float, *, block: int = 6,
               shared: bool = True, use_zstd: bool = True,
               batched: bool = True, hist_engine: str = "numpy",
               lorenzo_engine: str = "auto",
               entropy_engine: str = "auto") -> SHEResult:
    """Compress a list of 3D/4D bricks with per-brick Lor/Reg prediction.

    ``shared=True``  → Algorithm 4: one Huffman tree over all bricks, one
    encoder launch, one lossless (zstd) pass over the whole bitstream.
    ``shared=False`` → the per-block baseline SHE replaces: one tree, one
    bitstream, one lossless pass *per brick* (the per-launch overhead the
    paper measures against).

    ``batched=True`` (default) vectorizes the prediction stage over
    same-shape groups of bricks and builds the shared codebook from one
    aggregated histogram; ``batched=False`` is the sequential per-brick
    reference path.  Outputs are bit-identical either way *on the numpy
    Lorenzo engine* (the CPU default).  ``lorenzo_engine="auto"`` routes
    the batched Lorenzo branch through the float32 Pallas kernel when a
    TPU is attached — codes there may differ from the float64 oracle in
    half-integer rounding; pass ``lorenzo_engine="numpy"`` to force
    bit-exactness on any backend.  ``entropy_engine`` selects the
    :mod:`repro.core.entropy` engine used when the zstd pass sizes the
    pooled bitstream — all entropy engines are bit-identical, so this
    only affects speed.
    """
    if batched:
        results: list[SZResult | None] = [None] * len(bricks)
        groups: dict[tuple[int, ...], list[int]] = {}
        for i, brk in enumerate(bricks):
            brk = np.asarray(brk)
            if brk.ndim == 3:
                groups.setdefault(brk.shape, []).append(i)
            else:  # rare 4D bricks keep the reference per-brick path
                results[i] = compress_lor_reg(brk, eb, block=block,
                                              count_entropy=False)
        for shape, idxs in groups.items():
            stack = np.stack([np.asarray(bricks[i]) for i in idxs])
            for i, r in zip(idxs, compress_lor_reg_batched(
                    stack, eb, block=block, engine=lorenzo_engine)):
                results[i] = r
    else:
        results = [compress_lor_reg(b, eb, block=block, count_entropy=False)
                   for b in bricks]
    meta = sum(r.meta_bits for r in results)
    # stream-splitting info: #codes per brick (32 bit each)
    meta += 32 * len(results)
    if shared:
        payload, cb_bits, cb = _shared_entropy_stage(
            results, use_zstd=use_zstd, engine=hist_engine,
            entropy_engine=entropy_engine)
    else:
        payload = 0
        cb_bits = 0
        cb = None
        for r in results:
            # per-block baseline: one codebook per brick, so there is no
            # shared-codebook batch to form — the single-stream surface
            # is the right one here
            rcb = huffman.build_codebook(r.codes)
            packed, nbits = entropy.encode_stream(rcb, r.codes)
            bits = nbits
            if use_zstd and nbits:
                zbits = zstd_size_bits(packed.tobytes())
                if zbits is not None:
                    bits = min(bits, zbits)
            payload += bits
            cb_bits += huffman.codebook_size_bits(rcb)
            r.payload_bits = bits
            r.codebook_bits = huffman.codebook_size_bits(rcb)
    return SHEResult(results=results, payload_bits=int(payload),
                     codebook_bits=int(cb_bits), meta_bits=int(meta),
                     codebook=cb)
