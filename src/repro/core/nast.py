"""Naive Sparse Tensor (NaST) — paper §III-B, Fig. 7.

The baseline partition strategy: (1) split into unit blocks, (2) drop the
empty ones, (3) linearize the survivors into a 4D array
``(n_blocks, u, u, u)``, (4) compress the 4D array.  Decompression scatters
the blocks back by their saved indices.

NaST completely removes empty space but sacrifices spatial locality — the
motivation for OpST (§III-B) and AKDTree (§III-C).
"""
from __future__ import annotations

import numpy as np

from .blocks import BlockGrid, make_block_grid

__all__ = ["nast_pack", "nast_unpack", "nast_meta_bits"]


def nast_pack(data: np.ndarray, mask: np.ndarray | None = None, *,
              unit: int = 8) -> tuple[np.ndarray, np.ndarray, BlockGrid]:
    """Returns (packed (n,u,u,u) array, block indices (n,3), grid)."""
    grid = make_block_grid(data, mask, unit=unit)
    u = grid.unit
    bx, by, bz = grid.bshape
    blocks = (grid.data.reshape(bx, u, by, u, bz, u)
                       .transpose(0, 2, 4, 1, 3, 5)
                       .reshape(bx * by * bz, u, u, u))
    idx = np.argwhere(grid.occ.reshape(-1)).ravel()
    coords = np.stack(np.unravel_index(idx, grid.bshape), axis=1)
    return blocks[idx], coords.astype(np.int32), grid


def nast_unpack(packed: np.ndarray, coords: np.ndarray, grid: BlockGrid) -> np.ndarray:
    u = grid.unit
    out = np.zeros(grid.data.shape, dtype=np.float32)
    for blk, (x, y, z) in zip(packed, coords):
        out[x * u:(x + 1) * u, y * u:(y + 1) * u, z * u:(z + 1) * u] = blk
    return out


def nast_meta_bits(coords: np.ndarray) -> int:
    """3×16-bit block coordinates per non-empty block + header."""
    return coords.shape[0] * 3 * 16 + 3 * 32
