"""Unit-block partitioning utilities (paper §III-A/B/C).

Every TAC pre-process strategy first partitions a level's 3D grid into
*unit blocks* (16³ in the paper for 512³ grids; scaled down here).  A unit
block is *empty* when no valid cell of the level falls inside it.  GSP pads
empty blocks, NaST/OpST/AKDTree remove them.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["BlockGrid", "SubBlock", "make_block_grid", "extract_subblock",
           "subblocks_tile_exactly"]


@dataclass
class BlockGrid:
    """A level partitioned into unit blocks."""

    data: np.ndarray          # the level's (padded) 3D data
    mask: np.ndarray          # validity mask, same shape
    unit: int                 # unit block edge length (cells)
    occ: np.ndarray           # (bx,by,bz) bool: unit block is non-empty
    counts: np.ndarray        # (bx,by,bz) int: valid cells per unit block

    @property
    def bshape(self) -> tuple[int, int, int]:
        return tuple(self.occ.shape)

    @property
    def n_blocks(self) -> int:
        return int(np.prod(self.occ.shape))

    @property
    def n_nonempty(self) -> int:
        return int(self.occ.sum())

    @property
    def block_density(self) -> float:
        """Fraction of non-empty unit blocks — the density that drives the
        hybrid strategy thresholds T0/T1/T2 (paper §III-E)."""
        return self.n_nonempty / max(self.n_blocks, 1)


def _pad_to_multiple(a: np.ndarray, unit: int, fill=0) -> np.ndarray:
    pads = [(0, (-s) % unit) for s in a.shape]
    if any(p[1] for p in pads):
        a = np.pad(a, pads, constant_values=fill)
    return a


def make_block_grid(data: np.ndarray, mask: np.ndarray | None = None, *,
                    unit: int = 8) -> BlockGrid:
    """Partition ``data`` into unit blocks (padding the grid up to a
    multiple of ``unit`` with empty cells if needed)."""
    if mask is None:
        mask = data != 0
    data = _pad_to_multiple(np.asarray(data), unit)
    mask = _pad_to_multiple(np.asarray(mask, dtype=bool), unit, fill=False)
    bx, by, bz = (s // unit for s in data.shape)
    m6 = mask.reshape(bx, unit, by, unit, bz, unit)
    counts = m6.sum(axis=(1, 3, 5)).astype(np.int64)
    occ = counts > 0
    return BlockGrid(data=data, mask=mask, unit=unit, occ=occ, counts=counts)


@dataclass
class SubBlock:
    """A cuboid of unit blocks extracted by OpST/AKDTree (block coords)."""

    origin: tuple[int, int, int]   # unit-block coordinates of the corner
    bsize: tuple[int, int, int]    # size in unit blocks per dim

    def cell_origin(self, unit: int) -> tuple[int, int, int]:
        return tuple(o * unit for o in self.origin)

    def cell_size(self, unit: int) -> tuple[int, int, int]:
        return tuple(s * unit for s in self.bsize)

    @property
    def n_units(self) -> int:
        return int(np.prod(self.bsize))

    def meta_bits(self) -> int:
        """Side-info cost of one sub-block: 3 coords + 3 sizes @16 bit."""
        return 6 * 16


def extract_subblock(grid: BlockGrid, sb: SubBlock) -> np.ndarray:
    ox, oy, oz = sb.cell_origin(grid.unit)
    sx, sy, sz = sb.cell_size(grid.unit)
    return grid.data[ox:ox + sx, oy:oy + sy, oz:oz + sz]


def subblocks_tile_exactly(grid: BlockGrid, subblocks: list[SubBlock]) -> bool:
    """Partition invariant (DESIGN.md §8.2): the sub-blocks cover every
    non-empty unit block exactly once and no empty unit block."""
    cover = np.zeros(grid.bshape, dtype=np.int32)
    for sb in subblocks:
        x, y, z = sb.origin
        dx, dy, dz = sb.bsize
        cover[x:x + dx, y:y + dy, z:z + dz] += 1
    return bool(((cover == 1) == grid.occ).all() and (cover <= 1).all())
