"""SZ error-bounded lossy compression core (paper §II-A), TPU-adapted.

The paper builds on two SZ algorithm families:

  * **Lor/Reg** (SZ2, [15]): block the data into 6³ blocks, per block pick a
    Lorenzo predictor or a linear-regression (plane-fit) predictor, quantize
    the prediction residual against the user error bound, Huffman-encode.
  * **Interp** (SZ3, [34]): global multi-level interpolation across the
    whole array, residual quantization, Huffman.

Hardware adaptation (DESIGN.md §3): classic SZ predicts from previously
*reconstructed* values — a loop-carried dependency in all three dims that
cannot be vectorized on the TPU VPU/MXU.  We use the established
**dual-quantization** parallelization (cuSZ): first pre-quantize
``q = round(x / (2·eb))`` element-wise (so ``|x − 2·eb·q| ≤ eb`` is already
guaranteed), then predict on the *exact integer grid* ``q`` — Lorenzo deltas
and interpolation residuals on integers are lossless, so the final error
bound is exactly the pre-quantization bound.  Every stage is now
embarrassingly parallel; the Pallas kernel in ``repro.kernels.lorenzo3d``
implements the fused prequant+delta hot loop for TPU.

Three compressors, one result type:

  * :func:`compress_lorenzo`   — global N-D Lorenzo on the integer grid
    (used on GSP-padded full grids and on TAC's merged 4D arrays, where the
    paper's cross-block-boundary artifact appears *by construction*).
  * :func:`compress_lor_reg`   — per-block self-contained Lorenzo-vs-
    regression with per-block choice: the faithful SZ2 analogue and the
    prediction stage of SHE (each block predicted independently, paper
    Alg. 4 line 4).
  * :func:`compress_interp`    — global multi-level linear interpolation on
    the integer grid: the faithful SZ3 "Interp" analogue.

Entropy stage: canonical Huffman (``repro.core.huffman``) + optional
Zstandard pass over the packed bitstream, exactly SZ's huffman+lossless
pipeline.  All sizes are measured from materialized bitstreams — no
estimated compression ratios.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import huffman
from .compat import zstd_size_bits
from ..obs import metrics as obsm

__all__ = [
    "SZResult",
    "prequant",
    "lorenzo_nd_codes",
    "lorenzo_nd_recon",
    "interp_nd_codes",
    "interp_nd_recon",
    "compress_lorenzo",
    "compress_lor_reg",
    "compress_lor_reg_batched",
    "compress_interp",
    "decode_codes",
    "decode_codes_batched",
    "entropy_bits",
    "entropy_stage",
    "reg_block_grid",
]

# --------------------------------------------------------------------------
# result container
# --------------------------------------------------------------------------


@dataclass
class SZResult:
    """One compressed array + exact storage accounting (bits)."""

    recon: np.ndarray          # reconstructed values (same shape as input)
    codes: np.ndarray          # int64 quantization-code stream (flattened)
    payload_bits: int          # entropy-coded code stream
    codebook_bits: int         # serialized Huffman codebook(s)
    meta_bits: int             # side info: coeffs, choices, dims, eb, ...
    eb: float
    method: str
    extras: dict = field(default_factory=dict)

    @property
    def total_bits(self) -> int:
        return int(self.payload_bits + self.codebook_bits + self.meta_bits)

    def compression_ratio(self, n_values: int | None = None,
                          dtype_bits: int = 32) -> float:
        n = int(np.prod(self.recon.shape)) if n_values is None else n_values
        return n * dtype_bits / max(self.total_bits, 1)


# --------------------------------------------------------------------------
# dual quantization
# --------------------------------------------------------------------------


def prequant(x: np.ndarray, eb: float) -> np.ndarray:
    """``q = round(x / (2 eb))`` — guarantees ``|x − 2 eb q| ≤ eb``.

    Precision note: the guarantee is exact in real arithmetic; the float32
    reconstruction adds at most one ulp of the value (≈ 2⁻²⁴·|x|), the same
    machine-precision caveat every SZ-family implementation carries for
    float32 outputs.  Tests assert ``err ≤ eb + 2⁻²²·max|x|``.
    """
    if eb <= 0:
        raise ValueError("error bound must be positive")
    return np.rint(np.asarray(x, dtype=np.float64) / (2.0 * eb)).astype(np.int64)


def dequant(q: np.ndarray, eb: float) -> np.ndarray:
    return (np.asarray(q, dtype=np.float64) * (2.0 * eb)).astype(np.float32)


# --------------------------------------------------------------------------
# N-D Lorenzo on the integer grid
# --------------------------------------------------------------------------


def lorenzo_nd_codes(q: np.ndarray, axes: tuple[int, ...] | None = None) -> np.ndarray:
    """Exact integer N-D Lorenzo delta: alternating first differences.

    Composing ``diff`` with zero-prepend along each axis yields the
    (-1)^(a+b+c) corner formula of the 3D Lorenzo predictor; it is its own
    generalization in any rank (the paper's 4D merged arrays included).
    """
    c = np.asarray(q, dtype=np.int64)
    axes = tuple(range(c.ndim)) if axes is None else axes
    for ax in axes:
        c = np.diff(c, axis=ax, prepend=0)
    return c


def lorenzo_nd_recon(codes: np.ndarray, axes: tuple[int, ...] | None = None) -> np.ndarray:
    """Inverse Lorenzo: N-D inclusive prefix sum (exact in integers)."""
    qr = np.asarray(codes, dtype=np.int64)
    axes = tuple(range(qr.ndim)) if axes is None else axes
    for ax in axes:
        qr = np.cumsum(qr, axis=ax)
    return qr


# --------------------------------------------------------------------------
# N-D multi-level interpolation on the integer grid (SZ3 "Interp")
# --------------------------------------------------------------------------


def _interp_schedule(shape: tuple[int, ...]) -> list[tuple[int, int]]:
    """(axis, stride) stages, coarsest level first, mirroring SZ3's
    level-by-level, axis-by-axis interpolation order."""
    max_dim = max(shape)
    s = 1
    while s < max_dim:
        s *= 2
    stages = []
    while s >= 2:
        for ax in range(len(shape)):
            stages.append((ax, s))
        s //= 2
    return stages


def _interp_stage_indices(dim: int, stride: int):
    """Midpoint + 4-point stencil indices for one axis stage.

    Returns (mids, left, right, left2, right2, cubic_ok): interior
    midpoints use SZ3's cubic spline stencil (−a + 9b + 9c − d)/16 over the
    known lattice at ±s/2 and ±3s/2; boundary midpoints degrade to linear,
    and a missing right neighbor degrades to copy-left.
    """
    half = stride // 2
    mids = np.arange(half, dim, stride)
    left = mids - half
    right_raw = mids + half
    has_right = right_raw < dim
    right = np.where(has_right, np.minimum(right_raw, dim - 1), left)
    left2_raw = mids - 3 * half
    right2_raw = mids + 3 * half
    cubic_ok = (left2_raw >= 0) & (right2_raw < dim) & has_right
    left2 = np.where(cubic_ok, np.maximum(left2_raw, 0), left)
    right2 = np.where(cubic_ok, np.minimum(right2_raw, dim - 1), right)
    return mids, left, right, left2, right2, cubic_ok


def _interp_predict(q: np.ndarray, ax: int, left, right, left2, right2,
                    cubic_ok) -> np.ndarray:
    """Replayable integer prediction: cubic where the stencil fits, else
    linear (floor averages — identical on encoder and decoder)."""
    ql = np.take(q, left, axis=ax)
    qr = np.take(q, right, axis=ax)
    lin = (ql + qr) >> 1
    qa = np.take(q, left2, axis=ax)
    qd = np.take(q, right2, axis=ax)
    # round-to-nearest of (−a + 9b + 9c − d)/16, exact in integers
    cub = (-qa + 9 * ql + 9 * qr - qd + 8) >> 4
    shape = [1] * q.ndim
    shape[ax] = len(cubic_ok)
    sel = cubic_ok.reshape(shape)
    return np.where(sel, cub, lin)


def interp_nd_codes(q: np.ndarray) -> np.ndarray:
    """Residual codes for global multi-level linear interpolation.

    Because prediction happens on the exact integer grid (dual-quant), the
    encoder needs no sequential reconstruction: every stage's predictors are
    true ``q`` values that the decoder will have recovered exactly.
    """
    q = np.asarray(q, dtype=np.int64)
    codes = q.copy()  # anchor points keep code == q (pred 0)
    # strides per axis, tracking the known lattice
    for ax, stride in _interp_schedule(q.shape):
        mids, left, right, left2, right2, cubic_ok = _interp_stage_indices(
            q.shape[ax], stride)
        if mids.size == 0:
            continue
        qm = np.take(q, mids, axis=ax)
        pred = _interp_predict(q, ax, left, right, left2, right2, cubic_ok)
        # write residuals at the midpoints; *but only at positions whose
        # other-axis indices are on the currently-known lattice* — handled
        # implicitly: stages for other axes overwrite later at finer strides,
        # and the final value each cell keeps is from the unique stage that
        # defines it (odd-multiple decomposition is unique).
        idx = [slice(None)] * q.ndim
        idx[ax] = mids
        codes[tuple(idx)] = qm - pred
    return codes


def interp_nd_recon(codes: np.ndarray) -> np.ndarray:
    """Decoder replay of :func:`interp_nd_codes` (exact)."""
    codes = np.asarray(codes, dtype=np.int64)
    q = codes.copy()  # anchors are already correct
    for ax, stride in _interp_schedule(codes.shape):
        mids, left, right, left2, right2, cubic_ok = _interp_stage_indices(
            codes.shape[ax], stride)
        if mids.size == 0:
            continue
        pred = _interp_predict(q, ax, left, right, left2, right2, cubic_ok)
        idx = [slice(None)] * codes.ndim
        idx[ax] = mids
        q[tuple(idx)] = pred + codes[tuple(idx)]
    return q


# --------------------------------------------------------------------------
# entropy stage: Huffman (+ optional zstd), real bitstreams
# --------------------------------------------------------------------------


def entropy_stage(codes: np.ndarray, *, use_zstd: bool = True,
                  codebook: huffman.Codebook | None = None,
                  engine: str = "auto") -> tuple[int, int, dict]:
    """(payload_bits, codebook_bits, artifacts) from a materialized bitstream.

    ``artifacts`` carries the codebook and the packed Huffman payload
    (``{"codebook", "packed", "nbits"}``) that pricing already materialized.
    The compressor front-ends stash it on ``SZResult.extras["entropy"]`` so
    the TACZ write path (``repro.io.writer.pack_level``) can serialize
    GSP/global levels without re-building the codebook and re-encoding the
    exact same payload (ROADMAP memoization item).  Retention note: the
    payload bytes are a small fraction of the ``codes`` array every
    SZResult already pins (int64 per value vs the entropy-coded stream),
    so accounting-only sweeps are not meaningfully taxed.

    Thin wrapper (kept for compatibility) over
    ``repro.core.entropy.EntropyEngine.encode_payloads`` for its single
    pooled stream; all engines produce identical bytes, so ``engine``
    only affects speed.
    """
    from . import entropy as _entropy

    codes = np.asarray(codes).ravel()
    if codes.size == 0:
        return 0, 0, {"codebook": None, "packed": b"", "nbits": 0}
    cb = codebook if codebook is not None else huffman.build_codebook(codes)
    (blob, nbits), = _entropy.get_engine(engine).encode_payloads(cb, [codes])
    payload = nbits
    if use_zstd:
        zbits = zstd_size_bits(blob)
        if zbits is not None:
            payload = min(payload, zbits)
    cb_bits = 0 if codebook is not None else huffman.codebook_size_bits(cb)
    return int(payload), int(cb_bits), {"codebook": cb, "packed": blob,
                                        "nbits": int(nbits)}


def entropy_bits(codes: np.ndarray, *, use_zstd: bool = True,
                 codebook: huffman.Codebook | None = None) -> tuple[int, int]:
    """(payload_bits, codebook_bits) from a materialized bitstream."""
    payload, cb_bits, _ = entropy_stage(codes, use_zstd=use_zstd,
                                        codebook=codebook)
    return payload, cb_bits


_DIM_META_BITS = 3 * 32 + 64  # dims + eb


# --------------------------------------------------------------------------
# compressor front-ends
# --------------------------------------------------------------------------


def compress_lorenzo(x: np.ndarray, eb: float, *, use_zstd: bool = True,
                     codebook: huffman.Codebook | None = None,
                     entropy_engine: str = "auto") -> SZResult:
    """Global N-D dual-quant Lorenzo (the TPU-kernel-backed path)."""
    x = np.asarray(x)
    q = prequant(x, eb)
    codes = lorenzo_nd_codes(q)
    payload, cb_bits, ent = entropy_stage(codes, use_zstd=use_zstd,
                                          codebook=codebook,
                                          engine=entropy_engine)
    recon = dequant(lorenzo_nd_recon(codes), eb).reshape(x.shape)
    return SZResult(recon=recon, codes=codes.ravel(), payload_bits=payload,
                    codebook_bits=cb_bits, meta_bits=_DIM_META_BITS, eb=eb,
                    method="lorenzo", extras={"entropy": ent})


def compress_interp(x: np.ndarray, eb: float, *, use_zstd: bool = True,
                    codebook: huffman.Codebook | None = None,
                    entropy_engine: str = "auto") -> SZResult:
    """Global multi-level interpolation (faithful SZ3 'Interp' analogue)."""
    x = np.asarray(x)
    q = prequant(x, eb)
    codes = interp_nd_codes(q)
    payload, cb_bits, ent = entropy_stage(codes, use_zstd=use_zstd,
                                          codebook=codebook,
                                          engine=entropy_engine)
    recon = dequant(interp_nd_recon(codes), eb).reshape(x.shape)
    return SZResult(recon=recon, codes=codes.ravel(), payload_bits=payload,
                    codebook_bits=cb_bits, meta_bits=_DIM_META_BITS, eb=eb,
                    method="interp", extras={"entropy": ent})


# ---------------------------- Lor/Reg (SZ2) --------------------------------


def _block_view(a: np.ndarray, b: int) -> np.ndarray:
    """(X,Y,Z) → (bx,by,bz, b,b,b) view after edge-replication padding."""
    pads = [(0, (-s) % b) for s in a.shape]
    if any(p[1] for p in pads):
        a = np.pad(a, pads, mode="edge")
    bx, by, bz = (s // b for s in a.shape)
    return (a.reshape(bx, b, by, b, bz, b)
             .transpose(0, 2, 4, 1, 3, 5)), (bx, by, bz)


def reg_block_grid(shape: tuple[int, ...], block: int
                   ) -> tuple[int, tuple[int, ...]]:
    """(block edge b, blocked-grid shape) for a brick's regression branch.

    This derivation is load-bearing for serialized data: the encoder's
    code layout, :func:`decode_codes`, and the TACZ reader's betas/prefix
    arithmetic must all agree on it, so it lives in exactly one place.
    """
    b = min(block, min(shape)) if min(shape) >= 2 else 1
    return b, tuple(-(-s // b) for s in shape)


def _fit_from_betas(betas: np.ndarray, b: int) -> np.ndarray:
    """Replay the plane fit from stored float32 betas (exact float64 eval).

    Shared by the encoder and :func:`decode_codes`, so a regression brick
    reconstructed from serialized (betas, codes) is bit-identical to the
    encoder-side recon.
    """
    coord = np.arange(b, dtype=np.float64) - (b - 1) / 2.0
    bf = np.asarray(betas).astype(np.float64)
    return (bf[..., 0, None, None, None]
            + bf[..., 1, None, None, None] * coord[:, None, None]
            + bf[..., 2, None, None, None] * coord[None, :, None]
            + bf[..., 3, None, None, None] * coord[None, None, :])


def _regression_fit(xb: np.ndarray, b: int) -> tuple[np.ndarray, np.ndarray]:
    """Closed-form per-block plane fit f = β0 + β1 i + β2 j + β3 k.

    ``xb``: (..., b, b, b) blocks.  Returns (betas float32 (...,4), fit).
    Coordinates are centered so the normal equations are diagonal — this is
    a pure batched-``einsum`` computation (MXU-friendly, DESIGN.md §3).
    The fit is evaluated from the *float32-cast* betas so the decoder can
    replay it exactly from the serialized coefficients.
    """
    coord = np.arange(b, dtype=np.float64) - (b - 1) / 2.0
    var = float((coord ** 2).sum()) * b * b  # Σ over block of (i-ī)²
    mean = xb.mean(axis=(-3, -2, -1), keepdims=True)
    xc = xb.astype(np.float64) - mean
    b1 = np.einsum("...ijk,i->...", xc, coord) / var
    b2 = np.einsum("...ijk,j->...", xc, coord) / var
    b3 = np.einsum("...ijk,k->...", xc, coord) / var
    betas = np.stack([mean[..., 0, 0, 0], b1, b2, b3], axis=-1).astype(np.float32)
    return betas, _fit_from_betas(betas, b)


def _reg_recon(betas: np.ndarray, codes_reg: np.ndarray, b: int,
               bgrid: tuple[int, int, int], orig_shape: tuple[int, ...],
               eb: float) -> np.ndarray:
    """Regression-branch reconstruction from (betas, codes) — the decode
    path of the serialized container, and the exact recon the encoder uses."""
    bx, by, bz = bgrid
    fit = _fit_from_betas(betas, b)
    recon_b = (fit + 2.0 * eb * np.asarray(codes_reg, dtype=np.int64)
               ).astype(np.float32)
    recon = (recon_b.reshape(bx, by, bz, b, b, b)
                    .transpose(0, 3, 1, 4, 2, 5)
                    .reshape(bx * b, by * b, bz * b))
    return recon[tuple(slice(0, s) for s in orig_shape)]


def _code_cost_bits(codes: np.ndarray, axis) -> np.ndarray:
    """Cheap per-block Huffman-size proxy: Elias-gamma-like magnitude bits."""
    return np.log2(1.0 + 2.0 * np.abs(codes.astype(np.float64))).sum(axis=axis) + 1.0


def compress_lor_reg(x: np.ndarray, eb: float, *, block: int = 6,
                     use_zstd: bool = True,
                     codebook: huffman.Codebook | None = None,
                     count_entropy: bool = True,
                     entropy_engine: str = "auto") -> SZResult:
    """SZ2 "Lor/Reg" analogue: Lorenzo vs. linear regression, chosen
    adaptively — at *brick* granularity.

    Faithfulness note (DESIGN.md §3): SZ2 chooses Lorenzo-vs-regression per
    6³ block, with Lorenzo crossing block borders through previously
    *reconstructed* values.  Under dual-quantization a per-6³ mixed choice
    would make Lorenzo neighbors of regression blocks decoder-inexact (the
    reason cuSZ dropped the regression branch entirely on GPUs).  We keep
    both predictors but hoist the choice to the whole brick:

      * **Lorenzo branch** — global dual-quant Lorenzo over the brick
        (boundary cost only at the brick's own faces, which is exactly the
        independence SHE requires per partition sub-block);
      * **Regression branch** — per-``block³`` closed-form plane fits with
        residual quantization (self-contained, decoder-exact, batched
        einsum → MXU-friendly).

    The cheaper branch (estimated bits, regression pays 4×32-bit
    coefficients per block) wins; 1 branch bit per brick.

    With ``count_entropy=False`` the entropy stage is skipped (payload left
    at 0) so SHE can pool this brick's codes into a shared codebook.
    """
    x = np.asarray(x)
    orig_shape = x.shape
    if x.ndim != 3:
        # operate on trailing 3D bricks (merged 4D arrays supported)
        lead = int(np.prod(x.shape[:-3]))
        x3 = x.reshape((lead,) + x.shape[-3:])
        parts = [compress_lor_reg(x3[i], eb, block=block, use_zstd=False,
                                  codebook=codebook, count_entropy=False)
                 for i in range(lead)]
        codes = np.concatenate([p.codes for p in parts])
        meta = sum(p.meta_bits for p in parts)
        payload = cb_bits = 0
        extras4: dict = {}
        if count_entropy:
            payload, cb_bits, ent = entropy_stage(codes, use_zstd=use_zstd,
                                                  codebook=codebook,
                                                  engine=entropy_engine)
            extras4["entropy"] = ent
        recon = np.stack([p.recon for p in parts]).reshape(orig_shape)
        return SZResult(recon=recon, codes=codes, payload_bits=payload,
                        codebook_bits=cb_bits, meta_bits=meta, eb=eb,
                        method="lor_reg", extras=extras4)

    b, _ = reg_block_grid(x.shape, block)
    # --- Lorenzo branch: global dual-quant Lorenzo over the brick ----------
    q = prequant(x, eb)
    codes_lor = lorenzo_nd_codes(q)
    cost_lor = float(_code_cost_bits(codes_lor, axis=None))

    # --- Regression branch: per-block plane fits ----------------------------
    # A 1³ "plane fit" is degenerate (zero coordinate variance → NaN betas),
    # so Lorenzo wins by construction; skip the wasted fit entirely.
    use_reg = False
    if b >= 2:
        xb, bgrid = _block_view(x, b)
        betas, fit = _regression_fit(xb, b)
        codes_reg = np.rint((xb - fit) / (2.0 * eb)).astype(np.int64)
        n_blocks = int(np.prod(bgrid))
        cost_reg = (float(_code_cost_bits(codes_reg, axis=None))
                    + n_blocks * 4 * 32)
        use_reg = cost_reg < cost_lor

    if use_reg:
        recon = _reg_recon(betas, codes_reg, b, bgrid, orig_shape, eb)
        codes = codes_reg
        meta = _DIM_META_BITS + 1 + n_blocks * 4 * 32
        method = "lor_reg/reg"
        extras = {"betas": betas, "branch": "reg"}
    else:
        recon = dequant(lorenzo_nd_recon(codes_lor), eb).reshape(orig_shape)
        codes = codes_lor
        meta = _DIM_META_BITS + 1
        method = "lor_reg/lorenzo"
        extras = {"branch": "lorenzo"}

    payload = cb_bits = 0
    if count_entropy:
        payload, cb_bits, ent = entropy_stage(codes, use_zstd=use_zstd,
                                              codebook=codebook,
                                              engine=entropy_engine)
        extras["entropy"] = ent
    return SZResult(recon=recon, codes=codes.ravel(), payload_bits=payload,
                    codebook_bits=cb_bits, meta_bits=meta, eb=eb,
                    method=method, extras=extras)


# ------------------------- decode from serialized codes ---------------------


def decode_codes(codes: np.ndarray, shape: tuple[int, ...], eb: float, *,
                 branch: str, block: int = 6,
                 betas: np.ndarray | None = None) -> np.ndarray:
    """Reconstruct an array from its quantization-code stream.

    This is the read path of the TACZ container: given the codes a
    ``compress_*`` front-end produced (plus the regression betas for the
    ``reg`` branch), replay the reconstruction **bit-identically** to the
    ``recon`` the compressor returned.

      * ``branch="lorenzo"`` — inverse of the global N-D Lorenzo codes
        (:func:`compress_lorenzo` and the Lorenzo branch of
        :func:`compress_lor_reg`), any rank.
      * ``branch="interp"``  — inverse of :func:`compress_interp`.
      * ``branch="reg"``     — regression branch of
        :func:`compress_lor_reg`; ``codes`` are the blocked residuals and
        ``betas`` the per-``block³`` plane coefficients (float32, shape
        ``(bx, by, bz, 4)``).
    """
    shape = tuple(int(s) for s in shape)
    codes = np.asarray(codes, dtype=np.int64)
    if branch == "lorenzo":
        return dequant(lorenzo_nd_recon(codes.reshape(shape)), eb)
    if branch == "interp":
        return dequant(interp_nd_recon(codes.reshape(shape)), eb)
    if branch == "reg":
        if betas is None:
            raise ValueError("regression branch needs betas")
        if len(shape) != 3:
            raise ValueError("regression branch decodes 3D bricks only")
        b, bgrid = reg_block_grid(shape, block)
        codes_reg = codes.reshape(tuple(bgrid) + (b, b, b))
        return _reg_recon(betas, codes_reg, b, bgrid, shape, eb)
    raise ValueError(f"unknown branch {branch!r}")


def decode_codes_batched(codes: np.ndarray, shape: tuple[int, ...],
                         eb: float, *, branch: str, block: int = 6,
                         betas: np.ndarray | None = None) -> np.ndarray:
    """Vectorized :func:`decode_codes` over a stack of same-shape bricks.

    ``codes``: (N, n_codes) — N bricks that share ``shape``, ``branch``,
    and ``eb`` (the grouping the serving-side decode planner produces);
    for ``branch="reg"``, ``betas`` is the matching (N, bx, by, bz, 4)
    coefficient stack.  Returns an (N, \\*shape) float32 reconstruction
    whose every slice is **bit-identical** to
    ``decode_codes(codes[i], shape, eb, ...)`` — the Lorenzo prefix sums
    and the regression replay run once across the batch axis instead of
    once per brick (the same vectorization the encode side got in PR 1).
    The interp branch keeps a per-item loop: its stage schedule is a
    function of the array rank, and interp only ever appears as a single
    global payload per level.
    """
    shape = tuple(int(s) for s in shape)
    codes = np.ascontiguousarray(codes, dtype=np.int64)
    if codes.ndim != 2:
        raise ValueError("expected a (N, n_codes) stack of code streams")
    n = codes.shape[0]
    if branch == "lorenzo":
        stacked = codes.reshape((n,) + shape)
        axes = tuple(range(1, len(shape) + 1))
        return dequant(lorenzo_nd_recon(stacked, axes=axes), eb)
    if branch == "interp":
        if n == 0:
            return np.zeros((0,) + shape, dtype=np.float32)
        return np.stack([dequant(interp_nd_recon(codes[i].reshape(shape)),
                                 eb) for i in range(n)])
    if branch == "reg":
        if betas is None:
            raise ValueError("regression branch needs betas")
        if len(shape) != 3:
            raise ValueError("regression branch decodes 3D bricks only")
        b, bgrid = reg_block_grid(shape, block)
        bx, by, bz = bgrid
        codes_reg = codes.reshape((n,) + tuple(bgrid) + (b, b, b))
        fit = _fit_from_betas(np.asarray(betas), b)
        rr = (fit + 2.0 * eb * codes_reg).astype(np.float32)
        rr = (rr.reshape(n, bx, by, bz, b, b, b)
                .transpose(0, 1, 4, 2, 5, 3, 6)
                .reshape(n, bx * b, by * b, bz * b))
        return rr[(slice(None),) + tuple(slice(0, s) for s in shape)]
    raise ValueError(f"unknown branch {branch!r}")


# ----------------------- batched Lor/Reg (SHE hot path) ---------------------


def _block_view_batched(a: np.ndarray, b: int) -> tuple[np.ndarray, tuple[int, int, int]]:
    """(N,X,Y,Z) → (N, bx,by,bz, b,b,b) view after per-brick edge padding.

    Per-brick this is exactly :func:`_block_view`; the padding and the
    transpose never mix values across the leading batch axis.
    """
    pads = [(0, 0)] + [(0, (-s) % b) for s in a.shape[1:]]
    if any(p[1] for p in pads):
        a = np.pad(a, pads, mode="edge")
    n = a.shape[0]
    bx, by, bz = (s // b for s in a.shape[1:])
    return (a.reshape(n, bx, b, by, b, bz, b)
             .transpose(0, 1, 3, 5, 2, 4, 6)), (bx, by, bz)


def _code_cost_bits_rows(codes: np.ndarray) -> np.ndarray:
    """Per-brick :func:`_code_cost_bits`: sum over everything but axis 0.

    ``codes`` must be C-contiguous so each brick's row reduction adds the
    same values in the same (pairwise) order as the sequential per-brick
    ``sum(axis=None)`` — keeping the batched branch scores bit-identical.
    """
    mag = np.log2(1.0 + 2.0 * np.abs(np.ascontiguousarray(codes)
                                     .astype(np.float64)))
    return mag.reshape(mag.shape[0], -1).sum(axis=1) + 1.0


def _tpu_attached() -> bool:
    """True when JAX's default backend is a real TPU (ROADMAP open item:
    the batched Lorenzo branch routes through the Pallas kernel there)."""
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present in-repo
        return False


# One brick must fit in one VMEM tile (the kernel's zero-halo is per tile,
# so tile == brick is the independence contract); this is the default tile's
# footprint budget from repro.kernels.lorenzo3d.
_MAX_PALLAS_BRICK = 8 * 128 * 128
# The kernel quantizes as rint(x · float32(1/2eb)) in float32 and stores
# int32 codes; the error-bound guarantee needs the quantized integers to be
# float32-exact, i.e. |x|/(2eb) < 2^24 (one bit of margin kept).
_MAX_PALLAS_Q = float(2 ** 23)


def _lorenzo_codes_batched_pallas(x: np.ndarray, eb: float) -> np.ndarray | None:
    """Fused prequant+Lorenzo via ``repro.kernels.lorenzo3d`` (batched).

    The tile is the whole brick — the kernel computes a zero-halo Lorenzo
    per tile, so tile == brick is what makes each sub-block's prediction
    self-contained (Alg. 4 line 4).  Returns None (callers fall back to
    the numpy oracle) when a brick exceeds the VMEM tile budget or when
    the quantized magnitudes exceed the float32-exact integer range — past
    that the kernel's float32/int32 arithmetic would break the error
    bound rather than merely differ in last-ulp rounding.  The numpy host
    path stays the bit-exact float64/int64 oracle.
    """
    shape = tuple(int(s) for s in x.shape[1:])
    if int(np.prod(shape)) > _MAX_PALLAS_BRICK:
        return None
    if float(np.abs(x).max(initial=0.0)) / (2.0 * eb) >= _MAX_PALLAS_Q:
        return None
    from repro.kernels import ops

    codes = ops.lorenzo3d_codes_batched(x.astype(np.float32), eb=float(eb),
                                        tile=shape)
    return np.asarray(codes).astype(np.int64)


def compress_lor_reg_batched(x: np.ndarray, eb: float, *, block: int = 6,
                             engine: str = "auto") -> list[SZResult]:
    """Batched :func:`compress_lor_reg` over a stack of same-shape bricks.

    ``x``: (N, X, Y, Z) — N independent 3D bricks (e.g. one padded-shape
    group of SHE sub-blocks).  Every stage of the per-brick compressor is
    vectorized across the leading axis with identical arithmetic, so each
    returned :class:`SZResult` is bit-identical (codes, recon, meta, branch
    choice) to ``compress_lor_reg(x[i], eb, block=block,
    count_entropy=False)`` — the sequential path stays the oracle.

    ``engine`` selects the Lorenzo-branch *codes* backend: ``"numpy"`` is
    the bit-exact host oracle; ``"pallas"`` routes the fused
    prequant+Lorenzo through the batched Pallas kernel — float32/int32
    on-device arithmetic, falling back to numpy when a brick exceeds the
    VMEM tile budget or the float32-exact quantization range.  ``"auto"``
    (default) picks ``"pallas"`` when a TPU backend is attached and
    ``"numpy"`` otherwise.  Reconstruction always uses the float64 host
    dequant (the same arithmetic ``decode_codes`` replays), so serialized
    codes round-trip bit-identically to ``recon`` on every backend.

    The entropy stage is intentionally left to the caller (payloads are 0):
    SHE pools all bricks' codes under one shared codebook (paper Alg. 4),
    so pricing them here would be wasted work.
    """
    x = np.asarray(x)
    if x.ndim != 4:
        raise ValueError("expected a (N, X, Y, Z) stack of 3D bricks")
    if engine not in ("auto", "numpy", "pallas"):
        raise ValueError(f"unknown engine {engine!r}")
    n = x.shape[0]
    if n == 0:
        return []
    bshape = x.shape[1:]
    b, _ = reg_block_grid(bshape, block)

    # --- Lorenzo branch: zero-halo dual-quant Lorenzo per brick ------------
    with obsm.timed(obsm.COMPRESS_STAGE_SECONDS.labels("prequant"),
                    "prequant"):
        if engine == "auto":
            engine = "pallas" if _tpu_attached() else "numpy"
        codes_lor = None
        if engine == "pallas":
            codes_lor = _lorenzo_codes_batched_pallas(x, eb)
            if codes_lor is None:
                engine = "numpy"
        if codes_lor is None:
            codes_lor = lorenzo_nd_codes(prequant(x, eb), axes=(1, 2, 3))

    # --- Regression branch: per-block plane fits + branch scoring ----------
    # Degenerate b == 1 (zero coordinate variance → NaN betas) can never
    # beat Lorenzo; skip the fit, matching the sequential path.
    with obsm.timed(obsm.COMPRESS_STAGE_SECONDS.labels("branch_score"),
                    "branch_score"):
        cost_lor = _code_cost_bits_rows(codes_lor)
        n_blocks = 0
        if b >= 2:
            xb, bgrid = _block_view_batched(x, b)
            betas, fit = _regression_fit(xb, b)
            codes_reg = np.rint((xb - fit) / (2.0 * eb)).astype(np.int64)
            n_blocks = int(np.prod(bgrid))
            cost_reg = _code_cost_bits_rows(codes_reg) + n_blocks * 4 * 32
            use_reg = cost_reg < cost_lor
        else:
            use_reg = np.zeros(n, dtype=bool)

    # --- per-brick branch choice: reconstruct only the winning branch ------
    recon = np.empty(x.shape, dtype=np.float32)
    lor_idx = np.flatnonzero(~use_reg)
    reg_idx = np.flatnonzero(use_reg)
    if lor_idx.size:
        # recon always goes through the float64 host dequant — the same
        # arithmetic decode_codes replays — so a container written from
        # kernel-produced codes round-trips bit-identically on any backend
        # (the kernel accelerates the codes hot loop; dequant is cheap)
        recon[lor_idx] = dequant(
            lorenzo_nd_recon(codes_lor[lor_idx], axes=(1, 2, 3)), eb)
    if reg_idx.size:
        bx, by, bz = bgrid
        rr = (fit[reg_idx] + 2.0 * eb * codes_reg[reg_idx]).astype(np.float32)
        rr = (rr.reshape(len(reg_idx), bx, by, bz, b, b, b)
                .transpose(0, 1, 4, 2, 5, 3, 6)
                .reshape(len(reg_idx), bx * b, by * b, bz * b))
        recon[reg_idx] = rr[(slice(None),)
                            + tuple(slice(0, s) for s in bshape)]

    out: list[SZResult] = []
    for i in range(n):
        if use_reg[i]:
            out.append(SZResult(
                recon=recon[i], codes=codes_reg[i].ravel().copy(),
                payload_bits=0, codebook_bits=0,
                meta_bits=_DIM_META_BITS + 1 + n_blocks * 4 * 32, eb=eb,
                method="lor_reg/reg",
                extras={"betas": betas[i], "branch": "reg"}))
        else:
            out.append(SZResult(
                recon=recon[i], codes=codes_lor[i].ravel().copy(),
                payload_bits=0, codebook_bits=0,
                meta_bits=_DIM_META_BITS + 1, eb=eb,
                method="lor_reg/lorenzo", extras={"branch": "lorenzo"}))
    return out
