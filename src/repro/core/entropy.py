"""Batched entropy stage: one engine surface over Huffman encode/decode.

Entropy coding used to be reachable through three divergent ad-hoc
surfaces (``huffman.encode/decode``, ``she.encode_brick_payloads/
decode_brick_payloads``, ``sz.entropy_stage``), each looping Python-level
per payload — the last stage of the pipeline still bit-serial after the
Lorenzo/regression engines were batched.  This module consolidates them
behind one :class:`EntropyEngine` protocol with the same engine pattern
as ``sz.compress_lor_reg_batched``:

  * ``"numpy"``   — the serial, bit-exact oracle (:func:`encode_stream` /
    :func:`decode_stream`, the bodies that used to live in
    ``repro.core.huffman``);
  * ``"batched"`` — vectorized numpy: encode packs ALL payloads in one
    offset-scatter pass over the pooled symbol stream, decode runs a
    canonical-Huffman interval walk over a stacked window matrix
    (symbols within a stream stay sequential, streams advance in
    lockstep);
  * ``"pallas"``  — the window matrix is built by the
    ``repro.kernels.huffdec`` Pallas kernel and the decode walk runs as
    a jitted ``lax.scan`` on the accelerator; encode shares the batched
    host path (bit packing is memory-bound scatter, not FLOPs);
  * ``"auto"``    — ``"pallas"`` when a TPU backend is attached,
    ``"batched"`` otherwise.

Every engine is bit-identical to the oracle: encoded payload bytes match
``huffman.encode`` byte-for-byte (each payload is laid out at its own
byte-aligned offset of the pooled bitstream, so per-payload ``packbits``
padding is reproduced exactly), and the batched decoder reproduces the
oracle's outputs *and errors* — including the degenerate empty/
single-symbol codebooks and the exact truncated-vs-corrupt distinction
of the serial bit walk (see :func:`_decode_batched`).
"""
from __future__ import annotations

import numpy as np

from . import huffman

__all__ = [
    "ENGINE_NAMES",
    "EntropyEngine",
    "NumpyEngine",
    "BatchedEngine",
    "PallasEngine",
    "get_engine",
    "encode_stream",
    "decode_stream",
]

ENGINE_NAMES = ("auto", "numpy", "batched", "pallas")

# Batched-decode guards: below _MIN_BATCH payloads the per-step numpy
# dispatch overhead loses to the serial walk (results are identical either
# way, so this is purely a heuristic); window values are built in int64,
# so code lengths must leave headroom for the shift-or; the window matrix
# is (payloads, max_bits) int64 — past the element budget fall back to the
# serial walk rather than blow memory (single huge gsp payloads take this
# path, and they are exactly the A=1 case batching cannot help anyway).
_MIN_BATCH = 4
_MAX_BATCH_MAXLEN = 57
_MAX_WINDOW_ELEMS = 1 << 27
# Pallas windows are int32 and the kernel pads the bit matrix into VMEM
# tiles — much tighter budgets than the host path's.
_MAX_PALLAS_MAXLEN = 30
_MAX_PALLAS_WINDOW_ELEMS = 1 << 24


# --------------------------------------------------------------------------
# serial primitives — the bit-exact oracle (moved from repro.core.huffman)
# --------------------------------------------------------------------------


def encode_stream(cb: huffman.Codebook, data: np.ndarray, *,
                  indices: np.ndarray | None = None,
                  ) -> tuple[np.ndarray, int]:
    """Encode one symbol stream.  Returns (packed uint8 bitstream, nbits).

    This is the oracle ``huffman.encode`` wraps: offset-scatter bit
    packing — codeword i occupies ``[start_i, start_i + len_i)`` and one
    vectorized pass per bit position fills the dense bitstream.
    ``indices`` may carry a precomputed ``huffman.symbol_indices`` result.
    """
    data = np.asarray(data, dtype=np.int64).ravel()
    if data.size == 0:
        return np.zeros(0, dtype=np.uint8), 0
    idx = huffman.symbol_indices(cb, data) if indices is None else indices
    codes = cb.codes[idx]
    lens = cb.lengths[idx]
    maxlen = int(lens.max())
    ends = np.cumsum(lens)
    starts = ends - lens
    nbits = int(ends[-1])
    bitstream = np.zeros(nbits, dtype=np.uint8)
    sel = np.ones(data.size, dtype=bool)
    for j in range(maxlen):
        if j > 0:
            sel = lens > j
            if not sel.any():
                break
        c, l, s = codes[sel], lens[sel], starts[sel]
        bitstream[s + j] = (c >> (l - 1 - j)) & 1
    packed = np.packbits(bitstream)
    return packed, nbits


def decode_stream(cb: huffman.Codebook, packed: np.ndarray, nbits: int,
                  n_symbols: int) -> np.ndarray:
    """Decode ``n_symbols`` from one packed bitstream (canonical walk).

    This is the oracle ``huffman.decode`` wraps and every batched engine
    is pinned against — its exact error behavior (``"truncated
    bitstream"`` when the stream ends mid-codeword, ``"corrupt
    bitstream"`` when ``maxlen`` bits match nothing, empty/single-symbol
    degenerate codebooks) is part of the engine contract.
    """
    if n_symbols == 0:
        return np.zeros(0, dtype=np.int64)
    symbols = cb.symbols
    if len(symbols) == 0:
        raise ValueError("cannot decode symbols with an empty codebook")
    bits = np.unpackbits(np.asarray(packed, dtype=np.uint8))[:nbits]
    nbits = min(int(nbits), bits.size)
    out = np.empty(n_symbols, dtype=np.int64)
    if len(symbols) == 1:
        # degenerate: single-symbol alphabet, 1 bit per symbol on the wire
        if nbits < n_symbols:
            raise ValueError("truncated bitstream")
        out[:] = symbols[0]
        return out
    maxlen = cb.max_length
    first_code = cb.first_code
    first_index = cb.first_index
    count = cb.count
    i = 0
    bl = bits.tolist()  # python ints — much faster to index than np scalars
    for k in range(n_symbols):
        code = 0
        l = 0
        while True:
            if i >= nbits:
                raise ValueError("truncated bitstream")
            code = (code << 1) | bl[i]
            i += 1
            l += 1
            if l > maxlen:
                raise ValueError("corrupt bitstream")
            c0 = first_code[l]
            if count[l] and code - c0 < count[l] and code >= c0:
                out[k] = symbols[first_index[l] + (code - c0)]
                break
    return out


# --------------------------------------------------------------------------
# payload plumbing
# --------------------------------------------------------------------------


def _as_u8(buf) -> np.ndarray:
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    return np.asarray(buf, dtype=np.uint8).ravel()


def _triples(payloads, n_codes) -> list[tuple[np.ndarray, int, int]]:
    """Normalize decode inputs to ``(uint8 buf, nbits, n_codes)`` triples.

    ``payloads`` may be ``(buf, nbits, n_codes)`` triples (the
    ``she.decode_brick_payloads`` shape) or ``(buf, nbits)`` pairs with a
    separate per-payload ``n_codes`` sequence.
    """
    out = []
    if n_codes is None:
        for buf, nbits, nc in payloads:
            out.append((_as_u8(buf), int(nbits), int(nc)))
    else:
        for (buf, nbits), nc in zip(payloads, n_codes, strict=True):
            out.append((_as_u8(buf), int(nbits), int(nc)))
    return out


def _decode_tables(cb: huffman.Codebook):
    """(present lengths, interval uppers, maxlen) — the canonical-decode
    acceleration tables of the batched interval walk.

    Left-justified to ``maxlen`` bits, the windows starting with a
    length-``l`` codeword occupy the half-open interval
    ``[fc_l << (maxlen-l), (fc_l + count_l) << (maxlen-l))``; canonical
    code assignment makes consecutive intervals adjacent and the first
    start at 0, so a single ``searchsorted`` over the interval uppers
    finds the (unique, prefix-free) code length of any window — or lands
    past the last upper for the codeword-free gap an incomplete
    (deserialized) codebook leaves at the top of the range.
    """
    maxlen = cb.max_length
    ls = np.flatnonzero(cb.count[1:maxlen + 1]) + 1
    uppers = ((cb.first_code[ls] + cb.count[ls]).astype(np.int64)
              << (maxlen - ls))
    return ls.astype(np.int64), uppers, maxlen


def _bit_matrix(triples, maxlen: int, pad: int = 0,
                ) -> tuple[np.ndarray, np.ndarray]:
    """(A, max_nbits + maxlen + pad) 0/1 matrix + effective nbits per row.

    Row ``a`` holds payload ``a``'s first ``nbits_a`` bits; everything
    past them is zero (the serial oracle never reads those positions, so
    the zero padding only has to keep the window math in range — the
    walk's error rules make padded windows reproduce the oracle's
    truncation errors, see :func:`_decode_batched`).
    """
    nbits_eff = np.array([min(nb, 8 * buf.size) for buf, nb, _ in triples],
                         dtype=np.int64)
    width = int(nbits_eff.max(initial=0)) + maxlen + pad
    bits = np.zeros((len(triples), width), dtype=np.uint8)
    for a, (buf, _, _) in enumerate(triples):
        nb = int(nbits_eff[a])
        if nb:
            bits[a, :nb] = np.unpackbits(buf, count=nb)
    return bits, nbits_eff


def _window_matrix(bits: np.ndarray, maxlen: int, width: int) -> np.ndarray:
    """``W[a, t]`` = the ``maxlen``-bit window of row ``a`` at bit ``t``,
    as an int64 — ``maxlen`` shift-or passes over the bit matrix."""
    w = np.zeros((bits.shape[0], width), dtype=np.int64)
    for j in range(maxlen):
        w = (w << 1) | bits[:, j:j + width]
    return w


def _raise_payload_error(err_kind: np.ndarray) -> None:
    """Raise the oracle's error for the lowest-index failed payload."""
    bad = np.flatnonzero(err_kind)
    if bad.size:
        kind = int(err_kind[bad[0]])
        raise ValueError("corrupt bitstream" if kind == 2
                         else "truncated bitstream")


def _decode_batched(cb: huffman.Codebook, triples) -> list[np.ndarray]:
    """Vectorized canonical decode of many payloads under one codebook.

    Streams advance in lockstep: each step gathers every live stream's
    current ``maxlen``-bit window, finds its code length with one
    ``searchsorted`` over the interval uppers, and emits one symbol per
    stream.  Error parity with the serial oracle:

      * an accepted codeword that would consume bits past the payload's
        ``nbits`` → ``"truncated bitstream"`` (the oracle hits its
        ``i >= nbits`` check mid-codeword);
      * a window in the codeword-free gap → ``"corrupt bitstream"`` only
        when ``nbits - pos >= maxlen + 1`` (the oracle must successfully
        read ``maxlen + 1`` bits to trip its ``l > maxlen`` check),
        otherwise ``"truncated bitstream"`` — this is what makes the
        zero-padded windows of the stacked matrix safe;
      * with several failing payloads, the error raised is the
        lowest-index one (the oracle iterates payloads in list order).
    """
    ls, uppers, maxlen = _decode_tables(cb)
    symbols = cb.symbols
    first_code = cb.first_code.astype(np.int64)
    first_index = cb.first_index.astype(np.int64)

    bits, nbits_arr = _bit_matrix(triples, maxlen, pad=1)
    width = int(nbits_arr.max(initial=0)) + 1
    wm = _window_matrix(bits, maxlen, width)

    A = len(triples)
    ncodes_arr = np.array([nc for _, _, nc in triples], dtype=np.int64)
    out = np.zeros((A, int(ncodes_arr.max(initial=0))), dtype=np.int64)
    pos = np.zeros(A, dtype=np.int64)
    err_kind = np.zeros(A, dtype=np.int8)     # 0 ok, 1 truncated, 2 corrupt
    rows = np.arange(A)
    for k in range(out.shape[1]):
        act = (k < ncodes_arr) & (err_kind == 0)
        if not act.any():
            break
        r = rows[act]
        w = wm[r, pos[act]]
        ii = np.searchsorted(uppers, w, side="right")
        valid = ii < len(ls)
        l = ls[np.minimum(ii, len(ls) - 1)]
        rem = nbits_arr[r] - pos[r]
        fits = l <= rem
        ok = valid & fits
        corrupt = ~valid & (rem >= maxlen + 1)
        err_kind[r] = np.where(corrupt, 2,
                               np.where(ok, 0, 1)).astype(np.int8)
        okr, lok, wok = r[ok], l[ok], w[ok]
        sym_idx = first_index[lok] + (wok >> (maxlen - lok)) - first_code[lok]
        out[okr, k] = symbols[sym_idx]
        pos[okr] += lok
    _raise_payload_error(err_kind)
    return [out[a, :ncodes_arr[a]].copy() for a in range(A)]


# --------------------------------------------------------------------------
# engines
# --------------------------------------------------------------------------


class EntropyEngine:
    """Protocol: batch entropy coding under one shared codebook.

    ``encode_payloads(cb, streams)`` → one ``(payload bytes, nbits)``
    pair per symbol stream, byte-identical to per-stream
    ``huffman.encode`` + ``packbits`` padding (the TACZ payload framing).
    ``decode_payloads(cb, payloads, n_codes=None)`` → one int64 code
    array per payload; ``payloads`` are ``(buf, nbits, n_codes)``
    triples, or ``(buf, nbits)`` pairs with ``n_codes`` given separately.
    Implementations must match the serial oracle bit-for-bit, errors
    included.
    """

    name = "abstract"

    def encode_payloads(self, cb: huffman.Codebook,
                        streams) -> list[tuple[bytes, int]]:
        raise NotImplementedError

    def decode_payloads(self, cb: huffman.Codebook, payloads,
                        n_codes=None) -> list[np.ndarray]:
        raise NotImplementedError


class NumpyEngine(EntropyEngine):
    """The serial reference: one oracle call per payload."""

    name = "numpy"

    def encode_payloads(self, cb, streams):
        out = []
        for s in streams:
            packed, nbits = encode_stream(cb, np.asarray(s, dtype=np.int64))
            out.append((packed.tobytes(), int(nbits)))
        return out

    def decode_payloads(self, cb, payloads, n_codes=None):
        return [decode_stream(cb, buf, nbits, nc)
                for buf, nbits, nc in _triples(payloads, n_codes)]


class BatchedEngine(EntropyEngine):
    """Vectorized numpy: whole-batch encode scatter + lockstep decode."""

    name = "batched"

    def encode_payloads(self, cb, streams):
        streams = [np.asarray(s, dtype=np.int64).ravel() for s in streams]
        sizes = np.array([s.size for s in streams], dtype=np.int64)
        pooled = (np.concatenate(streams) if streams
                  else np.zeros(0, dtype=np.int64))
        if pooled.size == 0:
            return [(b"", 0)] * len(streams)
        # one lookup pass over the pooled stream (the codebook sort inside
        # symbol_indices is paid once, not once per payload)
        idx = huffman.symbol_indices(cb, pooled)
        lens = cb.lengths[idx]
        codes = cb.codes[idx]
        maxlen = int(lens.max())
        cum_bits = np.concatenate(([0], np.cumsum(lens)))
        bounds = np.cumsum(sizes)
        start_sym = bounds - sizes
        nbits_p = cum_bits[bounds] - cum_bits[start_sym]
        bytelen_p = (nbits_p + 7) // 8
        base_bits = 8 * np.concatenate(([0], np.cumsum(bytelen_p)))[:-1]
        # global bit offset of every codeword: its offset inside its own
        # payload's bitstream, shifted to the payload's byte-aligned base —
        # the inter-payload gap bits stay 0, exactly the zero padding
        # per-payload packbits would have emitted, so the sliced bytes are
        # identical to the serial framing
        stream_of = np.repeat(np.arange(len(streams)), sizes)
        starts = (cum_bits[:-1] - cum_bits[start_sym][stream_of]
                  + base_bits[stream_of])
        total_bytes = int(bytelen_p.sum())
        bitstream = np.zeros(total_bytes * 8, dtype=np.uint8)
        sel = np.ones(pooled.size, dtype=bool)
        for j in range(maxlen):
            if j > 0:
                sel = lens > j
                if not sel.any():
                    break
            c, l, s = codes[sel], lens[sel], starts[sel]
            bitstream[s + j] = (c >> (l - 1 - j)) & 1
        packed = np.packbits(bitstream)
        out = []
        for p in range(len(streams)):
            b0 = int(base_bits[p]) // 8
            out.append((packed[b0:b0 + int(bytelen_p[p])].tobytes(),
                        int(nbits_p[p])))
        return out

    def decode_payloads(self, cb, payloads, n_codes=None):
        triples = self._serial_or_none(cb, _triples(payloads, n_codes))
        if isinstance(triples, list) and triples and \
                isinstance(triples[0], np.ndarray):
            return triples
        return _decode_batched(cb, triples)

    def _serial_or_none(self, cb, triples):
        """Serial fallback (identical results) for the cases batching
        cannot help: degenerate codebooks, tiny batches, over-deep codes,
        or a window matrix past the memory budget."""
        if self._use_serial(cb, triples):
            return [decode_stream(cb, buf, nbits, nc)
                    for buf, nbits, nc in triples]
        return triples

    @staticmethod
    def _use_serial(cb, triples, *, min_batch: int = _MIN_BATCH,
                    max_maxlen: int = _MAX_BATCH_MAXLEN,
                    max_elems: int = _MAX_WINDOW_ELEMS) -> bool:
        if len(cb.symbols) <= 1 or len(triples) < min_batch:
            return True
        if cb.max_length > max_maxlen:
            return True
        max_bits = max((min(nb, 8 * buf.size) for buf, nb, _ in triples),
                       default=0)
        return len(triples) * (max_bits + cb.max_length + 1) > max_elems


class PallasEngine(BatchedEngine):
    """Decode through the ``repro.kernels.huffdec`` window kernel + jitted
    scan walk; encode shares the batched host scatter (bit packing is a
    memory-bound byte shuffle — there is no FLOP win to move)."""

    name = "pallas"

    def decode_payloads(self, cb, payloads, n_codes=None):
        triples = _triples(payloads, n_codes)
        if self._use_serial(cb, triples):
            return [decode_stream(cb, buf, nbits, nc)
                    for buf, nbits, nc in triples]
        max_bits = max(min(nb, 8 * buf.size) for buf, nb, _ in triples)
        if (cb.max_length > _MAX_PALLAS_MAXLEN
                or len(triples) * (max_bits + cb.max_length + 1)
                > _MAX_PALLAS_WINDOW_ELEMS):
            return _decode_batched(cb, triples)
        from repro.kernels import huffdec, ops

        ls, uppers, maxlen = _decode_tables(cb)
        bits, nbits_arr = _bit_matrix(triples, maxlen, pad=1)
        width = int(nbits_arr.max(initial=0)) + 1
        wm = ops.huffdec_windows(bits, maxlen=maxlen, width=width)
        ncodes_arr = np.array([nc for _, _, nc in triples], dtype=np.int64)
        steps = int(ncodes_arr.max(initial=0))
        if steps == 0:
            return [np.zeros(0, dtype=np.int64) for _ in triples]
        sidx, err_kind = huffdec.decode_walk(
            wm, nbits_arr.astype(np.int32), ncodes_arr.astype(np.int32),
            uppers.astype(np.int32), ls.astype(np.int32),
            cb.first_code.astype(np.int32), cb.first_index.astype(np.int32),
            maxlen=maxlen, steps=steps)
        _raise_payload_error(np.asarray(err_kind))
        # symbol values stay int64 on the host: the walk returns codebook
        # row indices, which always fit the device's int32 lanes
        sidx = np.asarray(sidx)
        out = []
        for a, nc in enumerate(ncodes_arr):
            nc = int(nc)
            row = np.zeros(nc, dtype=np.int64)
            if nc:
                row[:] = cb.symbols[np.clip(sidx[a, :nc], 0,
                                            len(cb.symbols) - 1)]
            out.append(row)
        return out


_ENGINES: dict[str, EntropyEngine] = {}


def get_engine(name: str | EntropyEngine = "auto") -> EntropyEngine:
    """Resolve an entropy engine, mirroring the Lorenzo engine selection.

    ``"auto"`` picks ``"pallas"`` when a TPU backend is attached (same
    probe as ``sz.compress_lor_reg_batched``) and ``"batched"``
    otherwise; an :class:`EntropyEngine` instance passes through
    unchanged.  Instances are cached — engines are stateless.
    """
    if isinstance(name, EntropyEngine):
        return name
    if name not in ENGINE_NAMES:
        raise ValueError(f"unknown entropy engine {name!r} "
                         f"(expected one of {ENGINE_NAMES})")
    if name == "auto":
        from .sz import _tpu_attached
        name = "pallas" if _tpu_attached() else "batched"
    eng = _ENGINES.get(name)
    if eng is None:
        eng = _ENGINES.setdefault(
            name, {"numpy": NumpyEngine, "batched": BatchedEngine,
                   "pallas": PallasEngine}[name]())
    return eng


def check_engine_name(name: str | EntropyEngine) -> None:
    """Fail-fast name validation without resolving ``"auto"`` (resolution
    may probe accelerator backends — writers validate at construction but
    resolve lazily, the ``ParallelTACZWriter`` fork-safety pattern)."""
    if not isinstance(name, EntropyEngine) and name not in ENGINE_NAMES:
        raise ValueError(f"unknown entropy engine {name!r} "
                         f"(expected one of {ENGINE_NAMES})")
