"""Batched serving engine: prefill + iterative decode with a (optionally
int8-quantized) KV cache / recurrent state.

``make_prefill_step`` / ``make_serve_step`` are the jit'd units the
dry-run lowers for the ``prefill_32k`` / ``decode_32k`` / ``long_500k``
cells.  :class:`ServingEngine` wires them into a minimal batched loop
(greedy or temperature sampling) for the examples and integration tests.

The region-serving counterpart — :class:`AsyncServingCore`, the bounded
worker-pool execution front with 429/503 admission control the HTTP
region endpoint runs on — is re-exported here from
:mod:`repro.serving.core` (kept in its own module so the region path
stays importable without JAX).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import model as M
from ..models.layers import mesh_context
from .core import AsyncServingCore, ServerBusy
from .kv_cache import quantize_prefill_cache

__all__ = ["AsyncServingCore", "ServerBusy", "make_prefill_step",
           "make_serve_step", "ServingEngine"]


def make_prefill_step(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None,
                      *, q_chunk=512, kv_chunk=1024, unroll_scans=False):
    """Build the jit-able prefill step.

    :param cfg: model architecture config.
    :param run: run config; ``run.kv_quant`` quantizes the produced cache.
    :param mesh: optional device mesh (with ``rules``) for sharded runs.
    :param q_chunk: query-chunk size of the chunked-attention prefill.
    :param kv_chunk: key/value-chunk size.
    :param unroll_scans: unroll recurrent scans (trades compile time for
        step latency).
    :returns: ``prefill(params, batch) -> (last-token logits, state)``.
    """
    def prefill(params, batch):
        ctx = mesh_context(mesh, rules) if mesh is not None else _null()
        with ctx:
            kw = ({"tokens": batch["tokens"]} if cfg.input_mode == "tokens"
                  else {"embeds": batch["embeds"]})
            logits, aux = M.forward(params, cfg, mode="prefill",
                                    q_chunk=q_chunk, kv_chunk=kv_chunk,
                                    unroll_scans=unroll_scans, **kw)
            state = aux["state"]
            if run.kv_quant:
                state = quantize_prefill_cache(cfg, state)
        return logits[:, -1], state

    return prefill


def make_serve_step(cfg: ModelConfig, run: RunConfig, mesh=None, rules=None,
                    *, kv_chunk=1024, unroll_scans=False):
    """One decode step: (params, state, token, cache_len) → (logits, state)."""
    def serve(params, state, batch, cache_len):
        ctx = mesh_context(mesh, rules) if mesh is not None else _null()
        with ctx:
            kw = ({"tokens": batch["tokens"]} if cfg.input_mode == "tokens"
                  else {"embeds": batch["embeds"]})
            logits, aux = M.forward(params, cfg, mode="decode", state=state,
                                    cache_len=cache_len, q_chunk=1,
                                    kv_chunk=kv_chunk,
                                    unroll_scans=unroll_scans, **kw)
        return logits[:, -1], aux["state"]

    return serve


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


@dataclass
class ServingEngine:
    """Minimal batched generation loop over the jit'd steps."""

    cfg: ModelConfig
    run: RunConfig
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, self.run))
        self._decode = jax.jit(make_serve_step(self.cfg, self.run))

    def generate(self, params, prompts: jnp.ndarray, *, new_tokens: int,
                 greedy: bool = True, key=None):
        """prompts: (B, P) token ids.  Returns (B, new_tokens) ids."""
        B, P = prompts.shape
        capacity = P + new_tokens
        logits, state = self._prefill(params, {"tokens": prompts})
        # grow the prefill cache (capacity P) to full capacity
        state = self._grow_cache(state, capacity - P)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(new_tokens):
            outs.append(tok)
            logits, state = self._decode(
                params, state, {"tokens": tok[:, None]}, jnp.int32(P + i))
            if greedy or key is None:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        return jnp.stack(outs, axis=1)

    def _grow_cache(self, state, extra: int):
        """Pad the cache seq axis from prefill capacity to full capacity.

        Every cache leaf is stacked (layers/groups, B, S, ...) — the seq
        axis is always index 2 (k/v: (L,B,S,H,hd); scales: (L,B,S,H))."""
        if extra <= 0:
            return state

        def grow(a):
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, extra)
            return jnp.pad(a, pad)

        if self.cfg.family == "ssm":
            return state
        if self.cfg.family == "hybrid":
            return {"mamba": state["mamba"],
                    "kv": jax.tree.map(grow, state["kv"])}
        return jax.tree.map(grow, state)
