"""``repro.serving.client`` — stdlib client for the region endpoint.

Mirrors the server's wire format (``repro.serving.http_api``): metadata as
JSON, region payloads as raw little-endian float32 reassembled into
:class:`~repro.io.reader.ROILevel` objects, so a remote fetch drops into
the same downstream code as a local ``read_roi``.
"""
from __future__ import annotations

import json
import struct
import urllib.request

import numpy as np

from repro.io.reader import ROILevel

from .http_api import format_box, parse_box

__all__ = ["RegionClient"]


class RegionClient:
    """Client for one region endpoint (``http://host:port``)."""

    def __init__(self, base_url: str, *, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = float(timeout)

    def _get(self, path: str):
        return urllib.request.urlopen(self.base_url + path,
                                      timeout=self.timeout)

    def meta(self) -> dict:
        """Snapshot + level metadata + cache stats."""
        with self._get("/v1/meta") as resp:
            return json.loads(resp.read())

    def stats(self) -> dict:
        with self._get("/v1/stats") as resp:
            return json.loads(resp.read())

    def region(self, level: int, box) -> ROILevel:
        """One level's crop of ``box`` (finest-grid cells)."""
        path = f"/v1/region?level={int(level)}&box={format_box(box)}"
        with self._get(path) as resp:
            raw = resp.read()
            shape = tuple(int(s) for s in
                          resp.headers["X-TACZ-Shape"].split(",")
                          if s != "")
            lbox = parse_box(resp.headers["X-TACZ-Box"])
            data = np.frombuffer(raw, dtype="<f4").reshape(shape)
            return ROILevel(level=int(resp.headers["X-TACZ-Level"]),
                            ratio=int(resp.headers["X-TACZ-Ratio"]),
                            box=lbox, data=data)

    def regions(self, boxes, levels=None) -> list[list[ROILevel]]:
        """Batched fetch — one list of per-level crops per box."""
        req = {"boxes": [[list(r) for r in box] for box in boxes]}
        if levels is not None:
            req["levels"] = [int(li) for li in levels]
        body = json.dumps(req).encode()
        request = urllib.request.Request(
            self.base_url + "/v1/regions", data=body,
            headers={"Content-Type": "application/json"}, method="POST")
        with urllib.request.urlopen(request, timeout=self.timeout) as resp:
            blob = resp.read()
        (hdr_len,) = struct.unpack_from("<I", blob, 0)
        header = json.loads(blob[4:4 + hdr_len])
        payload = blob[4 + hdr_len:]
        out: list[list[ROILevel]] = []
        for rows in header["results"]:
            per_box: list[ROILevel] = []
            for r in rows:
                shape = tuple(r["shape"])
                data = np.frombuffer(
                    payload, dtype="<f4", offset=r["offset"],
                    count=int(np.prod(shape, dtype=np.int64)),
                ).reshape(shape)
                per_box.append(ROILevel(
                    level=r["level"], ratio=r["ratio"],
                    box=tuple(tuple(v) for v in r["box"]), data=data))
            out.append(per_box)
        return out
