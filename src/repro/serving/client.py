"""``repro.serving.client`` — stdlib client for the region endpoint.

Mirrors the server's wire format (``repro.serving.http_api``): metadata as
JSON, region payloads as raw little-endian float32 reassembled into
:class:`~repro.io.reader.ROILevel` objects, so a remote fetch drops into
the same downstream code as a local ``read_roi``.
"""
from __future__ import annotations

import http.client
import io
import json
import struct
import threading
import time
import urllib.error
import urllib.parse
import urllib.request

import numpy as np

from repro import obs
from repro.io.reader import ROILevel

from .http_api import format_box, parse_box

__all__ = ["RegionClient", "RegionAPIError"]


class RegionAPIError(urllib.error.HTTPError):
    """An HTTP error response from a region endpoint, with context.

    Subclasses ``urllib.error.HTTPError`` (existing ``except`` clauses
    keep working) but the message carries everything a fleet operator
    needs to attribute the failure: the URL, the HTTP status + reason,
    an excerpt of the response body (the server's JSON ``error``
    message), and the server's request ID — greppable in the shard's
    access log via ``rid=<id>``.
    """

    def __init__(self, url: str, status: int, reason: str,
                 headers, body: bytes):
        super().__init__(url, status, reason, headers, io.BytesIO(body))
        self.request_id = (headers.get(obs.REQUEST_ID_HEADER, "")
                           if headers else "") or ""
        try:
            excerpt = body[:200].decode("utf-8", "replace")
        except Exception:   # pragma: no cover - bytes always decode here
            excerpt = repr(body[:200])
        self.body_excerpt = excerpt

    def __str__(self) -> str:
        rid = f" request_id={self.request_id}" if self.request_id else ""
        body = f": {self.body_excerpt}" if self.body_excerpt else ""
        return (f"HTTP {self.code} {self.reason} from {self.url}"
                f"{rid}{body}")


class RegionClient:
    """Client for one region endpoint (``http://host:port``).

    Backpressure: a 429/503 response carrying a ``Retry-After`` header
    means the endpoint is *busy*, not down — admission control rejected
    the request because its decode queue is full.  The client honors the
    hint transparently: it sleeps ``min(Retry-After, busy_backoff_cap)``
    and retries, up to ``busy_retries`` times, before surfacing the
    :class:`RegionAPIError`.  (A 503 *without* ``Retry-After`` — e.g. a
    health readiness failure — is never retried.)

    :param base_url: endpoint root, e.g. ``"http://127.0.0.1:8765"``
        (trailing slash tolerated).
    :param timeout: per-request socket timeout in seconds.
    :param busy_retries: how many 429/503 + ``Retry-After`` rejections to
        wait out per request before raising (0 disables).
    :param busy_backoff_cap: upper bound in seconds on each honored
        ``Retry-After`` sleep.
    """

    def __init__(self, base_url: str, *, timeout: float = 30.0,
                 busy_retries: int = 2, busy_backoff_cap: float = 2.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = float(timeout)
        self.busy_retries = max(0, int(busy_retries))
        self.busy_backoff_cap = float(busy_backoff_cap)
        split = urllib.parse.urlsplit(self.base_url)
        if split.scheme not in ("http", "https") or not split.hostname:
            raise ValueError(
                f"base_url must be http(s)://host[:port][/prefix], "
                f"got {base_url!r}")
        self._conn_cls = (http.client.HTTPSConnection
                          if split.scheme == "https"
                          else http.client.HTTPConnection)
        self._host = split.hostname
        self._port = split.port   # None → the scheme's default port
        self._prefix = split.path.rstrip("/")   # e.g. a reverse-proxy root
        self._local = threading.local()   # one keep-alive conn per thread

    def _busy_delay(self, retry_after: str) -> float:
        """The (capped) sleep a ``Retry-After`` hint asks for."""
        try:
            delay = float(retry_after)
        except (TypeError, ValueError):
            delay = 1.0
        return min(max(delay, 0.0), self.busy_backoff_cap)

    def _get(self, path: str):
        """``GET`` with contextual errors: a 4xx/5xx response raises
        :class:`RegionAPIError` (status + body excerpt + the server's
        request ID) instead of a bare ``HTTPError``.  A 429/503 with a
        ``Retry-After`` header is waited out up to ``busy_retries``
        times first (server busy, not down)."""
        busy_left = self.busy_retries
        while True:
            try:
                return urllib.request.urlopen(self.base_url + path,
                                              timeout=self.timeout)
            except urllib.error.HTTPError as exc:
                body = b""
                try:
                    body = exc.read()
                except Exception:  # pragma: no cover - unreadable body
                    pass
                ra = (exc.headers.get("Retry-After")
                      if exc.headers else None)
                if exc.code in (429, 503) and ra is not None and busy_left:
                    busy_left -= 1
                    time.sleep(self._busy_delay(ra))
                    continue
                raise RegionAPIError(self.base_url + path, exc.code,
                                     exc.reason, exc.headers,
                                     body) from exc

    def _post(self, path: str, body: bytes,
              headers: dict | None = None) -> tuple[dict, bytes]:
        """``POST`` over a per-thread persistent HTTP/1.1 connection.

        The batched-regions route is the hot path of the sharded router
        (several POSTs per batch per shard); reusing the connection avoids
        a TCP handshake per request.  A dropped/stale connection is
        retried once on a fresh one; HTTP errors surface as
        :class:`RegionAPIError` (an ``urllib.error.HTTPError`` subclass,
        same contract as the GET routes).
        """
        send_headers = {"Content-Type": "application/json"}
        if headers:
            send_headers.update(headers)
        drop_left, busy_left = 1, self.busy_retries
        while True:
            conn = getattr(self._local, "conn", None)
            try:
                if conn is None:
                    conn = self._conn_cls(self._host, self._port,
                                          timeout=self.timeout)
                    self._local.conn = conn
                conn.request("POST", self._prefix + path, body=body,
                             headers=send_headers)
                resp = conn.getresponse()
                data = resp.read()
            except (http.client.HTTPException, OSError) as exc:
                self._local.conn = None
                if conn is not None:
                    conn.close()
                if not drop_left:
                    raise urllib.error.URLError(exc) from exc
                drop_left -= 1
                continue
            if resp.status in (429, 503):
                # busy, not down: honor the Retry-After hint and retry
                ra = resp.headers.get("Retry-After")
                if ra is not None and busy_left:
                    busy_left -= 1
                    if resp.will_close:
                        self._local.conn = None
                        conn.close()
                    time.sleep(self._busy_delay(ra))
                    continue
            if resp.status >= 400:
                self._local.conn = None
                conn.close()
                raise RegionAPIError(self.base_url + path, resp.status,
                                     resp.reason, resp.headers, data)
            if resp.will_close:
                self._local.conn = None
                conn.close()
            return dict(resp.headers), data

    def meta(self) -> dict:
        """Snapshot + level metadata + cache stats (``GET /v1/meta``).

        :returns: dict with ``snapshot_crc``, ``version``, per-level
            ``levels`` rows, ``cache`` counters, and ``shard`` info when
            the endpoint is shard-filtered.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        with self._get("/v1/meta") as resp:
            return json.loads(resp.read())

    def stats(self) -> dict:
        """Cache/serving counters only (``GET /v1/stats``).

        :returns: the server's :meth:`RegionServer.stats` dict.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        with self._get("/v1/stats") as resp:
            return json.loads(resp.read())

    def region(self, level: int, box, *, target=None,
               variant=None) -> ROILevel:
        """One level's crop of ``box`` (finest-grid cells).

        :param level: level index on the serving snapshot.
        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :param target: optional distortion target (``"psnr>=60"``) — the
            endpoint serves the cheapest satisfying eb variant.
        :param variant: optional explicit variant name.
        :returns: the crop, reassembled from the raw ``<f4`` body and the
            ``X-TACZ-*`` headers.
        :raises urllib.error.HTTPError: on a 4xx/5xx response (including
            a 400 for an unsatisfiable target).
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        path = f"/v1/region?level={int(level)}&box={format_box(box)}"
        if target is not None:
            path += "&target=" + urllib.parse.quote(str(target))
        if variant is not None:
            path += "&variant=" + urllib.parse.quote(str(variant))
        with self._get(path) as resp:
            raw = resp.read()
            shape = tuple(int(s) for s in
                          resp.headers["X-TACZ-Shape"].split(",")
                          if s != "")
            lbox = parse_box(resp.headers["X-TACZ-Box"])
            data = np.frombuffer(raw, dtype="<f4").reshape(shape)
            return ROILevel(level=int(resp.headers["X-TACZ-Level"]),
                            ratio=int(resp.headers["X-TACZ-Ratio"]),
                            box=lbox, data=data)

    def regions(self, boxes, levels=None, *, target=None,
                variant=None) -> list[list[ROILevel]]:
        """Batched fetch — one list of per-level crops per box.

        :param boxes: half-open boxes in finest-grid cells.
        :param levels: optional level-index filter applied to every box.
        :param target: optional distortion target (``"psnr>=60"``).
        :param variant: optional explicit variant name.
        :returns: ``out[b][l]`` = crop of ``boxes[b]`` at the l-th
            requested level.
        :raises urllib.error.HTTPError: on a 4xx/5xx response.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        return self.regions_meta(boxes, levels, target=target,
                                 variant=variant)[1]

    def regions_meta(self, boxes, levels=None, *, request_id=None,
                     target=None, variant=None,
                     ) -> tuple[int, list[list[ROILevel]]]:
        """Batched fetch that also returns the serving snapshot identity.

        Same wire call as :meth:`regions`, but the footer index CRC the
        endpoint reported alongside the payload is surfaced — the sharded
        router compares it with its own snapshot to detect a shard that
        has not yet picked up a republish.

        :returns: ``(snapshot_crc, results)`` with ``results`` as in
            :meth:`regions`.
        :raises RegionAPIError: on a 4xx/5xx response.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        header, out = self.regions_ex(boxes, levels,
                                      request_id=request_id,
                                      target=target, variant=variant)
        return int(header["snapshot_crc"]), out

    def regions_ex(self, boxes, levels=None, *, request_id=None,
                   target=None, variant=None,
                   ) -> tuple[dict, list[list[ROILevel]]]:
        """Batched fetch returning the full response header.

        The header carries ``snapshot_crc``, the server's ``request_id``
        (equal to ``request_id`` when one was sent — the fleet-tracing
        contract), ``variant`` — the eb variant that served the batch
        (null unless the endpoint is distortion-aware and a ``target``/
        ``variant`` was sent) — and ``trace`` — the server's span-tree
        summary for this batch (stage timings in milliseconds).

        :param request_id: optional caller-minted ID propagated via the
            ``X-Repro-Request-Id`` header (the sharded router stamps one
            per batch so every shard logs the same ID).
        :param target: optional distortion target (``"psnr>=60"``) — an
            unsatisfiable one is a :class:`RegionAPIError` with code 400
            whose body names the best achievable value.
        :param variant: optional explicit variant name.
        :returns: ``(response_header_dict, results)``.
        :raises RegionAPIError: on a 4xx/5xx response.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        req = {"boxes": [[list(r) for r in box] for box in boxes]}
        if levels is not None:
            req["levels"] = [int(li) for li in levels]
        if target is not None:
            req["target"] = str(target)
        if variant is not None:
            req["variant"] = str(variant)
        body = json.dumps(req).encode()
        extra = ({obs.REQUEST_ID_HEADER: str(request_id)}
                 if request_id else None)
        _, blob = self._post("/v1/regions", body, extra)
        (hdr_len,) = struct.unpack_from("<I", blob, 0)
        header = json.loads(blob[4:4 + hdr_len])
        payload = blob[4 + hdr_len:]
        out: list[list[ROILevel]] = []
        for rows in header["results"]:
            per_box: list[ROILevel] = []
            for r in rows:
                shape = tuple(r["shape"])
                data = np.frombuffer(
                    payload, dtype="<f4", offset=r["offset"],
                    count=int(np.prod(shape, dtype=np.int64)),
                ).reshape(shape)
                per_box.append(ROILevel(
                    level=r["level"], ratio=r["ratio"],
                    box=tuple(tuple(v) for v in r["box"]), data=data))
            out.append(per_box)
        return header, out

    def metrics(self) -> dict:
        """The endpoint's metrics, scraped and parsed
        (``GET /v1/metrics`` through :func:`repro.obs.expo.parse`).

        :returns: ``{family_name:`` :class:`repro.obs.expo.ParsedFamily`
            ``}`` — counters/gauges as floats, histograms as
            :class:`~repro.obs.expo.ParsedHistogram` with bucket bounds
            and quantile estimation.  Use :meth:`metrics_text` for the
            raw exposition body.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        :raises ValueError: if the scrape body is malformed.
        """
        from repro.obs import expo
        return expo.parse(self.metrics_text())

    def metrics_text(self) -> str:
        """The endpoint's raw Prometheus text exposition
        (``GET /v1/metrics``).

        :returns: the scrape body as text.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        with self._get("/v1/metrics") as resp:
            return resp.read().decode("utf-8")

    def health(self) -> dict:
        """The endpoint's liveness/readiness report
        (``GET /v1/health``).

        :returns: the health dict — ``status`` (``"ok"`` | ``"degraded"``
            | ``"down"``), ``snapshot_crc``, ``checks`` — from
            :meth:`RegionServer.health` or the router's fleet view.  A
            503 (readiness failure) still returns the body rather than
            raising, so callers can read *why* the endpoint is not ready.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        try:
            with self._get("/v1/health") as resp:
                return json.loads(resp.read())
        except RegionAPIError as exc:
            if exc.code == 503:
                try:
                    return json.loads(exc.read())
                except ValueError:
                    pass
            raise

    def cache_export(self, keys) -> bytes:
        """Pull a CRC-checked handoff blob of the endpoint's decoded
        bricks for ``keys`` (``POST /v1/cache/export``).

        Used during live resharding: the old owner of moved keys exports
        its warm bricks so the new owner can start warm (see
        :meth:`RegionServer.cache_export` for the wire format).

        :param keys: ``(level, sub_block)`` pairs to export.
        :returns: the handoff blob (feed to a peer's
            :meth:`cache_import`).
        :raises RegionAPIError: e.g. 400 from an endpoint with no cache.
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        body = json.dumps(
            {"keys": [[int(li), int(sbi)] for li, sbi in keys]}).encode()
        _, blob = self._post("/v1/cache/export", body)
        return blob

    def cache_import(self, blob: bytes) -> dict:
        """Push a handoff blob into the endpoint's cache
        (``POST /v1/cache/import``).

        :param blob: bytes from a peer's :meth:`cache_export`.
        :returns: the import summary — ``imported``, ``skipped_foreign``,
            ``skipped_stale``, ``bytes``, ``snapshot_crc``.
        :raises RegionAPIError: 400 on a corrupt blob (CRC mismatch).
        :raises urllib.error.URLError: if the endpoint is unreachable.
        """
        _, data = self._post("/v1/cache/import", bytes(blob),
                             {"Content-Type": "application/octet-stream"})
        return json.loads(data)
