"""``repro.serving.core`` — the admission-controlled decode engine.

The stdlib ``ThreadingHTTPServer`` model the region endpoint started
with spawns one unbounded thread per connection and decodes inline: one
fat ``POST /v1/regions`` (say, every level of a large snapshot) holds a
thread for its whole decode, and enough of them starve the host —
exactly the pipeline stall AMRIC (PAPERS.md) warns makes hot-path
compression a net loss.  :class:`AsyncServingCore` bounds that work:

  * a fixed decode pool of ``decode_workers`` threads is the only place
    region decodes run — the semaphore that caps decode concurrency is
    the pool size itself;
  * a batch is split into **per-level decode units** before admission,
    so an oversized multi-level batch interleaves with everyone else's
    units instead of monopolizing a worker for its full duration;
  * admission is bounded at ``decode_workers + queue_depth`` in-flight
    units — beyond that the batch is rejected *immediately* with
    :class:`ServerBusy` (HTTP 429 with ``Retry-After``), counted in
    ``tacz_server_backpressure_total{reason="queue_full"}``.  A closed
    (draining) core rejects with 503, ``reason="draining"``.

Splitting is transparent on the wire: unit results are re-merged into
the exact per-box × per-level layout an unsplit
``get_regions_with_crc`` returns, and a snapshot hot-swap landing
*between* units (units would disagree on the serving CRC) retries the
whole batch once against the new generation — a batch never mixes
generations.  Trace spans recorded inside pool threads are grafted back
into the caller's root span, so response ``trace`` metadata is unchanged.

This module is deliberately numpy/stdlib-only (no JAX): the HTTP region
stack imports it directly, and ``repro.serving.engine`` re-exports it
next to the LM-serving engine.
"""
from __future__ import annotations

import math
import threading
from concurrent.futures import ThreadPoolExecutor

from repro import obs
from repro.obs import metrics as obsm

__all__ = ["AsyncServingCore", "ServerBusy"]


class ServerBusy(RuntimeError):
    """Admission control rejected a batch; carries the HTTP semantics.

    ``status`` is 429 for ``reason="queue_full"`` (transient — the
    client should retry after ``retry_after`` seconds) and 503 for
    ``reason="draining"`` (the core is shutting down; retry against
    another endpoint).  Both responses carry a ``Retry-After`` header,
    which is how a well-behaved client/router distinguishes *busy* from
    *down*: busy endpoints are retried with backoff, never demoted.
    """

    def __init__(self, reason: str, retry_after_s: float,
                 pending: int, capacity: int):
        self.reason = str(reason)
        self.status = 429 if self.reason == "queue_full" else 503
        #: integer seconds for the ``Retry-After`` header (HTTP requires
        #: a non-negative integer; sub-second hints round up to 1)
        self.retry_after = max(1, int(math.ceil(float(retry_after_s))))
        self.pending = int(pending)
        self.capacity = int(capacity)
        super().__init__(
            f"server busy ({self.reason}): {self.pending}/{self.capacity} "
            f"decode units in flight; retry after {self.retry_after}s")


class AsyncServingCore:
    """Bounded-concurrency execution front for one region server.

    :param server: the object to execute against — a
        :class:`~repro.serving.regions.RegionServer`, a
        :class:`~repro.serving.variants.VariantServer`, or a mounted
        :class:`~repro.serving.sharded.ShardedRegionRouter` (anything
        with ``get_regions_with_crc``; ``get_regions_ex`` for
        distortion-aware requests).
    :param decode_workers: decode pool size — the hard cap on concurrent
        region decodes.
    :param queue_depth: admitted-but-not-running unit budget on top of
        the workers; ``0`` means a unit is only admitted when a worker
        is free.
    :param retry_after_s: the ``Retry-After`` hint rejected requests
        carry (rounded up to whole seconds on the wire).
    """

    def __init__(self, server, *, decode_workers: int = 4,
                 queue_depth: int = 16, retry_after_s: float = 1.0):
        self.server = server
        self.decode_workers = max(1, int(decode_workers))
        self.queue_depth = max(0, int(queue_depth))
        #: admission bound: units in flight (queued + running)
        self.capacity = self.decode_workers + self.queue_depth
        self.retry_after_s = float(retry_after_s)
        self._pool = ThreadPoolExecutor(max_workers=self.decode_workers,
                                        thread_name_prefix="decode-worker")
        self._lock = threading.Lock()
        self._pending = 0
        self._closed = False

    # ------------------------------ lifecycle ------------------------------

    def close(self) -> None:
        """Stop admitting, then wait for in-flight units to finish."""
        with self._lock:
            self._closed = True
        self._pool.shutdown(wait=True)

    @property
    def pending(self) -> int:
        """Units currently admitted (queued + running)."""
        with self._lock:
            return self._pending

    def stats(self) -> dict:
        """Admission-control configuration and occupancy."""
        with self._lock:
            return {"decode_workers": self.decode_workers,
                    "queue_depth": self.queue_depth,
                    "capacity": self.capacity,
                    "pending": self._pending,
                    "draining": self._closed}

    # ------------------------------ admission ------------------------------

    def _reject(self, reason: str) -> None:
        obsm.SERVER_BACKPRESSURE.labels(reason).inc()
        raise ServerBusy(reason, self.retry_after_s, self._pending,
                         self.capacity)

    def _admit(self, n_units: int) -> None:
        with self._lock:
            if self._closed:
                self._reject("draining")
            if self._pending + n_units > self.capacity:
                self._reject("queue_full")
            self._pending += n_units
            obsm.SERVER_QUEUE_DEPTH.set(self._pending)

    def _release(self, n_units: int) -> None:
        with self._lock:
            self._pending = max(0, self._pending - n_units)
            obsm.SERVER_QUEUE_DEPTH.set(self._pending)

    # ------------------------------ execution ------------------------------

    def _unit_levels(self, levels, target, variant) -> list:
        """The per-unit level lists one batch splits into.

        Distortion-aware batches stay whole (variant resolution must be
        atomic per batch); plain batches split one unit per level so a
        fat multi-level request cannot monopolize the decode pool.
        """
        if target is not None or variant is not None:
            return [levels]
        if levels is None:
            n = getattr(self.server, "n_levels", 0)
            levels = list(range(int(n)))
            if not levels:
                return [None]
        levels = [int(li) for li in levels]
        if len(levels) <= 1:
            return [levels]
        return [[li] for li in levels]

    def _run_unit(self, boxes, levels, target, variant):
        """One decode unit on a pool thread.  Returns ``(crc, variant,
        results, spans)`` with the unit's finished trace spans collected
        for grafting (pool threads do not inherit the caller's root)."""
        obsm.SERVER_DECODE_UNITS.inc()
        with obs.root_span("decode_unit") as root:
            if target is None and variant is None:
                crc, results = self.server.get_regions_with_crc(
                    boxes, levels=levels)
                vname = None
            else:
                ex = getattr(self.server, "get_regions_ex", None)
                if ex is None:
                    raise ValueError(
                        "endpoint does not support distortion targets")
                crc, vname, results = ex(boxes, levels=levels,
                                         target=target, variant=variant)
        return crc, vname, results, list(root.children)

    def execute(self, boxes, levels=None, *, target=None, variant=None):
        """Serve one batch through the bounded pool.

        :returns: ``(snapshot_crc, variant_name_or_None, results)`` —
            the :meth:`RegionServer.get_regions_ex` contract, with
            ``results[b][l]`` in the caller's requested level order.
        :raises ServerBusy: admission rejected the batch (429/503).
        :raises IOError: a snapshot hot-swap raced the split batch on
            both attempts (pathological republish churn).
        """
        for attempt in (0, 1):
            units = self._unit_levels(levels, target, variant)
            self._admit(len(units))
            futs = []
            try:
                try:
                    for u in units:
                        futs.append(self._pool.submit(
                            self._run_unit, boxes, u, target, variant))
                except RuntimeError:   # pool shut down after admission
                    self._reject("draining")
                outs = [f.result() for f in futs]
            finally:
                self._release(len(units))
            if len({crc for crc, _, _, _ in outs}) == 1:
                return self._merge(outs)
            # a hot swap landed between units: units disagree on the
            # serving generation — retry the whole batch once against
            # the new snapshot rather than mixing generations
            if attempt:
                raise IOError(
                    "snapshot hot-swap raced the batch on both attempts")
        raise AssertionError("unreachable")  # pragma: no cover

    @staticmethod
    def _graft_merged(parent, span_lists) -> None:
        """Graft unit trace spans into ``parent``, merging same-name
        spans across units into one aggregate span each — so a split
        batch reports the same stage names an unsplit one does.  An
        aggregate's duration is the *sum* over units (decode work, not
        wall time — units run concurrently) and it carries a ``units``
        count; children merge recursively the same way."""
        order: list[str] = []
        groups: dict[str, list] = {}
        for spans in span_lists:
            for sp in spans:
                if sp.name not in groups:
                    order.append(sp.name)
                    groups[sp.name] = []
                groups[sp.name].append(sp)
        for name in order:
            members = groups[name]
            if len(members) == 1:
                parent.add_child(members[0])
                continue
            agg = obs.Span(name)
            agg.duration = sum(m.duration for m in members)
            agg.meta = {"units": len(members)}
            AsyncServingCore._graft_merged(
                agg, [m.children for m in members])
            parent.add_child(agg)

    def _merge(self, outs):
        """Re-merge per-unit results into the unsplit response layout,
        grafting unit trace spans into the caller's root span."""
        parent = obs.current_span()
        if parent is not None:
            self._graft_merged(parent, [spans for _, _, _, spans in outs])
        crc, vname, first, _ = outs[0]
        if len(outs) == 1:
            return crc, vname, first
        results = []
        for b in range(len(first)):
            row = []
            for _, _, unit_results, _ in outs:
                row.extend(unit_results[b])
            results.append(row)
        return crc, vname, results
