"""``repro.serving.variants`` — distortion-aware serving of variant sets.

A :class:`VariantServer` mounts a multi-variant snapshot set (a
directory the autotuner's :func:`repro.tuning.write_variant_set`
published: N eb-variant snapshots of the same dataset under one CRC'd
``variants.json`` catalog — :mod:`repro.io.variants`) behind the exact
serving surface :class:`~repro.serving.regions.RegionServer` exposes.
``http_api.serve`` therefore mounts either interchangeably, and the
wire protocol grows only two optional request fields:

  * ``target`` — a distortion target (``"psnr>=60"``); the catalog's
    cheapest satisfying variant serves the batch, its name travels back
    in the response metadata, and the choice lands in
    ``tacz_variant_requests_total{variant=...}``.
  * ``variant`` — an explicit variant name, bypassing selection (the
    sharded router uses this to pin every shard of a batch to the
    variant it resolved locally).

No target selects the catalog's default variant.  An unsatisfiable
target raises :class:`repro.io.frontier.TargetUnsatisfiable` — a clean
HTTP 400 upstream, counted in ``tacz_variant_unsatisfied_total``.
Inner per-variant servers are built lazily (first request to a variant
opens its reader and its own cache slice) and share the server's shard
filter and ``fault_hook``.
"""
from __future__ import annotations

import os
import threading

from repro.io import variants as vrt
from repro.io.reader import Box, ROILevel
from repro.obs import metrics as obsm

from .regions import RegionServer

__all__ = ["VariantServer"]


class VariantServer:
    """Serve region queries from a variant set, selecting per request.

    Construction reads and validates the catalog once; each variant's
    :class:`~repro.serving.regions.RegionServer` (reader + sub-block
    cache + planner) is created on first use and kept hot after.  All
    ``RegionServer`` constructor knobs apply per variant; ``cache_bytes``
    is a *per-variant* budget (variants hold different payload bytes, so
    their decoded bricks cannot share entries anyway).

    :param path: the variant-set directory (or its ``variants.json``).
    :param cache_bytes: per-variant :class:`SubBlockCache` byte budget.
    :param auto_reload: per-variant footer-CRC hot-swap check per batch.
    :param shard_map: optional shard filter, shared by every variant —
        sub-block partition is eb-independent (same index geometry), so
        one map covers the whole set.
    :param shard_id: this server's shard in ``shard_map``.
    :param entropy_engine: payload-decode engine for every variant.
    :raises ValueError: if the catalog fails validation.
    :raises OSError: if the catalog cannot be read.
    """

    def __init__(self, path, *, cache_bytes: int = 256 << 20,
                 auto_reload: bool = False, shard_map=None,
                 shard_id: str | None = None,
                 entropy_engine: str = "auto"):
        self.path = str(path)
        if os.path.basename(self.path) == vrt.VARIANTS_NAME:
            self.path = os.path.dirname(self.path)
        self.catalog = vrt.load_catalog(self.path)
        self.auto_reload = bool(auto_reload)
        self.shard_map = shard_map
        self.shard_id = shard_id
        self._kwargs = {"cache_bytes": int(cache_bytes),
                        "auto_reload": bool(auto_reload),
                        "shard_map": shard_map, "shard_id": shard_id,
                        "entropy_engine": entropy_engine}
        self._fault_hook = None
        self._servers: dict[str, RegionServer] = {}
        self._lock = threading.Lock()

    # ----------------------------- selection -------------------------------

    @property
    def default_variant(self) -> str:
        """The catalog's default variant name (served when no target)."""
        return str(self.catalog["default"])

    def variant_names(self) -> list[str]:
        """Variant names the catalog binds, in catalog order."""
        return vrt.variant_names(self.catalog)

    def variants_meta(self) -> dict:
        """Catalog summary for ``GET /v1/meta``: default, names, and
        each variant's target/bits/metrics (not the eb vectors)."""
        return {"default": self.default_variant,
                "names": self.variant_names(),
                "variants": [{"name": str(v["name"]),
                              "target": v.get("target"),
                              "bits": int(v.get("bits", 0)),
                              "metrics": dict(v.get("metrics", {}))}
                             for v in self.catalog["variants"]]}

    def resolve(self, target=None, variant: str | None = None) -> str:
        """The variant name a request's ``target``/``variant`` binds to.

        :raises ValueError: on an unknown ``variant`` name or malformed
            target spec.
        :raises repro.io.frontier.TargetUnsatisfiable: when no variant
            satisfies ``target``.
        """
        if variant is not None:
            if str(variant) not in self.variant_names():
                raise ValueError(
                    f"unknown variant {variant!r} (catalog has: "
                    f"{', '.join(self.variant_names())})")
            return str(variant)
        try:
            return str(vrt.select_variant(self.catalog, target)["name"])
        except vrt.TargetUnsatisfiable:
            obsm.VARIANT_UNSATISFIED.inc()
            raise

    def server(self, name: str) -> RegionServer:
        """The (lazily built) inner server for one variant name."""
        with self._lock:
            rs = self._servers.get(name)
            if rs is None:
                entry = next(v for v in self.catalog["variants"]
                             if str(v["name"]) == name)
                rs = RegionServer(os.path.join(self.path, entry["file"]),
                                  **self._kwargs)
                rs.fault_hook = self._fault_hook
                self._servers[name] = rs
            return rs

    # ------------------------------ lifecycle ------------------------------

    def close(self) -> None:
        """Close every inner server built so far."""
        with self._lock:
            for rs in self._servers.values():
                rs.close()
            self._servers.clear()

    def __enter__(self) -> "VariantServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def fault_hook(self):
        """Zero-arg fault-injection callable, forwarded to every inner
        server (existing and future) — same contract as
        :attr:`RegionServer.fault_hook`."""
        return self._fault_hook

    @fault_hook.setter
    def fault_hook(self, hook) -> None:
        with self._lock:
            self._fault_hook = hook
            for rs in self._servers.values():
                rs.fault_hook = hook

    # --------------- RegionServer surface (default variant) ----------------

    @property
    def reader(self):
        """The default variant's reader (``/v1/meta`` describes it)."""
        return self.server(self.default_variant).reader

    @property
    def cache(self):
        """The default variant's sub-block cache."""
        return self.server(self.default_variant).cache

    @property
    def n_levels(self) -> int:
        """Level count of the default variant."""
        return self.server(self.default_variant).n_levels

    @property
    def snapshot_crc(self) -> int:
        """Index CRC of the default variant's snapshot."""
        return self.server(self.default_variant).snapshot_crc

    def maybe_reload(self) -> bool:
        """Run the hot-swap check on every built variant server.

        :returns: True when any variant adopted a republished snapshot.
        """
        with self._lock:
            servers = list(self._servers.values())
        swapped = False
        for rs in servers:
            swapped = rs.maybe_reload() or swapped
        return swapped

    # ------------------------------- queries -------------------------------

    def get_regions_ex(self, boxes: list[Box],
                       levels: list[int] | None = None, *,
                       target=None, variant: str | None = None,
                       ) -> tuple[int, str | None, list[list[ROILevel]]]:
        """Serve a batch from the variant the request resolves to.

        :returns: ``(snapshot_crc_of_serving_variant, variant_name,
            results)`` — the CRC names the *variant's* snapshot, so the
            sharded router's generation check works per variant.
        :raises ValueError: on an unknown variant / malformed target.
        :raises repro.io.frontier.TargetUnsatisfiable: when no variant
            satisfies the target.
        """
        name = self.resolve(target, variant)
        obsm.VARIANT_REQUESTS.labels(name).inc()
        crc, out = self.server(name).get_regions_with_crc(boxes, levels)
        return crc, name, out

    def get_regions_with_crc(self, boxes: list[Box],
                             levels: list[int] | None = None,
                             ) -> tuple[int, list[list[ROILevel]]]:
        """Target-less batch against the default variant — the plain
        :meth:`RegionServer.get_regions_with_crc` contract."""
        return self.server(self.default_variant).get_regions_with_crc(
            boxes, levels)

    def get_regions(self, boxes: list[Box],
                    levels: list[int] | None = None,
                    ) -> list[list[ROILevel]]:
        """Target-less batch against the default variant."""
        return self.get_regions_with_crc(boxes, levels)[1]

    def get_region(self, level: int, box: Box) -> ROILevel:
        """One level's crop from the default variant."""
        return self.get_regions([box], levels=[level])[0][0]

    def get_roi(self, box: Box) -> list[ROILevel]:
        """All levels' crops from the default variant, finest first."""
        return self.get_regions([box])[0]

    # ----------------------------- introspection ---------------------------

    def stats(self) -> dict:
        """The default variant's stats plus per-variant cache summaries
        under ``variants`` (only variants that have served appear)."""
        s = self.server(self.default_variant).stats()
        with self._lock:
            built = dict(self._servers)
        s["variants"] = {"default": self.default_variant,
                         "names": self.variant_names(),
                         "built": sorted(built),
                         "caches": {n: rs.cache.stats()
                                    for n, rs in built.items()}}
        return s

    def health(self) -> dict:
        """Default variant's health, re-labeled ``role="variant-server"``
        with the catalog summary under ``checks["variants"]``.

        A missing default snapshot is ``down`` exactly as on a single
        server; unbuilt variants are not probed (first use will surface
        their failures as request errors).
        """
        try:
            h = self.server(self.default_variant).health()
        except Exception as exc:   # default variant unopenable
            h = {"status": "down", "snapshot_crc": None,
                 "checks": {"snapshot": {"ok": False,
                                         "error": str(exc)}}}
        h["role"] = "variant-server"
        h["checks"]["variants"] = {"ok": True,
                                   "default": self.default_variant,
                                   "names": self.variant_names()}
        return h
