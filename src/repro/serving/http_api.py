"""Thin stdlib HTTP endpoint over :class:`repro.serving.regions.RegionServer`.

JSON for metadata, raw little-endian float32 for region payloads — no
framework, no third-party deps (``http.server`` + ``ThreadingHTTPServer``).

Routes::

    GET  /v1/meta                 snapshot + level metadata + cache stats
    GET  /v1/stats                cache counters + latency quantiles
    GET  /v1/metrics              Prometheus text exposition of the
                                  process-wide repro.obs registry
    GET  /v1/health               liveness/readiness: snapshot CRC, cache
                                  headroom, shard reachability (router);
                                  200 ok/degraded, 503 down (with body)
    GET  /v1/region?level=L&box=x0:x1,y0:y1,z0:z1
                                  one level's crop; body = C-order <f4 bytes,
                                  shape/box/ratio travel in X-TACZ-* headers;
                                  optional &target=psnr>=60 / &variant=NAME
                                  (distortion-aware serving — the selected
                                  variant returns in X-TACZ-Variant)
    POST /v1/regions              batched: JSON {"boxes": [...], "levels":
                                  [...]?, "target": "psnr>=60"?, "variant":
                                  NAME?} in; u32 header length + JSON header
                                  + concatenated <f4 payloads out
    POST /v1/cache/export         JSON {"keys": [[level, sub_block], ...]}
                                  in; CRC-checked handoff blob of this
                                  shard's decoded bricks out (resharding)
    POST /v1/cache/import         handoff blob in; JSON import summary out
                                  (imported / skipped_foreign /
                                  skipped_stale counts)

The batched response header is ``{"snapshot_crc", "request_id", "trace",
"variant", "results"}`` where ``results[b][l]`` holds ``{level, ratio,
box, shape, offset, nbytes}`` and ``offset`` indexes into the payload
section that follows the header; ``variant`` is the eb variant that
served (null without a target); ``trace`` is the request's span-tree
summary and ``request_id`` echoes the caller's ``X-Repro-Request-Id``
header (minted here when absent) — the ID the sharded router stamps on a
batch so one slow request is greppable across every shard's access log.
A ``target`` no variant satisfies is a clean 400 whose JSON body names
the target and the best achievable value (never a 500).
Every request first runs the server's footer-CRC hot-swap check (when the
server was built with ``auto_reload=True``), so an atomically republished
snapshot is picked up without restarting the endpoint.

Concurrency model: the old one-unbounded-thread-per-connection
``ThreadingHTTPServer`` behavior is gone.  Connections are handled by a
fixed accept/parse pool (``accept_workers``), and every region decode
goes through the server's :class:`~repro.serving.core.AsyncServingCore`
— a bounded decode pool with admission control.  When the decode queue
is full the endpoint answers **429** (503 while draining) with a
``Retry-After`` header and a JSON body naming the reason; rejections are
counted in ``tacz_server_backpressure_total``.  Idle keep-alive
connections time out after ``keepalive_timeout`` seconds so they cannot
pin accept-pool workers (clients transparently reconnect).  Binary
payloads are written straight from the decoded arrays via ``memoryview``
— no intermediate payload copy.

Access logging: one structured record per request (method, path, status,
duration_ms, request_id) through the ``repro.serving.http`` logger at
DEBUG — quiet by default, and ``serve(..., verbose=True)`` raises it to
INFO.  The old behavior (unconditional stderr spam from
``BaseHTTPRequestHandler``) is gone either way.
"""
from __future__ import annotations

import json
import logging
import struct
import time
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro import obs
from repro.io import format as fmt
from repro.io import variants as vrt
from repro.io.frontier import TargetUnsatisfiable
from repro.obs import metrics as obsm

from .core import AsyncServingCore, ServerBusy
from .regions import RegionServer

__all__ = ["RegionHTTPServer", "RegionRequestHandler", "serve",
           "format_box", "parse_box"]

#: Structured access/error log for every region endpoint in the process.
#: Quiet by default: records go out at DEBUG (INFO with ``verbose=True``)
#: and propagate to whatever handlers the host application configured.
access_log = logging.getLogger("repro.serving.http")

# bounded route-label set for the HTTP metrics (an arbitrary 404 path
# must not mint an unbounded number of label values)
_KNOWN_ROUTES = ("/v1/meta", "/v1/stats", "/v1/metrics", "/v1/health",
                 "/v1/region", "/v1/regions",
                 "/v1/cache/export", "/v1/cache/import")


def format_box(box) -> str:
    """((x0,x1),(y0,y1),(z0,z1)) → ``"x0:x1,y0:y1,z0:z1"``."""
    return ",".join(f"{int(lo)}:{int(hi)}" for lo, hi in box)


def parse_box(s: str):
    """Inverse of :func:`format_box`; raises ValueError on malformed input."""
    dims = s.split(",")
    if len(dims) != 3:
        raise ValueError("box must have three x0:x1 ranges")
    box = []
    for d in dims:
        lo, _, hi = d.partition(":")
        box.append((int(lo), int(hi)))
    return tuple(box)


class RegionRequestHandler(BaseHTTPRequestHandler):
    """Routes ``/v1/meta|stats|region|regions`` onto one RegionServer."""

    server_version = "taczserve/1"
    protocol_version = "HTTP/1.1"

    #: set per request by :meth:`_handle`; echoed on every response
    _request_id: str = ""
    _status: int = 0

    def setup(self) -> None:
        """Idle keep-alive connections time out after the server's
        ``keepalive_timeout`` so they cannot pin a fixed-pool worker
        forever; clients re-open transparently (the region client
        already retries a dropped keep-alive connection once)."""
        self.timeout = getattr(self.server, "keepalive_timeout", 30.0)
        super().setup()

    def log_message(self, format: str, *args) -> None:
        """Base-class messages (errors, malformed requests) go through the
        structured logger instead of raw stderr — quiet by default."""
        access_log.debug("%s " + format, self.address_string(), *args)

    def log_request(self, code="-", size="-") -> None:
        """Suppressed: :meth:`_handle` emits one structured record per
        request with duration and request ID instead."""

    @property
    def rs(self) -> RegionServer:
        """The :class:`RegionServer` this endpoint serves."""
        return self.server.region_server

    # ------------------------------ plumbing -------------------------------

    def send_response(self, code: int, message: str | None = None) -> None:
        """Every response carries the request's ID back to the caller."""
        super().send_response(code, message)
        self._status = int(code)
        if self._request_id:
            self.send_header(obs.REQUEST_ID_HEADER, self._request_id)

    def _send_json(self, obj, status: int = 200) -> None:
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _fail(self, status: int, msg: str) -> None:
        self._send_json({"error": msg}, status=status)

    def _busy(self, exc: ServerBusy) -> None:
        """Admission control rejected the request: 429 (queue full) or
        503 (draining), always with a ``Retry-After`` header — the
        signal that this endpoint is *busy*, not *down*."""
        body = json.dumps({"error": str(exc), "reason": exc.reason,
                           "retry_after_s": exc.retry_after}).encode()
        self.send_response(exc.status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Retry-After", str(exc.retry_after))
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _unsatisfiable(self, exc: TargetUnsatisfiable) -> None:
        """A distortion target no variant meets: a clean 400 whose body
        names the target and the best achievable value — an operator
        mistake, not a server failure (never a 500)."""
        self._send_json({"error": str(exc),
                         "target": str(exc.target),
                         "best": exc.best}, status=400)

    def _meta(self) -> dict:
        rd = self.rs.reader
        levels = []
        for li, e in enumerate(rd.levels):
            levels.append({
                "level": li, "shape": list(e.shape),
                "ratio": max(int(e.ratio), 1), "eb": e.eb,
                "strategy": fmt.STRATEGY_NAMES.get(e.strategy, "?"),
                "algorithm": fmt.ALGO_NAMES.get(e.algorithm, "?"),
                "n_subblocks": len(e.subblocks),
            })
        meta = {"snapshot_crc": self.rs.snapshot_crc,
                "version": rd.version, "levels": levels}
        cache = getattr(self.rs, "cache", None)
        if cache is not None:     # a mounted router has no decode cache
            meta["cache"] = cache.stats()
        if self.rs.shard_map is not None:
            shard = {"n_shards": len(self.rs.shard_map),
                     "shard_map": self.rs.shard_map.to_dict()}
            sid = getattr(self.rs, "shard_id", None)
            if sid is not None:
                shard["shard_id"] = sid
            meta["shard"] = shard
        vm = getattr(self.rs, "variants_meta", None)
        if vm is not None:   # a VariantServer advertises its catalog
            meta["variants"] = vm()
        return meta

    def _serve_batch(self, boxes, levels, target, variant):
        """Serve one batch, distortion-aware when the request asks.

        :returns: ``(snapshot_crc, variant_name_or_None, results)``.
        :raises TargetUnsatisfiable: no variant meets ``target`` (the
            caller maps it to a 400 with an explanatory body).
        :raises ValueError: malformed target / unknown variant / an
            endpoint with no distortion-target support.
        :raises ServerBusy: decode admission control rejected the batch.
        """
        core = getattr(self.server, "core", None)
        if core is not None:
            return core.execute(boxes, levels=levels, target=target,
                                variant=variant)
        # no core mounted (bare handler reuse): direct, unbounded path
        if target is None and variant is None:
            crc, results = self.rs.get_regions_with_crc(boxes,
                                                        levels=levels)
            return crc, None, results
        ex = getattr(self.rs, "get_regions_ex", None)
        if ex is None:
            raise ValueError(
                "endpoint does not support distortion targets")
        return ex(boxes, levels=levels, target=target, variant=variant)

    # ------------------------------- routes --------------------------------

    def _handle(self, method: str) -> None:
        """Per-request envelope: request-ID adoption, HTTP metrics, and
        one structured access-log record (method, path, status,
        duration_ms, request_id)."""
        url = urlparse(self.path)
        rid = self.headers.get(obs.REQUEST_ID_HEADER, "").strip()
        self._request_id = rid or obs.new_request_id()
        self._status = 0
        route = url.path if url.path in _KNOWN_ROUTES else "other"
        t0 = time.perf_counter()
        try:
            if method == "GET":
                self._route_get(url)
            else:
                self._route_post(url)
        finally:
            dt = time.perf_counter() - t0
            obsm.HTTP_REQUESTS.labels(route, str(self._status or 500)).inc()
            obsm.HTTP_REQUEST_SECONDS.labels(route).observe(dt)
            level = (logging.INFO if getattr(self.server, "verbose", False)
                     else logging.DEBUG)
            if getattr(self.server, "log_json", False):
                access_log.log(level, "%s", json.dumps(
                    {"method": method, "path": self.path,
                     "status": self._status or 500,
                     "duration_ms": round(dt * 1000.0, 3),
                     "request_id": self._request_id}, sort_keys=True))
            else:
                access_log.log(
                    level, "%s %s %d %.2fms rid=%s", method, self.path,
                    self._status or 500, dt * 1000.0, self._request_id)

    def do_GET(self) -> None:
        """Dispatch ``/v1/meta``, ``/v1/stats``, ``/v1/metrics``,
        ``/v1/region``."""
        self._handle("GET")

    def do_POST(self) -> None:
        """Dispatch ``/v1/regions`` (batched fetch)."""
        self._handle("POST")

    def _route_get(self, url) -> None:
        if url.path == "/v1/meta":
            # data routes hot-swap inside get_regions (auto_reload);
            # metadata routes run the footer check themselves
            if self.rs.auto_reload:
                self.rs.maybe_reload()
            return self._send_json(self._meta())
        if url.path == "/v1/stats":
            if self.rs.auto_reload:
                self.rs.maybe_reload()
            return self._send_json(self.rs.stats())
        if url.path == "/v1/health":
            if self.rs.auto_reload:
                self.rs.maybe_reload()
            h = self.rs.health()
            # liveness (process answers) is the 200; readiness failure is
            # a 503 *with* the body, so probes can read why
            return self._send_json(
                h, status=503 if h.get("status") == "down" else 200)
        if url.path == "/v1/metrics":
            # scrape surface: the process-wide registry covers this
            # server's cache/planner/latency series and, when a router or
            # sibling shard servers share the process, theirs too
            cache = getattr(self.rs, "cache", None)
            if cache is not None:
                obsm.refresh_cache_gauges(cache.stats())
            body = obs.REGISTRY.render().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
            return
        if url.path == "/v1/region":
            return self._get_region(parse_qs(url.query))
        return self._fail(404, f"unknown path {url.path!r}")

    def _get_region(self, q: dict) -> None:
        try:
            level = int(q["level"][0])
            box = parse_box(q["box"][0])
            if not 0 <= level < self.rs.n_levels:
                raise ValueError(f"level {level} out of range")
        except (KeyError, IndexError, ValueError) as exc:
            return self._fail(400, f"bad region query: {exc}")
        target = (q.get("target") or [None])[0]
        variant = (q.get("variant") or [None])[0]
        try:
            crc, vname, results = self._serve_batch([box], [level],
                                                    target, variant)
            roi = results[0][0]
        except TargetUnsatisfiable as exc:
            return self._unsatisfiable(exc)
        except ServerBusy as exc:
            return self._busy(exc)
        except ValueError as exc:      # e.g. hot-swap shrank the level count
            return self._fail(400, f"bad region query: {exc}")
        except Exception as exc:       # corrupt payload, missing codec, ...
            return self._fail(500, f"region decode failed: {exc}")
        # zero-copy: the contiguous <f4 array is written straight to the
        # socket (wfile is unbuffered, so write() is a direct sendall)
        body = memoryview(
            np.ascontiguousarray(roi.data, dtype="<f4")).cast("B")
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(body)))
        self.send_header("X-TACZ-Level", str(roi.level))
        self.send_header("X-TACZ-Ratio", str(roi.ratio))
        self.send_header("X-TACZ-Box", format_box(roi.box))
        self.send_header("X-TACZ-Shape",
                         ",".join(str(s) for s in roi.shape))
        self.send_header("X-TACZ-Dtype", "<f4")
        self.send_header("X-TACZ-Snapshot-CRC", str(crc))
        if vname is not None:
            self.send_header("X-TACZ-Variant", str(vname))
        self.end_headers()
        self.wfile.write(body)

    def _route_post(self, url) -> None:
        if url.path == "/v1/cache/export":
            return self._cache_export()
        if url.path == "/v1/cache/import":
            return self._cache_import()
        if url.path != "/v1/regions":
            return self._fail(404, f"unknown path {url.path!r}")
        try:
            n = int(self.headers.get("Content-Length", 0))
            req = json.loads(self.rfile.read(n) or b"{}")
            boxes = [tuple((int(lo), int(hi)) for lo, hi in box)
                     for box in req["boxes"]]
            for box in boxes:
                if len(box) != 3:
                    raise ValueError("each box needs three (lo, hi) ranges")
            levels = req.get("levels")
            if levels is not None:
                levels = [int(li) for li in levels]
                for li in levels:
                    if not 0 <= li < self.rs.n_levels:
                        raise ValueError(f"level {li} out of range")
            target = req.get("target")
            target = None if target is None else str(target)
            variant = req.get("variant")
            variant = None if variant is None else str(variant)
        except (KeyError, TypeError, ValueError,
                json.JSONDecodeError) as exc:
            return self._fail(400, f"bad regions request: {exc}")
        try:
            # the CRC must name the snapshot that *served this batch* —
            # a hot-swap racing the decode must not stamp the new
            # generation on old data (the sharded router trusts this).
            # The root span makes every trace() below it (plan, fetch,
            # decode) collect into one tree this response carries back.
            with obs.root_span("regions") as span:
                crc, vname, results = self._serve_batch(boxes, levels,
                                                        target, variant)
        except TargetUnsatisfiable as exc:
            return self._unsatisfiable(exc)
        except ServerBusy as exc:
            return self._busy(exc)
        except ValueError as exc:      # e.g. hot-swap shrank the level count
            return self._fail(400, f"bad regions request: {exc}")
        except Exception as exc:       # corrupt payload, missing codec, ...
            return self._fail(500, f"region decode failed: {exc}")
        # zero-copy payload section: each decoded array is framed as a
        # memoryview and written straight to the socket — the payload is
        # never concatenated into an intermediate buffer
        frames: list = []
        total = 0
        header: dict = {"snapshot_crc": crc,
                        "request_id": self._request_id,
                        "variant": vname,
                        "trace": span.summary(), "results": []}
        for per_box in results:
            rows = []
            for roi in per_box:
                mv = memoryview(
                    np.ascontiguousarray(roi.data, dtype="<f4")).cast("B")
                rows.append({"level": roi.level, "ratio": roi.ratio,
                             "box": [list(r) for r in roi.box],
                             "shape": list(roi.shape),
                             "offset": total, "nbytes": len(mv)})
                frames.append(mv)
                total += len(mv)
            header["results"].append(rows)
        hdr = json.dumps(header).encode()
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(4 + len(hdr) + total))
        self.end_headers()
        self.wfile.write(struct.pack("<I", len(hdr)))
        self.wfile.write(hdr)
        for mv in frames:
            self.wfile.write(mv)

    # --------------------------- cache handoff ----------------------------

    def _cache_export(self) -> None:
        """``POST /v1/cache/export`` — serialize the requested decoded
        bricks into a CRC-checked handoff blob (live resharding)."""
        fn = getattr(self.rs, "cache_export", None)
        if fn is None:
            return self._fail(400, "endpoint has no sub-block cache")
        try:
            n = int(self.headers.get("Content-Length", 0))
            req = json.loads(self.rfile.read(n) or b"{}")
            keys = [(int(li), int(sbi)) for li, sbi in req.get("keys", [])]
        except (KeyError, TypeError, ValueError,
                json.JSONDecodeError) as exc:
            return self._fail(400, f"bad cache export request: {exc}")
        try:
            blob = fn(keys)
        except Exception as exc:
            return self._fail(500, f"cache export failed: {exc}")
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(blob)))
        self.end_headers()
        self.wfile.write(blob)

    def _cache_import(self) -> None:
        """``POST /v1/cache/import`` — ingest a handoff blob; responds
        with the import summary (a corrupt blob is a clean 400)."""
        fn = getattr(self.rs, "cache_import", None)
        if fn is None:
            return self._fail(400, "endpoint has no sub-block cache")
        try:
            n = int(self.headers.get("Content-Length", 0))
            summary = fn(self.rfile.read(n))
        except ValueError as exc:      # truncated frame / CRC mismatch
            return self._fail(400, f"bad cache handoff blob: {exc}")
        except Exception as exc:
            return self._fail(500, f"cache import failed: {exc}")
        return self._send_json(summary)


class RegionHTTPServer(ThreadingHTTPServer):
    """Worker-pooled HTTP server bound to one :class:`RegionServer` (or
    a router exposing the same serving surface).

    Unlike the ``ThreadingHTTPServer`` it subclasses, connections are
    NOT given a fresh unbounded thread each: :meth:`process_request` is
    overridden to hand every accepted socket to a fixed
    ``accept_workers``-sized pool, and all region decodes flow through
    :attr:`core` — an :class:`~repro.serving.core.AsyncServingCore`
    whose admission control turns overload into fast 429s instead of an
    unbounded thread pile-up.
    """

    daemon_threads = True

    def __init__(self, addr, region_server: RegionServer, *,
                 verbose: bool = False, log_json: bool = False,
                 accept_workers: int = 32, decode_workers: int = 4,
                 queue_depth: int = 16, retry_after_s: float = 1.0,
                 keepalive_timeout: float = 30.0):
        super().__init__(addr, RegionRequestHandler)
        self.region_server = region_server
        self.verbose = verbose
        self.log_json = log_json
        self.keepalive_timeout = float(keepalive_timeout)
        self.core = AsyncServingCore(region_server,
                                     decode_workers=decode_workers,
                                     queue_depth=queue_depth,
                                     retry_after_s=retry_after_s)
        self._accept_pool = ThreadPoolExecutor(
            max_workers=max(1, int(accept_workers)),
            thread_name_prefix="http-worker")

    def process_request(self, request, client_address) -> None:
        """Hand the accepted connection to the fixed accept pool
        (replaces ThreadingMixIn's thread-per-connection)."""
        self._accept_pool.submit(self._work, request, client_address)

    def _work(self, request, client_address) -> None:
        try:
            self.finish_request(request, client_address)
        except Exception:
            self.handle_error(request, client_address)
        finally:
            self.shutdown_request(request)

    def server_close(self) -> None:
        super().server_close()
        self._accept_pool.shutdown(wait=False)
        self.core.close()


def serve(src, host: str = "127.0.0.1", port: int = 8765, *,
          cache_bytes: int = 256 << 20, auto_reload: bool = True,
          shard_map=None, shard_id: str | None = None,
          verbose: bool = False, log_json: bool = False,
          accept_workers: int = 32, decode_workers: int = 4,
          queue_depth: int = 16, retry_after_s: float = 1.0,
          keepalive_timeout: float = 30.0,
          ) -> RegionHTTPServer:
    """Build a region endpoint from a ``.tacz`` path, a RegionServer, or
    a sharded router.

    :param src: a ``.tacz`` path (a :class:`RegionServer` is built for
        it), a variant-set directory (a
        :class:`repro.serving.variants.VariantServer` is built — the
        endpoint then honors ``target``/``variant`` request fields), an
        already-configured :class:`RegionServer`, or a
        :class:`repro.serving.sharded.ShardedRegionRouter` — a mounted
        router serves the same routes (``/v1/meta|stats|metrics|health|
        region|regions``), so a fleet's front door speaks the identical
        wire protocol as its shards.
    :param host: bind address.
    :param port: bind port; ``0`` binds an ephemeral port — read it back
        from ``server_address``.
    :param cache_bytes: sub-block cache budget (path form only).
    :param auto_reload: run the footer-CRC hot-swap check per request
        (path form only).
    :param shard_map: optional :class:`repro.serving.sharded.ShardMap` —
        with ``shard_id``, the endpoint serves (and caches) only the
        sub-blocks that shard owns (path form only).
    :param shard_id: this endpoint's shard in ``shard_map``.
    :param verbose: emit the structured access log at INFO instead of
        DEBUG (the ``repro.serving.http`` logger; quiet by default).
    :param log_json: emit each access-log record as one JSON object
        (``method``, ``path``, ``status``, ``duration_ms``,
        ``request_id``) instead of the plain-text line — machine-parsable
        fleet logs; the plain-text format is the unchanged default.
    :param accept_workers: fixed connection-handling pool size (replaces
        the old unbounded thread-per-connection model).
    :param decode_workers: decode pool size — the hard cap on concurrent
        region decodes.
    :param queue_depth: admitted-but-waiting decode-unit budget beyond
        the workers; a batch that would exceed
        ``decode_workers + queue_depth`` in-flight units is rejected
        with 429 + ``Retry-After``.
    :param retry_after_s: the ``Retry-After`` hint on rejections.
    :param keepalive_timeout: idle keep-alive connections are closed
        after this many seconds so they cannot pin accept-pool workers.
    :returns: the (not yet running) HTTP server; call ``serve_forever()``
        (typically on a thread) and ``shutdown()`` to stop.
    :raises ValueError: if only one of ``shard_map``/``shard_id`` is
        given, or the file fails TACZ validation.
    """
    if not isinstance(src, RegionServer) and \
            not hasattr(src, "get_regions_with_crc"):
        if vrt.is_variant_set(src):
            from .variants import VariantServer
            src = VariantServer(src, cache_bytes=cache_bytes,
                                auto_reload=auto_reload,
                                shard_map=shard_map, shard_id=shard_id)
        else:
            src = RegionServer(src, cache_bytes=cache_bytes,
                               auto_reload=auto_reload,
                               shard_map=shard_map, shard_id=shard_id)
    return RegionHTTPServer((host, port), src, verbose=verbose,
                            log_json=log_json,
                            accept_workers=accept_workers,
                            decode_workers=decode_workers,
                            queue_depth=queue_depth,
                            retry_after_s=retry_after_s,
                            keepalive_timeout=keepalive_timeout)
