"""Thin stdlib HTTP endpoint over :class:`repro.serving.regions.RegionServer`.

JSON for metadata, raw little-endian float32 for region payloads — no
framework, no third-party deps (``http.server`` + ``ThreadingHTTPServer``).

Routes::

    GET  /v1/meta                 snapshot + level metadata + cache stats
    GET  /v1/stats                cache counters only
    GET  /v1/region?level=L&box=x0:x1,y0:y1,z0:z1
                                  one level's crop; body = C-order <f4 bytes,
                                  shape/box/ratio travel in X-TACZ-* headers
    POST /v1/regions              batched: JSON {"boxes": [...], "levels":
                                  [...]?} in; u32 header length + JSON header
                                  + concatenated <f4 payloads out

The batched response header is ``{"snapshot_crc", "results"}`` where
``results[b][l]`` holds ``{level, ratio, box, shape, offset, nbytes}`` and
``offset`` indexes into the payload section that follows the header.
Every request first runs the server's footer-CRC hot-swap check (when the
server was built with ``auto_reload=True``), so an atomically republished
snapshot is picked up without restarting the endpoint.
"""
from __future__ import annotations

import json
import struct
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.io import format as fmt

from .regions import RegionServer

__all__ = ["RegionHTTPServer", "RegionRequestHandler", "serve",
           "format_box", "parse_box"]


def format_box(box) -> str:
    """((x0,x1),(y0,y1),(z0,z1)) → ``"x0:x1,y0:y1,z0:z1"``."""
    return ",".join(f"{int(lo)}:{int(hi)}" for lo, hi in box)


def parse_box(s: str):
    """Inverse of :func:`format_box`; raises ValueError on malformed input."""
    dims = s.split(",")
    if len(dims) != 3:
        raise ValueError("box must have three x0:x1 ranges")
    box = []
    for d in dims:
        lo, _, hi = d.partition(":")
        box.append((int(lo), int(hi)))
    return tuple(box)


class RegionRequestHandler(BaseHTTPRequestHandler):
    """Routes ``/v1/meta|stats|region|regions`` onto one RegionServer."""

    server_version = "taczserve/1"
    protocol_version = "HTTP/1.1"

    # quiet by default — the serving loop should not spam stderr per request
    def log_message(self, *args) -> None:  # pragma: no cover - logging only
        if getattr(self.server, "verbose", False):
            super().log_message(*args)

    @property
    def rs(self) -> RegionServer:
        """The :class:`RegionServer` this endpoint serves."""
        return self.server.region_server

    # ------------------------------ plumbing -------------------------------

    def _send_json(self, obj, status: int = 200) -> None:
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _fail(self, status: int, msg: str) -> None:
        self._send_json({"error": msg}, status=status)

    def _meta(self) -> dict:
        rd = self.rs.reader
        levels = []
        for li, e in enumerate(rd.levels):
            levels.append({
                "level": li, "shape": list(e.shape),
                "ratio": max(int(e.ratio), 1), "eb": e.eb,
                "strategy": fmt.STRATEGY_NAMES.get(e.strategy, "?"),
                "algorithm": fmt.ALGO_NAMES.get(e.algorithm, "?"),
                "n_subblocks": len(e.subblocks),
            })
        meta = {"snapshot_crc": self.rs.snapshot_crc,
                "version": rd.version, "levels": levels,
                "cache": self.rs.cache.stats()}
        if self.rs.shard_map is not None:
            meta["shard"] = {"shard_id": self.rs.shard_id,
                             "n_shards": len(self.rs.shard_map),
                             "shard_map": self.rs.shard_map.to_dict()}
        return meta

    # ------------------------------- routes --------------------------------

    def do_GET(self) -> None:
        """Dispatch ``/v1/meta``, ``/v1/stats``, ``/v1/region``."""
        url = urlparse(self.path)
        if url.path == "/v1/meta":
            # data routes hot-swap inside get_regions (auto_reload);
            # metadata routes run the footer check themselves
            if self.rs.auto_reload:
                self.rs.maybe_reload()
            return self._send_json(self._meta())
        if url.path == "/v1/stats":
            if self.rs.auto_reload:
                self.rs.maybe_reload()
            return self._send_json(self.rs.stats())
        if url.path == "/v1/region":
            return self._get_region(parse_qs(url.query))
        return self._fail(404, f"unknown path {url.path!r}")

    def _get_region(self, q: dict) -> None:
        try:
            level = int(q["level"][0])
            box = parse_box(q["box"][0])
            if not 0 <= level < self.rs.n_levels:
                raise ValueError(f"level {level} out of range")
        except (KeyError, IndexError, ValueError) as exc:
            return self._fail(400, f"bad region query: {exc}")
        try:
            crc, results = self.rs.get_regions_with_crc([box],
                                                        levels=[level])
            roi = results[0][0]
        except ValueError as exc:      # e.g. hot-swap shrank the level count
            return self._fail(400, f"bad region query: {exc}")
        except Exception as exc:       # corrupt payload, missing codec, ...
            return self._fail(500, f"region decode failed: {exc}")
        body = np.ascontiguousarray(roi.data, dtype="<f4").tobytes()
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(body)))
        self.send_header("X-TACZ-Level", str(roi.level))
        self.send_header("X-TACZ-Ratio", str(roi.ratio))
        self.send_header("X-TACZ-Box", format_box(roi.box))
        self.send_header("X-TACZ-Shape",
                         ",".join(str(s) for s in roi.shape))
        self.send_header("X-TACZ-Dtype", "<f4")
        self.send_header("X-TACZ-Snapshot-CRC", str(crc))
        self.end_headers()
        self.wfile.write(body)

    def do_POST(self) -> None:
        """Dispatch ``/v1/regions`` (batched fetch)."""
        url = urlparse(self.path)
        if url.path != "/v1/regions":
            return self._fail(404, f"unknown path {url.path!r}")
        try:
            n = int(self.headers.get("Content-Length", 0))
            req = json.loads(self.rfile.read(n) or b"{}")
            boxes = [tuple((int(lo), int(hi)) for lo, hi in box)
                     for box in req["boxes"]]
            for box in boxes:
                if len(box) != 3:
                    raise ValueError("each box needs three (lo, hi) ranges")
            levels = req.get("levels")
            if levels is not None:
                levels = [int(li) for li in levels]
                for li in levels:
                    if not 0 <= li < self.rs.n_levels:
                        raise ValueError(f"level {li} out of range")
        except (KeyError, TypeError, ValueError,
                json.JSONDecodeError) as exc:
            return self._fail(400, f"bad regions request: {exc}")
        try:
            # the CRC must name the snapshot that *served this batch* —
            # a hot-swap racing the decode must not stamp the new
            # generation on old data (the sharded router trusts this)
            crc, results = self.rs.get_regions_with_crc(boxes,
                                                        levels=levels)
        except ValueError as exc:      # e.g. hot-swap shrank the level count
            return self._fail(400, f"bad regions request: {exc}")
        except Exception as exc:       # corrupt payload, missing codec, ...
            return self._fail(500, f"region decode failed: {exc}")
        payload = bytearray()
        header: dict = {"snapshot_crc": crc, "results": []}
        for per_box in results:
            rows = []
            for roi in per_box:
                raw = np.ascontiguousarray(roi.data, dtype="<f4").tobytes()
                rows.append({"level": roi.level, "ratio": roi.ratio,
                             "box": [list(r) for r in roi.box],
                             "shape": list(roi.shape),
                             "offset": len(payload), "nbytes": len(raw)})
                payload.extend(raw)
            header["results"].append(rows)
        hdr = json.dumps(header).encode()
        body = struct.pack("<I", len(hdr)) + hdr + bytes(payload)
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class RegionHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to one :class:`RegionServer`."""

    daemon_threads = True

    def __init__(self, addr, region_server: RegionServer, *,
                 verbose: bool = False):
        super().__init__(addr, RegionRequestHandler)
        self.region_server = region_server
        self.verbose = verbose


def serve(src, host: str = "127.0.0.1", port: int = 8765, *,
          cache_bytes: int = 256 << 20, auto_reload: bool = True,
          shard_map=None, shard_id: str | None = None,
          verbose: bool = False) -> RegionHTTPServer:
    """Build a region endpoint from a ``.tacz`` path or a RegionServer.

    :param src: a ``.tacz`` path (a :class:`RegionServer` is built for
        it) or an already-configured :class:`RegionServer`.
    :param host: bind address.
    :param port: bind port; ``0`` binds an ephemeral port — read it back
        from ``server_address``.
    :param cache_bytes: sub-block cache budget (path form only).
    :param auto_reload: run the footer-CRC hot-swap check per request
        (path form only).
    :param shard_map: optional :class:`repro.serving.sharded.ShardMap` —
        with ``shard_id``, the endpoint serves (and caches) only the
        sub-blocks that shard owns (path form only).
    :param shard_id: this endpoint's shard in ``shard_map``.
    :returns: the (not yet running) HTTP server; call ``serve_forever()``
        (typically on a thread) and ``shutdown()`` to stop.
    :raises ValueError: if only one of ``shard_map``/``shard_id`` is
        given, or the file fails TACZ validation.
    """
    if not isinstance(src, RegionServer):
        src = RegionServer(src, cache_bytes=cache_bytes,
                           auto_reload=auto_reload, shard_map=shard_map,
                           shard_id=shard_id)
    return RegionHTTPServer((host, port), src, verbose=verbose)
