"""KV-cache compression utilities (int8 + per-(token, head) scales).

The serve-time analogue of the paper's quantization stage: each (token,
head) vector is a "unit block" with its own scale (= local error bound),
mirroring TAC's per-block adaptivity.  Decode-time append/dequant lives in
``repro.models.attention``; this module converts a bf16 prefill cache into
the quantized layout and provides standalone (de)quantizers for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_kv", "dequantize_kv", "quantize_prefill_cache"]


def quantize_kv(x):
    """Quantize one K or V tensor to int8 with per-(token, head) scales.

    :param x: array shaped ``(..., S, H, hd)``, any float dtype.
    :returns: ``(codes, scales)`` — int8 codes of ``x``'s shape and
        float32 scales shaped ``(..., S, H)``; all-zero vectors get
        scale 1 so dequantization is exact for them.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    """Inverse of :func:`quantize_kv`.

    :param q: int8 codes ``(..., S, H, hd)``.
    :param scale: float32 scales ``(..., S, H)``.
    :param dtype: output dtype (bf16 by default — the attention compute
        dtype).
    :returns: the dequantized tensor at ``q``'s shape.
    """
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def quantize_prefill_cache(cfg, state):
    """Convert a prefill-produced bf16 cache tree to the int8 layout."""
    def conv(kv):
        kq, ks = quantize_kv(kv["k"])
        vq, vs = quantize_kv(kv["v"])
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}

    if cfg.family == "hybrid":
        return {"mamba": state["mamba"], "kv": conv(state["kv"])}
    if cfg.family == "ssm":
        return state
    return conv(state)
