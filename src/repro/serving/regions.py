"""``repro.serving.regions`` — region serving over a TACZ container.

The canonical read workload against a compressed AMR snapshot is many
overlapping region queries (AMReX visualization study, arXiv:2309.16980),
where repeated sub-block entropy decodes dominate: the Huffman walk is
bit-serial, so decoding the same hot brick for every query that touches it
wastes almost all of the serving budget.  This module turns a ``.tacz``
file into a queryable region service in three layers:

  * :class:`SubBlockCache` — byte-budgeted LRU over *decoded* bricks,
    keyed on (level, sub-block index), with hit/miss/eviction counters.
    Overlapping queries pay each brick's entropy decode once.
  * :class:`DecodePlanner` — maps a batch of ROI boxes to the minimal set
    of *uncached* sub-blocks, groups them by (level, shape, branch), and
    reconstructs each group through one vectorized
    ``sz.decode_codes_batched`` launch instead of PR 2's per-brick serial
    ``decode_codes`` walk.
  * :class:`RegionServer` — ``get_region(level, box)`` /
    ``get_regions(boxes)`` over one reader + cache + planner, with
    snapshot hot-swap keyed on the TACZ footer's index CRC (an atomically
    republished file is detected by a 20-byte footer read, the cache is
    dropped, queries continue against the new snapshot).

Assembly (box mapping, intersection, mask crop) is the reader's own code
path (``TACZReader.assemble_level_roi``), so every served crop is
bit-identical to ``TACZReader.read_roi`` — cold or warm.  The HTTP
endpoint lives in ``repro.serving.http_api``; the matching client in
``repro.serving.client``.
"""
from __future__ import annotations

import json
import struct
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro import obs
from repro.core import sz
from repro.io import format as fmt
from repro.io import frontier as frt
from repro.io.reader import (WHOLE_LEVEL, Box, ROILevel, TACZReader,
                             open_snapshot, probe_index_crc)
from repro.obs import metrics as obsm

__all__ = ["CacheKey", "SubBlockCache", "DecodePlanner", "PlannedLevel",
           "RegionServer", "WHOLE_LEVEL", "resolve_single_target"]


def resolve_single_target(reader, target) -> str:
    """Validate a distortion target against a *single* snapshot — the
    serving rule for servers/routers that hold one eb variant only.

    The snapshot's recorded frontier (``reader.frontier``) names the
    metrics of the point it was written at; the request is admitted when
    that default point satisfies the target.  A snapshot with no frontier
    — pre-frontier files, or a corrupt ``TACF`` section the reader
    degraded on — cannot prove anything either way, so the request is
    served as-is and counted in ``tacz_variant_fallbacks_total`` (the
    operator's signal that targets are being ignored, not enforced).

    :param reader: an open snapshot reader (``frontier`` attribute
        optional).
    :param target: a :class:`repro.io.frontier.Target` or its string
        form, e.g. ``"psnr>=60"``.
    :returns: the serving variant name — always ``"default"`` here.
    :raises ValueError: on a malformed target spec.
    :raises repro.io.frontier.TargetUnsatisfiable: when the frontier is
        present and the snapshot's own point misses the target (counted
        in ``tacz_variant_unsatisfied_total``).
    """
    if isinstance(target, str):
        target = frt.parse_target(target)
    fr = getattr(reader, "frontier", None)
    point = fr.default_point if fr is not None else None
    if point is None:
        obsm.VARIANT_FALLBACKS.inc()
    elif not target.satisfies(point.metrics):
        obsm.VARIANT_UNSATISFIED.inc()
        raise frt.TargetUnsatisfiable(target, fr.best_value(target.metric))
    obsm.VARIANT_REQUESTS.labels("default").inc()
    return "default"

# planner key: (level index, sub-block index); WHOLE_LEVEL (re-exported
# from repro.io.reader) marks the full reconstruction of a gsp/global
# level (their payload is not block-local).  In the cache itself keys
# carry a leading snapshot-CRC generation tag — see DecodePlanner.fetch.
CacheKey = tuple[int, int]


class SubBlockCache:
    """Thread-safe byte-budgeted LRU of decoded bricks.

    Keys are hashable tuples (the planner uses
    ``(snapshot_crc, level, sub-block index)``); values are float32
    reconstructions (marked read-only — they are shared across requests).
    Insertion evicts least-recently-used entries until the budget holds
    again; an entry larger than the whole budget is not inserted at all —
    it could never be held, and admitting it would flush the hot set.
    """

    def __init__(self, budget_bytes: int = 256 << 20):
        if budget_bytes <= 0:
            raise ValueError("cache budget must be positive")
        self.budget_bytes = int(budget_bytes)
        self._od: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple) -> np.ndarray | None:
        """Look one brick up, counting a hit (entry becomes MRU) or miss.

        :param key: hashable tuple, e.g. ``(gen, level, sub_block)``.
        :returns: the cached read-only array, or None on a miss.
        """
        with self._lock:
            arr = self._od.get(key)
            if arr is None:
                self.misses += 1
                return None
            self._od.move_to_end(key)
            self.hits += 1
            return arr

    def put(self, key: tuple, brick: np.ndarray) -> None:
        """Insert (or replace) one decoded brick, evicting LRU entries
        until the byte budget holds.

        :param key: hashable tuple, e.g. ``(gen, level, sub_block)``.
        :param brick: decoded array; stored C-contiguous and marked
            read-only (it is shared across requests).  A brick larger
            than the whole budget is silently not inserted.
        """
        brick = np.ascontiguousarray(brick)
        brick.setflags(write=False)
        with self._lock:
            old = self._od.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            if brick.nbytes > self.budget_bytes:
                return   # can never be held — don't flush the hot set
            self._od[key] = brick
            self._bytes += brick.nbytes
            while self._bytes > self.budget_bytes and self._od:
                _, victim = self._od.popitem(last=False)
                self._bytes -= victim.nbytes
                self.evictions += 1

    def peek(self, key: tuple) -> np.ndarray | None:
        """Look one brick up *without* touching counters or LRU order.

        The cache-handoff exporter uses this: serializing a shard's hot
        set for a peer must not skew the hit/miss statistics or promote
        entries the serving workload is not actually using.
        """
        with self._lock:
            return self._od.get(key)

    def drop(self, pred) -> int:
        """Remove every entry whose key matches ``pred(key)``.

        :param pred: predicate over full cache keys (e.g. the 3-tuple
            ``(gen, level, sub_block)`` form the planner uses).
        :returns: number of entries removed.
        """
        with self._lock:
            victims = [k for k in self._od if pred(k)]
            for k in victims:
                self._bytes -= self._od.pop(k).nbytes
            return len(victims)

    def clear(self) -> None:
        """Drop every entry (counters are kept — they are lifetime totals)."""
        with self._lock:
            self._od.clear()
            self._bytes = 0

    def swap_generation(self, old_gen: int, new_gen: int,
                        keep_levels: set) -> int:
        """Carry entries across a snapshot hot-swap, dropping the rest.

        Entries keyed ``(old_gen, level, sub_block)`` whose ``level`` is in
        ``keep_levels`` are re-tagged to ``new_gen`` (LRU order preserved);
        every other entry — changed levels, stale generations from raced
        requests — is dropped.  ``swap_generation(g, g', set())`` is
        :meth:`clear`.  The server calls this with the set of levels whose
        :meth:`repro.io.TACZReader.level_signature` did not change, so a
        republish that only touched some levels keeps the others warm.

        :param old_gen: generation tag (snapshot index CRC) to carry from.
        :param new_gen: generation tag of the newly adopted snapshot.
        :param keep_levels: level indices whose decoded bricks stay valid.
        :returns: number of entries carried over.
        """
        with self._lock:
            od: OrderedDict[tuple, np.ndarray] = OrderedDict()
            nbytes = 0
            for key, arr in self._od.items():
                if (len(key) == 3 and key[0] == old_gen
                        and key[1] in keep_levels):
                    od[(new_gen, key[1], key[2])] = arr
                    nbytes += arr.nbytes
            kept = len(od)
            self._od = od
            self._bytes = nbytes
            return kept

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._od

    @property
    def nbytes(self) -> int:
        """Decoded bytes currently held (always ≤ ``budget_bytes``)."""
        with self._lock:
            return self._bytes

    def stats(self) -> dict:
        """Lifetime counters and current occupancy.

        :returns: dict with ``hits``, ``misses``, ``evictions``,
            ``entries``, ``bytes``, ``budget_bytes``.
        """
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "entries": len(self._od),
                    "bytes": self._bytes,
                    "budget_bytes": self.budget_bytes}


@dataclass(frozen=True)
class PlannedLevel:
    """One (level, box) query resolved against the index: which sub-blocks
    the box touches, or whether the whole level must be materialized.

    On a shard-filtered server ``tasks`` holds only *owned* sub-blocks and
    ``owned`` is False for a whole-level plan whose key belongs to another
    shard — such a plan decodes nothing and assembles to zeros (the router
    overlays the owning shard's crop in its place).
    """

    level: int
    lbox: Box
    tasks: tuple[tuple[int, Box], ...]   # (sub-block index, intersection)
    whole_level: bool                    # gsp/global single-payload level
    owned: bool = True                   # False → serve zeros (shard filter)

    def keys(self) -> list[CacheKey]:
        """Cache/placement keys this plan needs decoded.

        :returns: ``[(level, WHOLE_LEVEL)]`` for an owned whole-level
            plan, one ``(level, sub_block)`` key per task otherwise —
            empty for non-owned or empty-box plans.
        """
        if self.whole_level:
            return [(self.level, WHOLE_LEVEL)] if self.owned else []
        return [(self.level, sbi) for sbi, _ in self.tasks]


class DecodePlanner:
    """Batch ROI queries into minimal, grouped decode work.

    ``plan`` resolves (level, box) queries against the reader's index;
    ``fetch`` dedupes the union of needed sub-blocks, consults the cache
    once per unique key, entropy-decodes only the misses, and reconstructs
    them per (level, shape, branch) group through
    ``sz.decode_codes_batched`` — the decode-side analogue of the batched
    SHE encode pipeline.

    :param reader: the open :class:`~repro.io.TACZReader` to plan against.
    :param owned: optional set of ``(level, sub_block)`` keys this planner
        may decode (a shard's slice of ``reader.subblock_keys()``).  When
        given, plans are restricted to owned keys: foreign sub-blocks are
        dropped from ``tasks`` and foreign whole-level plans are marked
        ``owned=False``.  ``None`` (the default) plans everything.
    """

    def __init__(self, reader: TACZReader,
                 owned: set[CacheKey] | None = None):
        self._rd = reader
        self._owned = owned

    def plan(self, queries: list[tuple[int, Box]]) -> list[PlannedLevel]:
        """Resolve ``(level, box)`` queries against the reader's index.

        :param queries: pairs of level index and finest-grid box.
        :returns: one :class:`PlannedLevel` per query, in order.
        :raises ValueError: if a box is not three ``(lo, hi)`` ranges.
        :raises IndexError: if a level index is out of range.
        """
        rd, owned = self._rd, self._owned
        out: list[PlannedLevel] = []
        for li, box in queries:
            if len(box) != 3:
                raise ValueError("box must be ((x0,x1),(y0,y1),(z0,z1))")
            lbox = rd.level_box(li, box)
            if any(hi <= lo for lo, hi in lbox):
                out.append(PlannedLevel(li, lbox, (), False))
            elif rd.levels[li].strategy in TACZReader._SHE_STRATEGIES:
                tasks = rd.intersecting_subblocks(li, lbox)
                if owned is not None:
                    tasks = [t for t in tasks if (li, t[0]) in owned]
                out.append(PlannedLevel(li, lbox, tuple(tasks), False))
            else:
                out.append(PlannedLevel(
                    li, lbox, (), True,
                    owned=owned is None or (li, WHOLE_LEVEL) in owned))
        return out

    def fetch(self, plans: list[PlannedLevel], cache: SubBlockCache,
              ) -> dict[CacheKey, np.ndarray]:
        """Bricks for every key the plans need, decoding only cache misses.

        Each unique key touches the cache exactly once per call, so the
        hit/miss counters reflect unique sub-blocks per request batch, not
        per overlapping box.

        Cache entries are tagged with the snapshot's index CRC: a request
        that raced a hot-swap (old reader, freshly cleared cache) can only
        insert under the *old* generation, which no post-swap request will
        ever look up — stale bricks age out through normal LRU eviction
        instead of being served.

        :param plans: output of :meth:`plan`.
        :param cache: the server's :class:`SubBlockCache`.
        :returns: ``{(level, sub_block): decoded brick}`` covering every
            key of every plan.
        :raises IOError: if a payload fails its CRC check.
        """
        rd = self._rd
        gen = rd.index_crc
        out: dict[CacheKey, np.ndarray] = {}
        missing: list[CacheKey] = []
        missing_set: set[CacheKey] = set()
        for p in plans:
            for key in p.keys():
                if key in out or key in missing_set:
                    continue
                arr = cache.get((gen,) + key)
                if arr is None:
                    missing.append(key)
                    missing_set.add(key)
                else:
                    out[key] = arr
        obsm.PLANNER_SUBBLOCKS.labels("cached").inc(len(out))
        obsm.PLANNER_SUBBLOCKS.labels("decoded").inc(len(missing))
        decoded_bytes = 0
        with obsm.timed(obsm.PLANNER_DECODE_SECONDS.labels(), "decode"):
            # gsp/global levels: single global payload each — decode
            # serially
            groups: dict[tuple[int, tuple[int, ...], int], list[int]] = {}
            for li, sbi in missing:
                if sbi == WHOLE_LEVEL:
                    full = rd.read_level(li)
                    cache.put((gen, li, sbi), full)
                    out[(li, sbi)] = full
                    decoded_bytes += full.nbytes
                else:
                    sb = rd.levels[li].subblocks[sbi]
                    groups.setdefault(
                        (li, rd.subblock_shape(li, sbi), sb.branch),
                        []).append(sbi)
            # SHE sub-blocks: one batched EntropyEngine launch per group's
            # payloads, then one vectorized reconstruction per (level,
            # shape, branch) group — no per-payload serial bit-walk
            # anywhere
            for (li, shape, branch), sbis in groups.items():
                e = rd.levels[li]
                decoded = rd.decode_subblocks(li, sbis)
                codes = np.stack([c for c, _ in decoded])
                betas = (np.stack([b for _, b in decoded])
                         if branch == fmt.BRANCH_REG else None)
                recon = sz.decode_codes_batched(
                    codes, shape, e.eb, branch=fmt.BRANCH_NAMES[branch],
                    block=e.sz_block, betas=betas)
                for sbi, brick in zip(sbis, recon):
                    brick = brick.copy()   # detach from the stacked batch
                    cache.put((gen, li, sbi), brick)
                    out[(li, sbi)] = brick
                    decoded_bytes += brick.nbytes
        obsm.PLANNER_DECODED_BYTES.inc(decoded_bytes)
        return out


class RegionServer:
    """Serve ROI queries from one TACZ snapshot with a hot sub-block cache.

    ``box`` semantics are exactly :meth:`TACZReader.read_roi`'s: half-open
    ranges in finest-grid cells, mapped through each level's coarsening
    ratio.  ``get_region(level, box)`` returns one level's
    :class:`~repro.io.reader.ROILevel`; ``get_regions(boxes)`` plans a
    whole batch at once (one cache pass + one batched decode per group);
    ``get_roi(box)`` mirrors ``read_roi`` (every level, finest first).

    Hot swap: :meth:`maybe_reload` re-reads the file's 20-byte footer and
    compares the index CRC with the serving snapshot's; on change (the
    writer republished via atomic ``os.replace``) the reader is reopened.
    Cache entries for levels whose content signature
    (:meth:`~repro.io.TACZReader.level_signature` — section CRCs, not byte
    offsets) is unchanged are carried over to the new snapshot; the rest
    are dropped.  Pass ``auto_reload=True`` to run the check at the start
    of every request batch (what the HTTP layer does).

    Sharding: pass ``shard_map``/``shard_id`` to restrict the server to
    the sub-blocks the map assigns to that shard.  Foreign sub-blocks are
    never decoded or cached (crops cover them with zeros), so N shard
    servers hold N disjoint cache slices — aggregate cache capacity grows
    ~linearly with N.  The :class:`repro.serving.sharded.ShardedRegionRouter`
    scatter-gathers such servers back into full, bit-identical crops.

    :param path: path of the snapshot to serve — a ``.tacz`` file or a
        multi-part snapshot directory (opened via
        :func:`repro.io.open_snapshot`; the reader surface is the same).
    :param cache_bytes: :class:`SubBlockCache` byte budget (~25 % of the
        decoded level bytes is a good default for overlapping workloads).
    :param auto_reload: run :meth:`maybe_reload` before every batch.
    :param shard_map: an object with ``owner(key) -> shard_id`` (normally
        :class:`repro.serving.sharded.ShardMap`); requires ``shard_id``.
    :param shard_id: this server's shard in ``shard_map``.
    :param entropy_engine: :mod:`repro.core.entropy` engine the reader
        decodes Huffman payloads with on cache misses (``"auto"``/
        ``"numpy"``/``"batched"``/``"pallas"``).  Engines are
        bit-identical, so served crops never depend on the choice;
        hot-swapped readers keep the same engine.
    :raises ValueError: if only one of ``shard_map``/``shard_id`` is given,
        or the file fails TACZ validation.
    :raises OSError: if the file cannot be opened.
    """

    def __init__(self, path, *, cache_bytes: int = 256 << 20,
                 auto_reload: bool = False, shard_map=None,
                 shard_id: str | None = None,
                 entropy_engine: str = "auto"):
        if (shard_map is None) != (shard_id is None):
            raise ValueError("shard_map and shard_id go together")
        self.path = str(path)
        self.entropy_engine = entropy_engine
        self.auto_reload = bool(auto_reload)
        self.shard_map = shard_map
        self.shard_id = shard_id
        #: optional zero-arg callable invoked at the top of every batch —
        #: a fault-injection point for tests/benchmarks (e.g. a
        #: ``time.sleep`` that makes an SLO latency rule fire on demand).
        #: Exceptions it raises surface as request failures.
        self.fault_hook = None
        self.cache = SubBlockCache(cache_bytes)
        self._lock = threading.Lock()
        # readers displaced by a hot swap, with in-flight request counts:
        # a retired reader closes as soon as its last request drains (or
        # immediately when idle), so republishing never accumulates fds
        self._inflight: dict[int, int] = {}
        self._retired: dict[int, TACZReader] = {}
        self._reader = open_snapshot(self.path,
                                     entropy_engine=entropy_engine)
        self._owned = self._compute_owned(self._reader)
        self._planner = DecodePlanner(self._reader, self._owned)

    def _compute_owned(self, reader: TACZReader) -> set[CacheKey] | None:
        if self.shard_map is None:
            return None
        return {k for k in reader.subblock_keys()
                if self.shard_map.owner(k) == self.shard_id}

    # ------------------------------ lifecycle ------------------------------

    def close(self) -> None:
        """Close the current reader and any hot-swap-retired readers."""
        with self._lock:
            self._reader.close()
            for rd in self._retired.values():
                rd.close()
            self._retired.clear()
            self._inflight.clear()

    def __enter__(self) -> "RegionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def reader(self) -> TACZReader:
        """The reader of the snapshot currently being served."""
        return self._reader

    @property
    def n_levels(self) -> int:
        """Level count of the serving snapshot."""
        return self._reader.n_levels

    @property
    def snapshot_crc(self) -> int:
        """Index CRC of the snapshot currently being served."""
        return self._reader.index_crc

    def maybe_reload(self) -> bool:
        """Swap to a republished snapshot; True when a swap happened.

        Cheap (one footer read) and safe to call per request.  A missing
        or truncated file keeps the current snapshot serving — the writer
        publishes atomically, so a half-written state is never adopted.

        Cache entries are carried over for every level whose content
        signature (section/payload CRCs — see
        :meth:`repro.io.TACZReader.level_signature`) matches the new
        snapshot: a republish that recompressed only some levels keeps the
        other levels' decoded bricks warm.  Entries for changed levels are
        dropped.

        :returns: True when a new snapshot was adopted.
        """
        crc = probe_index_crc(self.path)
        if crc is None or crc == self.snapshot_crc:
            return False
        with self._lock:
            if crc == self.snapshot_crc:                  # raced reload
                return False
            try:
                reader = open_snapshot(self.path,
                                       entropy_engine=self.entropy_engine)
            except (OSError, ValueError):
                return False
            # in-flight requests may still hold the old reader — close it
            # when idle, else park it until its last request drains
            old = self._reader
            keep = {li for li in range(min(old.n_levels, reader.n_levels))
                    if old.level_signature(li) == reader.level_signature(li)}
            if self._inflight.get(id(old), 0) == 0:
                old.close()
            else:
                self._retired[id(old)] = old
            self._reader = reader
            self._owned = self._compute_owned(reader)
            self._planner = DecodePlanner(reader, self._owned)
            self.cache.swap_generation(old.index_crc, reader.index_crc,
                                       keep)
        return True

    # ------------------------------- queries -------------------------------

    def get_regions(self, boxes: list[Box],
                    levels: list[int] | None = None,
                    ) -> list[list[ROILevel]]:
        """Serve a batch of boxes; one list of per-level crops per box.

        The whole batch is planned as one unit: overlapping boxes decode
        each hot sub-block once, and cache misses reconstruct in
        vectorized ``(level, shape, branch)`` groups.  On a shard-filtered
        server, cells belonging to foreign sub-blocks come back as zeros.

        :param boxes: half-open boxes in finest-grid cells.
        :param levels: restrict crops to these level indices (default:
            every level, finest first).
        :returns: ``out[b][l]`` = crop of ``boxes[b]`` at ``levels[l]``.
        :raises ValueError: if a level is out of range or a box malformed.
        :raises IOError: if a payload fails its CRC check.
        """
        return self.get_regions_with_crc(boxes, levels)[1]

    def get_regions_with_crc(self, boxes: list[Box],
                             levels: list[int] | None = None,
                             ) -> tuple[int, list[list[ROILevel]]]:
        """:meth:`get_regions` plus the identity of the snapshot that
        actually served the batch.

        A hot-swap can land *while* a batch is decoding against the
        previous reader; ``self.snapshot_crc`` read afterwards would then
        name the new generation for old data.  Callers that publish the
        CRC next to the payload (the HTTP layer, whose CRC the sharded
        router trusts for its generation check) must use this method.

        :returns: ``(index_crc_of_serving_snapshot, results)``.
        """
        if self.auto_reload:
            self.maybe_reload()
        with self._lock:
            rd, planner = self._reader, self._planner
            self._inflight[id(rd)] = self._inflight.get(id(rd), 0) + 1
        span = obs.trace("get_regions")
        span.__enter__()
        t0 = time.perf_counter()
        try:
            hook = self.fault_hook
            if hook is not None:
                hook()
            obsm.SERVER_REGIONS.inc(len(boxes))
            lis = list(range(rd.n_levels)) if levels is None else \
                [int(li) for li in levels]
            for li in lis:
                if not 0 <= li < rd.n_levels:
                    raise ValueError(f"level {li} out of range "
                                     f"(0..{rd.n_levels - 1})")
            queries = [(li, box) for box in boxes for li in lis]
            with obs.trace("plan"):
                plans = planner.plan(queries)
            bricks = planner.fetch(plans, self.cache)

            def fetch_brick(li, sbi, _local_hi):
                return bricks[(li, sbi)]

            def fetch_level(li):
                return bricks[(li, WHOLE_LEVEL)]

            out: list[list[ROILevel]] = []
            it = iter(plans)
            for _ in boxes:
                per_box: list[ROILevel] = []
                for li in lis:
                    p = next(it)
                    if not p.owned:   # foreign whole-level key: zeros —
                        # the router overlays the owning shard's crop
                        data = np.zeros(tuple(max(hi - lo, 0)
                                              for lo, hi in p.lbox),
                                        dtype=np.float32)
                    else:
                        data = rd.assemble_level_roi(p.level, p.lbox,
                                                     fetch_brick,
                                                     fetch_level,
                                                     tasks=p.tasks)
                    per_box.append(ROILevel(
                        level=p.level,
                        ratio=max(int(rd.levels[p.level].ratio), 1),
                        box=p.lbox, data=data))
                out.append(per_box)
            return rd.index_crc, out
        finally:
            span.__exit__(None, None, None)
            obsm.SERVER_REQUEST_SECONDS.labels().observe(
                time.perf_counter() - t0)
            with self._lock:
                n = self._inflight.get(id(rd), 1) - 1
                if n:
                    self._inflight[id(rd)] = n
                else:
                    self._inflight.pop(id(rd), None)
                    retired = self._retired.pop(id(rd), None)
                    if retired is not None:   # last request drained
                        retired.close()

    def get_regions_ex(self, boxes: list[Box],
                       levels: list[int] | None = None, *,
                       target=None, variant: str | None = None,
                       ) -> tuple[int, str | None, list[list[ROILevel]]]:
        """:meth:`get_regions_with_crc` plus distortion-target admission.

        A single-snapshot server holds exactly one eb variant, so the
        only question a ``target`` can ask is whether *this* snapshot's
        recorded frontier point satisfies it (see
        :func:`resolve_single_target`); :class:`repro.serving.variants.
        VariantServer` overrides the surface with real multi-variant
        selection.  This is the method the HTTP layer binds ``target``/
        ``variant`` request fields to.

        :param target: optional distortion target (string or
            :class:`repro.io.frontier.Target`), e.g. ``"psnr>=60"``.
        :param variant: optional explicit variant name — rejected here
            (a single snapshot has no named variants).
        :returns: ``(snapshot_crc, variant_name, results)`` —
            ``variant_name`` is None when no target/variant was given.
        :raises ValueError: on a malformed target or a ``variant`` name.
        :raises repro.io.frontier.TargetUnsatisfiable: when the target
            cannot be met (the HTTP layer maps this to a 400).
        """
        name = None
        if variant is not None:
            raise ValueError(
                f"unknown variant {variant!r}: this endpoint serves a "
                f"single snapshot, not a variant set")
        if target is not None:
            name = resolve_single_target(self._reader, target)
        crc, out = self.get_regions_with_crc(boxes, levels)
        return crc, name, out

    def get_region(self, level: int, box: Box) -> ROILevel:
        """One level's crop of ``box`` (finest-grid cells).

        :param level: level index.
        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :returns: the :class:`~repro.io.reader.ROILevel` crop.
        :raises ValueError: if ``level`` is out of range or ``box``
            malformed.
        """
        return self.get_regions([box], levels=[level])[0][0]

    def get_roi(self, box: Box) -> list[ROILevel]:
        """All levels' crops — the cached mirror of ``read_roi(box)``.

        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :returns: one crop per level, finest first (file order).
        """
        return self.get_regions([box])[0]

    # --------------------------- cache handoff -----------------------------
    #
    # Live resharding moves sub-block ownership between shard servers.
    # The handoff protocol lets the *new* owner start warm: the old owner
    # serializes its decoded bricks for the moved keys (`cache_export`),
    # the new owner ingests them (`cache_import`), and only then does the
    # old owner adopt the new shard map (`reshard`) and drop the keys.
    # The blob mirrors the /v1/regions framing (u32 header length + JSON
    # header + raw <f4 frames) with two integrity gates: a per-entry
    # zlib.crc32 over the frame bytes, and the exporter's snapshot CRC —
    # bricks from a different snapshot generation are skipped wholesale.

    def cache_export(self, keys: list[CacheKey]) -> bytes:
        """Serialize cached decoded bricks for ``keys`` into a handoff blob.

        Keys not currently cached are silently omitted (the importer's
        peer decodes them cold on first touch); lookups bypass the LRU
        and hit/miss counters.  Exported volume is counted in
        ``tacz_cache_handoff_keys_total`` / ``..._bytes_total``
        (``direction="export"``).

        :param keys: ``(level, sub_block)`` pairs to export.
        :returns: the blob — u32 header length, JSON header
            (``snapshot_crc`` + per-entry ``level/sub_block/shape/offset/
            nbytes/crc32``), then the concatenated ``<f4`` frames.
        """
        if self.auto_reload:
            self.maybe_reload()
        gen = self.snapshot_crc
        entries = []
        frames: list[memoryview] = []
        total = 0
        for li, sbi in keys:
            arr = self.cache.peek((gen, int(li), int(sbi)))
            if arr is None:
                continue
            mv = memoryview(np.ascontiguousarray(arr, dtype="<f4")).cast("B")
            entries.append({"level": int(li), "sub_block": int(sbi),
                            "shape": list(arr.shape),
                            "offset": total, "nbytes": len(mv),
                            "crc32": zlib.crc32(mv) & 0xFFFFFFFF})
            frames.append(mv)
            total += len(mv)
        hdr = json.dumps({"snapshot_crc": gen, "entries": entries},
                         sort_keys=True).encode()
        obsm.HANDOFF_KEYS.labels("export").inc(len(entries))
        obsm.HANDOFF_BYTES.labels("export").inc(total)
        return struct.pack("<I", len(hdr)) + hdr + b"".join(frames)

    def cache_import(self, blob: bytes) -> dict:
        """Ingest a :meth:`cache_export` blob into this server's cache.

        Three per-entry gates, in order: entries from a *different
        snapshot generation* than this server currently serves are
        counted ``skipped_stale`` (a hot-swap between export and import
        invalidates the bricks — not an error); entries this server does
        not *own* under its shard map are counted ``skipped_foreign``;
        a truncated frame or a ``crc32`` mismatch raises — corruption in
        a handoff must never seed the cache with wrong data.  Ingest is
        all-or-nothing: every frame is CRC-verified *before* the first
        one touches the cache, so a corrupt blob leaves it untouched.

        :param blob: bytes produced by a peer's :meth:`cache_export`.
        :returns: summary dict — ``imported``, ``skipped_foreign``,
            ``skipped_stale``, ``bytes``, ``snapshot_crc``.
        :raises ValueError: malformed blob, truncated frame, or CRC
            mismatch.
        """
        if self.auto_reload:
            self.maybe_reload()
        if len(blob) < 4:
            raise ValueError("handoff blob shorter than its length prefix")
        hlen = struct.unpack_from("<I", blob)[0]
        if 4 + hlen > len(blob):
            raise ValueError("handoff blob truncated inside its header")
        try:
            head = json.loads(blob[4:4 + hlen])
            src_crc = int(head["snapshot_crc"])
            entries = head["entries"]
        except (KeyError, TypeError, ValueError) as exc:
            raise ValueError(f"malformed handoff header: {exc}") from None
        gen = self.snapshot_crc
        stale = (src_crc & 0xFFFFFFFF) != (gen & 0xFFFFFFFF)
        base = 4 + hlen
        imported = skipped_foreign = skipped_stale = nbytes = 0
        owned = self._owned
        admitted = []                       # verified (key, frame, shape)
        for e in entries:
            li, sbi = int(e["level"]), int(e["sub_block"])
            if stale:
                skipped_stale += 1
                continue
            if owned is not None and (li, sbi) not in owned:
                skipped_foreign += 1
                continue
            off, n = base + int(e["offset"]), int(e["nbytes"])
            frame = blob[off:off + n]
            if len(frame) != n:
                raise ValueError(
                    f"handoff frame truncated for ({li}, {sbi})")
            if zlib.crc32(frame) & 0xFFFFFFFF != int(e["crc32"]):
                raise ValueError(
                    f"handoff CRC mismatch for ({li}, {sbi})")
            admitted.append(((gen, li, sbi), frame,
                             tuple(int(s) for s in e["shape"])))
        for key, frame, shape in admitted:
            arr = np.frombuffer(frame, dtype="<f4").reshape(shape).copy()
            self.cache.put(key, arr)
            imported += 1
            nbytes += len(frame)
        obsm.HANDOFF_KEYS.labels("import").inc(imported)
        obsm.HANDOFF_BYTES.labels("import").inc(nbytes)
        return {"imported": imported, "skipped_foreign": skipped_foreign,
                "skipped_stale": skipped_stale, "bytes": nbytes,
                "snapshot_crc": gen}

    def reshard(self, shard_map, shard_id: str | None = None) -> int:
        """Adopt a new shard map, dropping cache entries for keys this
        server no longer owns.

        Ordering matters for a live fleet: the *router* must adopt the
        new map (and the new owner must import the moved bricks) before
        old owners call this — a server that reshards early serves zeros
        for its moved keys while the router still queries it for them.

        :param shard_map: the new map (``owner(key) -> shard_id``).
        :param shard_id: this server's shard in the new map (defaults to
            its current ``shard_id``).
        :returns: number of cache entries dropped (now-foreign keys).
        """
        with self._lock:
            self.shard_map = shard_map
            if shard_id is not None:
                self.shard_id = shard_id
            self._owned = self._compute_owned(self._reader)
            self._planner = DecodePlanner(self._reader, self._owned)
            owned = self._owned
        if owned is None:
            return 0
        return self.cache.drop(
            lambda k: len(k) == 3 and (k[1], k[2]) not in owned)

    def stats(self) -> dict:
        """Cache counters plus snapshot identity (and shard info when
        shard-filtered).

        Also refreshes the ``tacz_cache_*`` gauges of the default obs
        registry and reports ``latency`` — request-count plus
        p50/p90/p99 estimates (milliseconds) derived from the
        ``tacz_server_request_seconds`` histogram's buckets.  The
        histogram is process-wide and lifetime (it survives hot swaps,
        like the cache counters).

        :returns: dict with ``hits/misses/evictions/entries/bytes/
            budget_bytes/snapshot_crc/n_levels/latency`` and, on a shard,
            ``shard`` = ``{shard_id, n_shards, owned_keys}``.
        """
        s = self.cache.stats()
        obsm.refresh_cache_gauges(s)
        s["snapshot_crc"] = self.snapshot_crc
        s["n_levels"] = self.n_levels
        hist = obsm.SERVER_REQUEST_SECONDS.labels()
        lat = {"count": hist.count}
        for q, key in ((0.5, "p50_ms"), (0.9, "p90_ms"), (0.99, "p99_ms")):
            est = hist.quantile(q)
            lat[key] = None if est is None else round(est * 1000.0, 3)
        mean = hist.mean()
        lat["mean_ms"] = None if mean is None else round(mean * 1000.0, 3)
        s["latency"] = lat
        if self.shard_map is not None:
            s["shard"] = {"shard_id": self.shard_id,
                          "n_shards": len(self.shard_map),
                          "owned_keys": len(self._owned or ())}
        return s

    def health(self) -> dict:
        """Liveness/readiness report (the body of ``GET /v1/health``).

        Three checks:

        * ``snapshot`` — the published file's footer CRC is readable
          (probe failure ⇒ ``down``: the server could not adopt a
          republish and a restart would not come back), and matches the
          serving snapshot (mismatch ⇒ ``degraded``: an atomic republish
          landed but has not been adopted yet — with ``auto_reload`` the
          next request heals it).
        * ``cache`` — byte-budget headroom (informational: a full cache
          evicting is normal steady state, never unhealthy by itself).
        * ``shard`` — present on a shard-filtered server: this shard's
          identity and owned-key count, so a fleet collector can see a
          shard serving zero keys after a resharding bug.

        :returns: dict with ``status`` (``"ok"`` | ``"degraded"`` |
            ``"down"``), ``snapshot_crc``, and per-check detail under
            ``checks``.  Never raises — a broken server must still be
            able to say *how* it is broken.
        """
        checks: dict = {}
        status = "ok"
        try:
            probe = probe_index_crc(self.path)
        except Exception:   # unreadable path: treat like a failed probe
            probe = None
        if probe is None:
            status = "down"
        elif probe != self.snapshot_crc:
            status = "degraded"
        checks["snapshot"] = {"ok": probe is not None,
                              "serving_crc": self.snapshot_crc,
                              "file_crc": probe,
                              "stale": (None if probe is None
                                        else probe != self.snapshot_crc)}
        cs = self.cache.stats()
        headroom = 1.0 - cs["bytes"] / cs["budget_bytes"]
        checks["cache"] = {"ok": True,
                           "budget_bytes": cs["budget_bytes"],
                           "bytes": cs["bytes"],
                           "headroom": round(headroom, 4)}
        if self.shard_map is not None:
            owned = len(self._owned or ())
            checks["shard"] = {"ok": owned > 0,
                               "shard_id": self.shard_id,
                               "n_shards": len(self.shard_map),
                               "owned_keys": owned}
        return {"status": status, "role": "server",
                "snapshot_crc": self.snapshot_crc, "checks": checks}
