"""Serving: prefill/decode steps, batched engine, compressed KV cache."""
