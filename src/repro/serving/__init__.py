"""``repro.serving`` — serving stacks over the TAC+ reproduction.

Two independent subsystems live here:

**TACZ region serving** (numpy/stdlib-only, re-exported below) turns a
``.tacz`` snapshot into a queryable region service and scales it out:

  * :class:`~repro.serving.regions.SubBlockCache` — thread-safe,
    byte-budgeted LRU of *decoded* bricks keyed ``(level, sub_block)``.
  * :class:`~repro.serving.regions.DecodePlanner` — a batch of ROI boxes
    → the minimal uncached sub-block set, reconstructed in vectorized
    ``(level, shape, branch)`` groups.
  * :class:`~repro.serving.regions.RegionServer` — cached, bit-identical
    mirror of ``TACZReader.read_roi`` with footer-CRC snapshot hot-swap
    (warm entries carry over for levels whose payload CRCs are
    unchanged) and an optional shard filter.
  * :class:`~repro.serving.core.AsyncServingCore` — bounded worker-pool
    execution front with admission control: per-level decode-unit
    splitting, 429/503 + ``Retry-After`` backpressure, and a
    ``tacz_server_backpressure_total`` rejection counter.
  * :mod:`~repro.serving.http_api` / :class:`~repro.serving.client.
    RegionClient` — stdlib HTTP endpoint and client (JSON metadata, raw
    ``<f4`` region payloads) — worker-pooled, with busy-aware client
    retry and the ``/v1/cache/export|import`` resharding handoff routes.
  * :class:`~repro.serving.sharded.ShardMap` /
    :class:`~repro.serving.sharded.ShardedRegionRouter` — consistent-hash
    placement of sub-blocks over N shard endpoints and the scatter-gather
    router that reassembles full crops (replica retry + local fallback).
  * :class:`~repro.serving.loadgen.LoadGenerator` /
    :class:`~repro.serving.loadgen.ZipfWorkload` — open-loop Zipf
    mixed-ROI traffic generation with exact client-side p50/p99,
    saturation detection, and sampled bit-identity verification.
  * :class:`~repro.serving.variants.VariantServer` — distortion-aware
    serving of multi-variant snapshot sets (``variants.json`` catalogs
    the autotuner publishes): a ``target``/``variant`` request field
    selects the cheapest eb variant satisfying an application-metric
    target.  See ``docs/tuning.md``.

See ``docs/serving.md`` for the architecture guide and ``docs/
tacz_format.md`` for the container byte layout.

**LM serving** (``repro.serving.engine``, ``repro.serving.kv_cache``)
imports JAX and is loaded explicitly by its callers — it is deliberately
not re-exported here so the region-serving path stays importable on
hosts without an accelerator stack.
"""
from .client import RegionClient
from .core import AsyncServingCore, ServerBusy
from .http_api import RegionHTTPServer, serve
from .loadgen import LoadGenerator, LoadReport, ZipfWorkload, client_fetch
from .regions import DecodePlanner, RegionServer, SubBlockCache
from .sharded import ShardedRegionRouter, ShardMap
from .variants import VariantServer

__all__ = ["AsyncServingCore", "DecodePlanner", "LoadGenerator",
           "LoadReport", "RegionClient", "RegionHTTPServer",
           "RegionServer", "ServerBusy", "ShardMap",
           "ShardedRegionRouter", "SubBlockCache", "VariantServer",
           "ZipfWorkload", "client_fetch", "serve"]
