"""Serving: prefill/decode steps, batched engine, compressed KV cache,
and the TACZ region-serving subsystem.

The LM-serving pieces (``repro.serving.engine``, ``repro.serving.kv_cache``)
import JAX and are loaded explicitly by their callers.  The region-serving
subsystem (``repro.serving.regions`` + ``http_api`` + ``client``) is
numpy/stdlib-only and re-exported here.
"""
from .client import RegionClient
from .http_api import RegionHTTPServer, serve
from .regions import DecodePlanner, RegionServer, SubBlockCache

__all__ = ["DecodePlanner", "RegionClient", "RegionHTTPServer",
           "RegionServer", "SubBlockCache", "serve"]
