"""``repro.serving.sharded`` — multi-host sharded region serving.

One :class:`~repro.serving.regions.RegionServer` caps the warm working
set at a single host's cache budget; on large AMR levels (AMRIC, Wang et
al. 2023 makes the same point for the write side) the per-level sub-block
count far exceeds what one host can hold decoded.  This module spreads
the ``(level, sub_block)`` key universe of a snapshot over N shard
servers and reassembles full crops on the way back:

  * :class:`ShardMap` — deterministic rendezvous (highest-random-weight)
    placement of ``(level, sub_block)`` keys onto named shards.  Stable
    under shard add/remove (only keys touching the added/removed shard
    move), independent of shard-list order and of ``PYTHONHASHSEED``
    (keyed BLAKE2b), and serializable — clients and servers built from
    the same config compute identical owners, which is what makes the
    server-side shard filter and the client-side scatter compose.
  * :class:`ShardedRegionRouter` — splits a batch of ROI boxes into
    per-shard sub-block fetch sets via the same
    :class:`~repro.serving.regions.DecodePlanner` box→sub-block mapping
    the servers use, scatter-gathers over the PR 3 HTTP wire format
    (concurrent ``POST /v1/regions`` per shard×level group), overlays the
    returned crops, and falls back per group — replica endpoints first,
    then a direct local :class:`~repro.io.TACZReader` decode — so one
    unreachable shard degrades throughput, not availability or
    correctness.

Each shard runs the stock ``RegionServer``/``http_api`` stack with a
shard filter (``RegionServer(shard_map=..., shard_id=...)``): it decodes
and caches only owned sub-blocks, so N shards hold N disjoint cache
slices and aggregate cache capacity scales ~linearly.  Reassembled crops
are bit-identical to a single unsharded server (property-tested), because
every cell of a crop is produced by exactly one owner through the shared
``assemble_level_roi`` code path.  Snapshot hot-swaps propagate through
the footer ``index_crc``: the router revalidates its own file per batch,
shards auto-reload per request, and a shard still serving a different
snapshot generation is treated as failed for that batch (replica/local
fallback) instead of silently mixing generations.
"""
from __future__ import annotations

import json
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait

import numpy as np

from repro import obs
from repro.io import placement
from repro.io import variants as vrt
from repro.obs import metrics as obsm
from repro.io.reader import (WHOLE_LEVEL, Box, ROILevel, TACZReader,
                             open_snapshot, probe_index_crc)

from .client import RegionAPIError, RegionClient
from .regions import CacheKey, DecodePlanner, resolve_single_target

__all__ = ["ShardMap", "ShardedRegionRouter"]


class ShardMap:
    """Deterministic rendezvous-hash placement of sub-block keys.

    Every ``(level, sub_block)`` key scores each shard with a keyed
    64-bit BLAKE2b of ``(seed, level, sub_block, shard_id)`` and is owned
    by the highest score.  Rendezvous hashing gives the two properties a
    serving fleet needs when resizing:

      * adding a shard moves only the keys whose new highest score is the
        added shard (~``1/(N+1)`` of them) — no key moves between two
        pre-existing shards;
      * removing a shard moves only the keys it owned.

    Ownership is a pure function of ``(shards, seed, key)``: it does not
    depend on shard-list order, process, platform, or ``PYTHONHASHSEED``,
    so a router and its shard servers agree as long as they were built
    from the same serialized config (:meth:`to_json`/:meth:`from_json`).

    The scoring function itself lives in :mod:`repro.io.placement` — the
    same rule the multi-part parallel writer partitions part files with,
    so a map built from a multi-part manifest's ``partition`` config
    (``ShardMap.from_dict(reader.partition)``) assigns each shard
    exactly the keys its part file holds.

    :param shards: shard identifiers (non-empty unique strings) — usually
        the names the deployment uses to look up endpoints.
    :param seed: placement salt; changing it reshuffles every key.
    :raises ValueError: on an empty/duplicate shard list or empty ids.
    """

    _ALGORITHM = placement.ALGORITHM

    def __init__(self, shards, *, seed: int = 0):
        shards = [str(s) for s in shards]
        if not shards:
            raise ValueError("ShardMap needs at least one shard")
        if any(not s for s in shards):
            raise ValueError("shard ids must be non-empty strings")
        if len(set(shards)) != len(shards):
            raise ValueError(f"duplicate shard ids in {shards!r}")
        self.shards: tuple[str, ...] = tuple(sorted(shards))
        self.seed = int(seed)

    # ------------------------------ placement ------------------------------

    def _score(self, shard: str, key: CacheKey) -> int:
        return placement.score(self.seed, key, shard)

    def owner(self, key: CacheKey) -> str:
        """The shard owning one ``(level, sub_block)`` key.

        :param key: ``(level_index, sub_block_index)``;
            ``sub_block_index`` is :data:`~repro.io.reader.WHOLE_LEVEL`
            for single-payload levels.
        :returns: the owning shard id.
        """
        return placement.owner(self.shards, self.seed, key)

    def partition(self, keys) -> dict[str, list[CacheKey]]:
        """Group keys by owner.

        :param keys: iterable of ``(level, sub_block)`` keys.
        :returns: ``{shard_id: [keys it owns]}`` — only shards owning at
            least one key appear.
        """
        out: dict[str, list[CacheKey]] = {}
        for key in keys:
            out.setdefault(self.owner(key), []).append(key)
        return out

    # ------------------------------ resizing -------------------------------

    def with_shard(self, shard_id: str) -> "ShardMap":
        """A new map with ``shard_id`` added (same seed).

        :raises ValueError: if the shard already exists.
        """
        return ShardMap(self.shards + (str(shard_id),), seed=self.seed)

    def without_shard(self, shard_id: str) -> "ShardMap":
        """A new map with ``shard_id`` removed (same seed).

        :raises ValueError: if the shard is unknown, or it was the last.
        """
        if str(shard_id) not in self.shards:
            raise ValueError(f"unknown shard {shard_id!r}")
        return ShardMap([s for s in self.shards if s != str(shard_id)],
                        seed=self.seed)

    def grow(self, shard_id: str, keys,
             ) -> tuple["ShardMap", list[CacheKey]]:
        """The map with ``shard_id`` added, plus exactly which of
        ``keys`` change owner — the live-resharding work list.

        Rendezvous hashing guarantees every moved key's *new* owner is
        the added shard (no key moves between two pre-existing shards),
        and only ~``1/(N+1)`` of the keys move at all.  The moved list
        drives the cache handoff: each moved key's old owner exports its
        decoded brick, the new shard imports it, and the fleet serves
        warm through the transition.

        :param shard_id: the shard to add.
        :param keys: the key universe to diff ownership over (normally
            ``reader.subblock_keys()``).
        :returns: ``(new_map, moved_keys)``.
        :raises ValueError: if the shard already exists.
        """
        new = self.with_shard(shard_id)
        moved = [k for k in keys if self.owner(k) != new.owner(k)]
        return new, moved

    # ---------------------------- serialization ----------------------------

    def to_dict(self) -> dict:
        """JSON-safe config; :meth:`from_dict` rebuilds an equal map."""
        return {"algorithm": self._ALGORITHM, "seed": self.seed,
                "shards": list(self.shards)}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardMap":
        """Inverse of :meth:`to_dict`.

        :raises ValueError: if the config names a different placement
            algorithm (a config from a future/incompatible version must
            fail loudly, not silently place keys elsewhere).
        """
        algo = d.get("algorithm", cls._ALGORITHM)
        if algo != cls._ALGORITHM:
            raise ValueError(f"unsupported shard-map algorithm {algo!r}")
        return cls(d["shards"], seed=int(d.get("seed", 0)))

    def to_json(self) -> str:
        """Canonical JSON form of :meth:`to_dict` (stable key order)."""
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ShardMap":
        """Inverse of :meth:`to_json`."""
        return cls.from_dict(json.loads(s))

    # ------------------------------- dunder --------------------------------

    def __len__(self) -> int:
        return len(self.shards)

    def __eq__(self, other) -> bool:
        return (isinstance(other, ShardMap) and self.shards == other.shards
                and self.seed == other.seed)

    def __hash__(self) -> int:
        return hash((self.shards, self.seed))

    def __repr__(self) -> str:
        return f"ShardMap(shards={list(self.shards)!r}, seed={self.seed})"


class _Part:
    """One rectangle of one planned (box, level) query: where it comes
    from (level-cell intersection box) and where it lands (plan index)."""

    __slots__ = ("plan_idx", "isect")

    def __init__(self, plan_idx: int, isect: Box):
        self.plan_idx = plan_idx
        self.isect = isect


class ShardedRegionRouter:
    """Scatter-gather region queries across shard-filtered region servers.

    The router plans a batch exactly like a single
    :class:`~repro.serving.regions.RegionServer` (same
    :class:`~repro.serving.regions.DecodePlanner` box→sub-block mapping
    against a local reader of the same snapshot), assigns every needed
    sub-block to its owner through the :class:`ShardMap`, and issues one
    batched ``POST /v1/regions`` per (shard, level) group — concurrently,
    over the unmodified PR 3 wire format.  Each returned crop covers the
    intersection of one query box with one owned sub-block (or a whole
    gsp/global level), and is pasted into the output at the same offsets
    the single server's assembly would write — which is why the result is
    bit-identical to an unsharded ``get_regions``.

    Failure handling is per group: endpoints for a shard are tried in
    order (primary, then replicas); a connection error, HTTP error,
    malformed response, or a shard answering for a *different snapshot
    generation* (footer ``index_crc`` mismatch) moves to the next
    endpoint, and when all are exhausted the group is decoded directly
    from the local file (``TACZReader.read_level_box``) — unless
    ``local_fallback=False``, in which case the batch raises.

    :param path: local path of the snapshot — a ``.tacz`` file, a
        multi-part snapshot directory, or a multi-variant set directory
        (``variants.json`` catalog; distortion-target batches then route
        per selected variant) — used for planning and for the fallback
        decode; on a multi-host deployment this is the replicated copy
        of the same published snapshot.
    :param shard_map: the :class:`ShardMap` the shard servers were
        configured with (same serialized config — ownership must agree).
    :param endpoints: ``{shard_id: url}`` or ``{shard_id: [url, ...]}``
        (first is primary, rest are replicas).  A shard missing from the
        dict is served through the local fallback.
    :param timeout: per-request socket timeout, seconds.
    :param local_fallback: decode groups locally when every endpoint of
        the owning shard failed (default True).
    :param auto_reload: revalidate the local snapshot (footer CRC) at the
        start of every batch, like the servers do per request.
    :param max_workers: concurrent shard requests per batch.
    :param load_balance: rotate read traffic across a shard's healthy
        endpoints (round-robin per request group) instead of always
        hitting the primary and treating replicas as failover-only.  An
        endpoint that fails is demoted to last place until it next
        succeeds; correctness is unchanged either way (every endpoint of
        a shard serves identical bytes, and failures still walk the
        remaining endpoints then the local fallback).
    :param busy_retries: per request group, how many 429/503 +
        ``Retry-After`` rejections to wait out *on the same endpoint*
        before treating it as failed.  Busy is not down: these waits are
        counted as retries but never as endpoint failures, and never
        demote the endpoint in the load-balance rotation.
    :param busy_backoff_cap: upper bound in seconds on each honored
        ``Retry-After`` sleep.
    :raises ValueError: if the snapshot fails validation.
    :raises OSError: if the snapshot cannot be opened.
    """

    def __init__(self, path, shard_map: ShardMap,
                 endpoints: dict[str, str | list[str]], *,
                 timeout: float = 30.0, local_fallback: bool = True,
                 auto_reload: bool = True, max_workers: int = 8,
                 load_balance: bool = False, busy_retries: int = 2,
                 busy_backoff_cap: float = 2.0):
        self.path = str(path)
        self.shard_map = shard_map
        self.endpoints: dict[str, list[str]] = {
            str(sid): [urls] if isinstance(urls, str) else list(urls)
            for sid, urls in endpoints.items()}
        self.timeout = float(timeout)
        self.local_fallback = bool(local_fallback)
        self.auto_reload = bool(auto_reload)
        self.load_balance = bool(load_balance)
        self.busy_retries = max(0, int(busy_retries))
        self.busy_backoff_cap = float(busy_backoff_cap)
        self._rotation: dict[str, int] = {}      # per-shard round-robin
        self._unhealthy: set[str] = set()        # demoted endpoint urls
        self._clients: dict[str, RegionClient] = {}
        self._pool = ThreadPoolExecutor(max_workers=max(1, int(max_workers)),
                                        thread_name_prefix="shard-router")
        self._lock = threading.Lock()
        # a variant-set directory routes per selected eb variant: the
        # default variant is the always-open planning snapshot, the rest
        # open lazily on first distortion-target/variant request.  The
        # sub-block partition depends only on index geometry (levels ×
        # sub-block indices), which eb does not change, so one shard map
        # covers every variant.
        self._catalog = None
        self._default_variant: str | None = None
        self._variant_paths: dict[str, str] = {}
        self._var_readers: dict[str, tuple[TACZReader, DecodePlanner]] = {}
        self._probe_path = self.path
        if vrt.is_variant_set(self.path):
            self._catalog = vrt.load_catalog(self.path)
            set_dir = self.path
            if os.path.basename(set_dir) == vrt.VARIANTS_NAME:
                set_dir = os.path.dirname(set_dir)
            self._default_variant = str(self._catalog["default"])
            self._variant_paths = {
                str(v["name"]): os.path.join(set_dir, v["file"])
                for v in self._catalog["variants"]}
            self._probe_path = self._variant_paths[self._default_variant]
        self._reader = open_snapshot(self._probe_path)
        self._planner = DecodePlanner(self._reader)
        # readers displaced by a reload, with per-reader in-flight counts
        # (same drain discipline as RegionServer: each retired reader
        # closes when *its* last batch finishes, so sustained overlapping
        # traffic across republishes never accumulates fds)
        self._inflight: dict[int, int] = {}
        self._retired: dict[int, TACZReader] = {}
        self.counters = {"batches": 0, "shard_requests": 0,
                         "endpoint_failures": 0, "local_fallbacks": 0,
                         "retries": 0, "demotions": 0}

    # ------------------------------ lifecycle ------------------------------

    def close(self) -> None:
        """Release the thread pool and every reader (current + retired)."""
        self._pool.shutdown(wait=True)
        with self._lock:
            self._reader.close()
            for rd, _ in self._var_readers.values():
                rd.close()
            self._var_readers.clear()
            for rd in self._retired.values():
                rd.close()
            self._retired.clear()
            self._inflight.clear()

    def __enter__(self) -> "ShardedRegionRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def snapshot_crc(self) -> int:
        """Index CRC of the snapshot the router currently plans against."""
        return self._reader.index_crc

    @property
    def reader(self) -> TACZReader:
        """The local reader the router plans (and falls back) against —
        the same property :class:`~repro.serving.regions.RegionServer`
        exposes, so ``http_api.serve`` can mount a router unchanged."""
        return self._reader

    @property
    def n_levels(self) -> int:
        """Level count of the planning snapshot."""
        return self._reader.n_levels

    def maybe_reload(self) -> bool:
        """Adopt a republished local snapshot; True when a swap happened.

        Mirrors :meth:`RegionServer.maybe_reload`: one footer read, and a
        missing/truncated/corrupt file keeps the current snapshot.  After
        a swap, shard responses carrying the old generation's CRC fail
        validation and fall back, so a batch never mixes generations.

        :returns: True when a new snapshot was adopted.
        """
        crc = probe_index_crc(self._probe_path)
        if crc is None or crc == self.snapshot_crc:
            return False
        with self._lock:
            if crc == self.snapshot_crc:
                return False
            try:
                reader = open_snapshot(self._probe_path)
            except (OSError, ValueError):
                return False
            old = self._reader
            if self._inflight.get(id(old), 0) == 0:
                old.close()
            else:
                self._retired[id(old)] = old
            self._reader = reader
            self._planner = DecodePlanner(reader)
        return True

    # ------------------------------- scatter -------------------------------

    def _client(self, url: str) -> RegionClient:
        # busy_retries=0: backpressure policy lives in _fetch_group (the
        # router decides whether to wait on an endpoint or move on), not
        # in the per-endpoint client
        with self._lock:   # pool-thread safe; clients are thread-safe
            cli = self._clients.get(url)
            if cli is None:
                cli = self._clients[url] = RegionClient(
                    url, timeout=self.timeout, busy_retries=0)
            return cli

    # router counters mirror into the process-wide obs registry so one
    # /v1/metrics scrape covers the fan-out series too
    _COUNTER_METRICS = {
        "batches": obsm.ROUTER_BATCHES,
        "shard_requests": obsm.ROUTER_SHARD_REQUESTS,
        "endpoint_failures": obsm.ROUTER_ENDPOINT_FAILURES,
        "local_fallbacks": obsm.ROUTER_LOCAL_FALLBACKS,
        "retries": obsm.ROUTER_RETRIES,
        "demotions": obsm.ROUTER_DEMOTIONS,
    }

    def _count(self, counter: str) -> None:
        with self._lock:   # += from pool threads is not atomic
            self.counters[counter] += 1
        self._COUNTER_METRICS[counter].inc()

    def _endpoint_order(self, shard: str) -> list[str]:
        """The order this request group walks the shard's endpoints.

        Failover-only (default): primary first, replicas after, as
        configured.  With ``load_balance=True``: round-robin over the
        endpoint list per request group, with endpoints whose last
        attempt failed demoted to the end — reads spread across healthy
        replicas instead of pinning the primary.
        """
        urls = self.endpoints.get(shard, ())
        if not self.load_balance or len(urls) < 2:
            return list(urls)
        with self._lock:
            k = self._rotation[shard] = self._rotation.get(shard, -1) + 1
            unhealthy = set(self._unhealthy)
        k %= len(urls)
        rotated = list(urls[k:]) + list(urls[:k])
        return ([u for u in rotated if u not in unhealthy]
                + [u for u in rotated if u in unhealthy])

    def _mark_endpoint(self, url: str, healthy: bool) -> None:
        demoted = False
        with self._lock:
            if healthy:
                self._unhealthy.discard(url)
            else:
                demoted = url not in self._unhealthy
                self._unhealthy.add(url)
        if demoted:   # count transitions, not repeated failures
            self._count("demotions")

    def _fetch_group(self, rd: TACZReader, shard: str, li: int,
                     parts: list[_Part], request_id: str = "",
                     variant: str | None = None,
                     ) -> tuple[list[np.ndarray], dict]:
        """Crops for one (shard, level) group, in ``parts`` order, plus a
        fan-out summary for the batch's response metadata.

        Tries the shard's endpoints (see :meth:`_endpoint_order`); every
        failure mode — unreachable, HTTP error, stale snapshot
        generation, mis-shaped response — moves on, and the local reader
        is the last resort.  One exception: a 429/503 carrying a
        ``Retry-After`` header means the endpoint is *busy*, not broken
        — the group waits out the hint (capped, up to ``busy_retries``
        times) and retries the *same* endpoint without counting an
        endpoint failure or demoting it.  Attempts beyond the first
        count as retries; the group's wall time lands in
        ``tacz_router_shard_seconds{shard=...}``.

        The summary dict carries ``shard``, ``level``, ``ms``, the
        ``endpoint`` that served (``"local"`` on fallback), and — when
        the shard returned one — its ``trace`` span summary, so the
        router can aggregate per-shard stage timings for the whole batch.

        :raises RuntimeError: when every endpoint failed and
            ``local_fallback`` is off.
        """
        t0 = time.perf_counter()
        r = max(int(rd.levels[li].ratio), 1)
        boxes_f = [tuple((lo * r, hi * r) for lo, hi in p.isect)
                   for p in parts]
        want_crc = rd.index_crc
        errors: list[str] = []

        def _summary(endpoint: str, remote: dict | None) -> dict:
            dt = time.perf_counter() - t0
            obsm.ROUTER_SHARD_SECONDS.labels(shard).observe(dt)
            info = {"shard": shard, "level": li, "endpoint": endpoint,
                    "ms": round(dt * 1000.0, 3)}
            if remote:
                info["trace"] = remote
            return info

        attempts = 0
        for url in self._endpoint_order(shard):
            busy_left = self.busy_retries
            while True:
                try:
                    self._count("shard_requests")
                    if attempts:
                        self._count("retries")
                    attempts += 1
                    header, results = self._client(url).regions_ex(
                        boxes_f, levels=[li],
                        request_id=request_id or None, variant=variant)
                    crc = int(header["snapshot_crc"])
                    if (crc & 0xFFFFFFFF) != want_crc:
                        raise ValueError(
                            f"snapshot mismatch: shard serves {crc:#x}, "
                            f"router plans against {want_crc:#x}")
                    crops = []
                    for part, per_box in zip(parts, results):
                        roi = per_box[0]
                        if tuple(roi.box) != tuple(part.isect):
                            raise ValueError(
                                f"shard returned box {roi.box}, "
                                f"wanted {part.isect}")
                        crops.append(roi.data)
                    self._mark_endpoint(url, healthy=True)
                    return crops, _summary(url, header.get("trace"))
                except RegionAPIError as exc:
                    ra = (exc.headers.get("Retry-After")
                          if exc.headers else None)
                    if (exc.code in (429, 503) and ra is not None
                            and busy_left):
                        # busy, not down: wait out the hint and retry
                        # the same endpoint — never a failure/demotion
                        busy_left -= 1
                        try:
                            delay = float(ra)
                        except ValueError:
                            delay = 1.0
                        time.sleep(min(max(delay, 0.0),
                                       self.busy_backoff_cap))
                        continue
                    self._count("endpoint_failures")
                    self._mark_endpoint(url, healthy=False)
                    errors.append(f"{url}: {exc}")
                    break
                except Exception as exc:  # noqa: BLE001 — per endpoint
                    self._count("endpoint_failures")
                    self._mark_endpoint(url, healthy=False)
                    errors.append(f"{url}: {exc}")
                    break
        if not self.local_fallback:
            raise RuntimeError(
                f"shard {shard!r} unreachable for level {li} and local "
                f"fallback is disabled: {'; '.join(errors) or 'no endpoints'}")
        self._count("local_fallbacks")
        crops = [rd.read_level_box(li, p.isect) for p in parts]
        return crops, _summary("local", None)

    # ------------------------------- queries -------------------------------

    def get_regions(self, boxes: list[Box],
                    levels: list[int] | None = None,
                    ) -> list[list[ROILevel]]:
        """Serve a batch of boxes across the shard fleet.

        Bit-identical to a single unsharded
        ``RegionServer.get_regions(boxes, levels)`` on the same snapshot,
        including when shards are unreachable (fallback path).

        :param boxes: half-open boxes in finest-grid cells.
        :param levels: restrict crops to these level indices (default:
            every level, finest first).
        :returns: ``out[b][l]`` = crop of ``boxes[b]`` at ``levels[l]``.
        :raises ValueError: if a level is out of range or a box malformed.
        :raises RuntimeError: if a shard is unreachable and
            ``local_fallback`` is disabled.
        """
        return self.get_regions_meta(boxes, levels)[0]

    def _resolve_variant(self, target, variant) -> str | None:
        """The variant name a batch's ``target``/``variant`` binds to.

        Over a single snapshot the target is validated against the local
        reader's recorded frontier (:func:`~repro.serving.regions.
        resolve_single_target`); over a variant set the catalog picks
        the cheapest satisfying variant — *locally*, so every shard of
        the batch is pinned to the same choice.

        :raises ValueError: unknown variant name / malformed target.
        :raises repro.io.frontier.TargetUnsatisfiable: no variant
            satisfies the target.
        """
        if self._catalog is None:
            if variant is not None:
                raise ValueError(
                    f"unknown variant {variant!r}: this router serves a "
                    f"single snapshot, not a variant set")
            if target is None:
                return None
            return resolve_single_target(self._reader, target)
        if variant is None and target is None:
            return None
        if variant is not None:
            name = str(variant)
            if name not in self._variant_paths:
                raise ValueError(
                    f"unknown variant {variant!r} (catalog has: "
                    f"{', '.join(sorted(self._variant_paths))})")
        else:
            try:
                name = str(vrt.select_variant(self._catalog,
                                              target)["name"])
            except vrt.TargetUnsatisfiable:
                obsm.VARIANT_UNSATISFIED.inc()
                raise
        obsm.VARIANT_REQUESTS.labels(name).inc()
        return name

    def _rd_planner_locked(self, name: str | None,
                           ) -> tuple[TACZReader, DecodePlanner]:
        """Reader+planner for one resolved variant (caller holds the
        lock).  The default variant *is* the hot-swappable planning
        snapshot; other variants open lazily and live until close."""
        if (name is None or self._catalog is None
                or name == self._default_variant):
            return self._reader, self._planner
        pair = self._var_readers.get(name)
        if pair is None:
            rd = open_snapshot(self._variant_paths[name])
            pair = (rd, DecodePlanner(rd))
            self._var_readers[name] = pair
        return pair

    def get_regions_ex(self, boxes: list[Box],
                       levels: list[int] | None = None, *,
                       target=None, variant: str | None = None,
                       ) -> tuple[int, str | None, list[list[ROILevel]]]:
        """Distortion-aware batch — the router-side mirror of
        :meth:`RegionServer.get_regions_ex` /
        :meth:`repro.serving.variants.VariantServer.get_regions_ex`.

        The variant is resolved *locally* from the router's catalog, the
        name is stamped into every shard request of the batch, and each
        shard's response must carry that variant's snapshot CRC — so a
        batch can never mix crops from different variants.

        :returns: ``(snapshot_crc, variant_name, results)``.
        :raises ValueError: unknown variant name / malformed target.
        :raises repro.io.frontier.TargetUnsatisfiable: no variant
            satisfies the target (HTTP layer maps it to a 400).
        """
        out, meta = self.get_regions_meta(boxes, levels, target=target,
                                          variant=variant)
        return int(meta["snapshot_crc"]), meta.get("variant"), out

    def get_regions_meta(self, boxes: list[Box],
                         levels: list[int] | None = None, *,
                         target=None, variant: str | None = None,
                         ) -> tuple[list[list[ROILevel]], dict]:
        """:meth:`get_regions` plus the batch's fan-out metadata.

        The router mints one request ID per batch and stamps it on every
        shard request (``X-Repro-Request-Id``), so the same ``rid=`` is
        greppable in every shard's access log.  The metadata aggregates
        each (shard, level) group's outcome — endpoint served, wall
        milliseconds, and the shard's own span summary when it returned
        one.

        :param target: optional distortion target (``"psnr>=60"``); see
            :meth:`get_regions_ex`.
        :param variant: optional explicit variant name.
        :returns: ``(out, meta)`` where ``meta`` has ``request_id``,
            ``snapshot_crc`` (the generation that served the batch),
            ``variant`` (the resolved variant name, or None), ``ms``
            (whole-batch wall time), and ``shards`` — one summary dict
            per fan-out group, slowest first.
        """
        rid = obs.new_request_id()
        t_batch = time.perf_counter()
        name = self._resolve_variant(target, variant)
        # only variant-set deployments understand the wire field; a
        # single-snapshot target was fully validated locally above
        wire_variant = name if self._catalog is not None else None
        if self.auto_reload:
            self.maybe_reload()
        with self._lock:
            rd, planner = self._rd_planner_locked(name)
            self._inflight[id(rd)] = self._inflight.get(id(rd), 0) + 1
            # pin the shard map for the whole batch: a concurrent
            # apply_shard_map (live resharding) must not re-owner keys
            # halfway through the scatter loop
            smap = self.shard_map
        try:
            lis = list(range(rd.n_levels)) if levels is None else \
                [int(li) for li in levels]
            for li in lis:
                if not 0 <= li < rd.n_levels:
                    raise ValueError(f"level {li} out of range "
                                     f"(0..{rd.n_levels - 1})")
            self._count("batches")
            plans = planner.plan([(li, box) for box in boxes for li in lis])

            # scatter: group every needed rectangle by (owner shard, level)
            groups: dict[tuple[str, int], list[_Part]] = {}
            for pi, p in enumerate(plans):
                if p.whole_level:
                    owner = smap.owner((p.level, WHOLE_LEVEL))
                    groups.setdefault((owner, p.level), []).append(
                        _Part(pi, p.lbox))
                else:
                    for sbi, isect in p.tasks:
                        owner = smap.owner((p.level, sbi))
                        groups.setdefault((owner, p.level), []).append(
                            _Part(pi, isect))

            futures = {gk: self._pool.submit(self._fetch_group, rd,
                                             gk[0], gk[1], parts, rid,
                                             wire_variant)
                       for gk, parts in groups.items()}
            # settle every group before consuming any result: a raising
            # group must not leave siblings still decoding from a reader
            # the finally block may let a hot-swap close
            wait(list(futures.values()))

            # gather: paste every crop at the offsets the single-server
            # assembly would write (isect relative to the plan's lbox)
            acc: dict[int, np.ndarray] = {}
            for pi, p in enumerate(plans):
                acc[pi] = np.zeros(tuple(max(hi - lo, 0)
                                         for lo, hi in p.lbox),
                                   dtype=np.float32)
            shard_infos: list[dict] = []
            for gk, fut in futures.items():
                crops, info = fut.result()
                shard_infos.append(info)
                for part, crop in zip(groups[gk], crops):
                    dst = tuple(slice(lo - b0, hi - b0)
                                for (lo, hi), (b0, _)
                                in zip(part.isect, plans[part.plan_idx].lbox))
                    acc[part.plan_idx][dst] = crop

            out: list[list[ROILevel]] = []
            it = iter(range(len(plans)))
            for _ in boxes:
                per_box: list[ROILevel] = []
                for li in lis:
                    pi = next(it)
                    p = plans[pi]
                    per_box.append(ROILevel(
                        level=p.level,
                        ratio=max(int(rd.levels[p.level].ratio), 1),
                        box=p.lbox, data=acc[pi]))
                out.append(per_box)
            shard_infos.sort(key=lambda i: i["ms"], reverse=True)
            dt = time.perf_counter() - t_batch
            obsm.ROUTER_BATCH_SECONDS.labels().observe(dt)
            meta = {"request_id": rid,
                    "snapshot_crc": rd.index_crc,
                    "variant": name,
                    "ms": round(dt * 1000.0, 3),
                    "shards": shard_infos}
            return out, meta
        finally:
            with self._lock:
                n = self._inflight.get(id(rd), 1) - 1
                if n:
                    self._inflight[id(rd)] = n
                else:
                    self._inflight.pop(id(rd), None)
                    retired = self._retired.pop(id(rd), None)
                    if retired is not None:   # last batch on it drained
                        retired.close()

    def apply_shard_map(self, shard_map: ShardMap,
                        endpoints: dict | None = None) -> None:
        """Atomically adopt a new shard map (live resharding).

        In-flight batches finish against the map they started with (the
        scatter loop pins it per batch); batches started after this call
        route by the new one.  Fleet ordering matters — see
        :meth:`RegionServer.reshard`: the new shard's server must be up
        (and its moved bricks imported) *before* the router adopts the
        map, and old owners drop moved keys only *after*.

        :param shard_map: the new :class:`ShardMap`.
        :param endpoints: optional replacement endpoint dict
            (``{shard_id: url | [urls]}``); None keeps the current one —
            callers adding a shard usually pass the old dict plus the
            new shard's url.
        """
        with self._lock:
            self.shard_map = shard_map
            if endpoints is not None:
                self.endpoints = {
                    str(sid): [urls] if isinstance(urls, str)
                    else list(urls)
                    for sid, urls in endpoints.items()}

    def get_region(self, level: int, box: Box) -> ROILevel:
        """One level's crop of ``box`` (finest-grid cells).

        :param level: level index.
        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :returns: the :class:`~repro.io.reader.ROILevel` crop.
        :raises ValueError: if ``level`` is out of range.
        """
        return self.get_regions([box], levels=[level])[0][0]

    def get_roi(self, box: Box) -> list[ROILevel]:
        """All levels' crops of one box, finest first.

        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :returns: one crop per level (the sharded mirror of ``read_roi``).
        """
        return self.get_regions([box])[0]

    def get_regions_with_crc(self, boxes: list[Box],
                             levels: list[int] | None = None,
                             ) -> tuple[int, list[list[ROILevel]]]:
        """:meth:`get_regions` plus the serving snapshot's identity —
        the same contract :meth:`RegionServer.get_regions_with_crc` has,
        so ``http_api`` can serve a router behind the identical routes.

        :returns: ``(index_crc_of_serving_snapshot, results)``.
        """
        out, meta = self.get_regions_meta(boxes, levels)
        return int(meta["snapshot_crc"]), out

    def stats(self) -> dict:
        """Router counters plus the planning snapshot's identity.

        Reports ``latency`` — batch count plus p50/p90/p99/mean
        estimates (milliseconds) from ``tacz_router_batch_seconds`` —
        with clean nulls (never NaN) before the first batch.

        :returns: dict with ``batches``, ``shard_requests``,
            ``endpoint_failures``, ``local_fallbacks``, ``snapshot_crc``,
            ``latency``, the shard-map config, and — when read
            load-balancing is on — the currently demoted endpoints.
        """
        s = dict(self.counters)
        s["snapshot_crc"] = self.snapshot_crc
        s["shard_map"] = self.shard_map.to_dict()
        s["load_balance"] = self.load_balance
        if self._catalog is not None:
            with self._lock:
                opened = sorted(self._var_readers)
            s["variants"] = {"default": self._default_variant,
                             "names": sorted(self._variant_paths),
                             "opened": opened}
        hist = obsm.ROUTER_BATCH_SECONDS.labels()
        lat = {"count": hist.count}
        for q, key in ((0.5, "p50_ms"), (0.9, "p90_ms"), (0.99, "p99_ms")):
            est = hist.quantile(q)
            lat[key] = None if est is None else round(est * 1000.0, 3)
        mean = hist.mean()
        lat["mean_ms"] = None if mean is None else round(mean * 1000.0, 3)
        s["latency"] = lat
        if self.load_balance:
            with self._lock:
                s["unhealthy_endpoints"] = sorted(self._unhealthy)
        return s

    def health(self) -> dict:
        """Liveness/readiness report (the body of ``GET /v1/health``).

        Checks the local planning snapshot (footer CRC, like
        :meth:`RegionServer.health`) and **shard reachability**: one
        ``GET /v1/health`` probe per configured endpoint.  A shard is
        reachable when at least one of its endpoints answers with a
        non-``down`` status.  Status is ``ok`` when the snapshot is
        current and every shard is reachable; ``degraded`` when the
        snapshot is stale or some shard is unreachable but
        ``local_fallback`` can cover it; ``down`` when a shard is
        unreachable and there is no fallback, or the snapshot probe
        fails.  Never raises.

        :returns: dict with ``status``, ``snapshot_crc``, and per-check
            detail under ``checks`` (``checks["shards"]`` maps shard id
            → ``{reachable, endpoints: {url: status}}``).
        """
        checks: dict = {}
        status = "ok"
        try:
            probe = probe_index_crc(self._probe_path)
        except Exception:
            probe = None
        if probe is None:
            status = "down"
        elif probe != self.snapshot_crc:
            status = "degraded"
        checks["snapshot"] = {"ok": probe is not None,
                              "serving_crc": self.snapshot_crc,
                              "file_crc": probe,
                              "stale": (None if probe is None
                                        else probe != self.snapshot_crc)}
        shards: dict[str, dict] = {}
        unreachable = 0
        for sid in self.shard_map.shards:
            statuses: dict[str, str] = {}
            reachable = False
            for url in self.endpoints.get(sid, ()):
                try:
                    h = self._client(url).health()
                    statuses[url] = str(h.get("status", "ok"))
                except Exception as exc:   # noqa: BLE001 — per endpoint
                    statuses[url] = f"unreachable: {exc}"
                    continue
                if statuses[url] != "down":
                    reachable = True
            if not reachable:
                unreachable += 1
            shards[sid] = {"reachable": reachable, "endpoints": statuses}
        checks["shards"] = shards
        if unreachable:
            if self.local_fallback and status != "down":
                status = "degraded"
            else:
                status = "down"
        return {"status": status, "role": "router",
                "snapshot_crc": self.snapshot_crc, "checks": checks}
