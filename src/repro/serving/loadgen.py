"""``repro.serving.loadgen`` — an honest, open-loop traffic generator
for the region-serving fleet.

The workload shape comes from the AMReX visualization study (PAPERS.md):
interactive viewers issue *many small, skewed* ROI queries — a handful
of hot regions absorb most of the traffic, with a long tail of one-off
boxes.  :class:`ZipfWorkload` models that as a fixed population of
(box, level) queries whose request probability follows a Zipf law over
popularity rank, drawn from three ROI size classes (≈1/8, 1/4, 1/2 of
the level extent per axis) so a request mix stresses both the cache
(small hot boxes) and the batched decode path (large cold ones).

:class:`LoadGenerator` drives a fetch function with that workload
**open-loop**: request *i* is due at ``t0 + i/rate`` regardless of how
fast earlier requests completed.  This is the honest way to measure a
service — a closed loop (send next after previous returns) lets a slow
server throttle its own load and hides saturation entirely.  Here, when
the fleet falls behind, due requests queue and ``achieved_rate <
offered_rate`` in the report *is* the saturation signal; client-side
per-request latency (which then includes queueing) is recorded for
exact p50/p99 — not bucket estimates.

Responses are sampled for **bit-identity** against a local reader
(``read_level_box`` on the same snapshot), so a load test doubles as a
correctness check: a fleet that got fast by corrupting crops fails the
run.  The SLO engine (:mod:`repro.obs.slo`) renders the pass/fail
verdict on top of a :class:`~repro.obs.collect.FleetCollector` watching
the fleet during the run — see ``benchmarks/bench_loadgen.py``.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Query", "ZipfWorkload", "LoadGenerator", "LoadReport",
           "client_fetch"]


@dataclass(frozen=True)
class Query:
    """One request of the workload population."""

    box: tuple            # half-open finest-grid box, three (lo, hi)
    levels: tuple[int, ...]
    rank: int             # popularity rank (0 = hottest)


def _percentile(sorted_vals: list[float], q: float) -> float | None:
    """Exact nearest-rank-interpolated percentile of a sorted list."""
    if not sorted_vals:
        return None
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class ZipfWorkload:
    """A Zipf-popularity population of mixed-size ROI queries.

    :param shape: the finest level's extent (finest-grid cells) queries
        are drawn inside.
    :param levels: level indices each query asks for (default ``(0,)`` —
        the finest level, the expensive one).
    :param population: number of distinct queries; popularity rank *r*
        (0-based) is requested with probability ∝ ``1/(r+1)**s``.
    :param s: Zipf exponent (≈1.1 matches measured web/viewer traffic:
        skewed but heavy-tailed).
    :param size_fracs: per-axis box extents as fractions of ``shape``,
        cycled over the population — the default mixes ≈1/8, 1/4, and
        1/2-extent boxes.
    :param seed: RNG seed; the same seed reproduces the same population
        *and* the same request sequence.
    """

    def __init__(self, shape, *, levels=(0,), population: int = 64,
                 s: float = 1.1, size_fracs=(0.125, 0.25, 0.5),
                 seed: int = 0):
        if population <= 0:
            raise ValueError("population must be positive")
        self.shape = tuple(int(d) for d in shape)
        self.levels = tuple(int(li) for li in levels)
        self.s = float(s)
        rng = random.Random(seed)
        self.queries: list[Query] = []
        for rank in range(int(population)):
            frac = size_fracs[rank % len(size_fracs)]
            box = []
            for dim in self.shape:
                ext = max(1, min(dim, int(round(dim * frac))))
                lo = rng.randrange(0, max(1, dim - ext + 1))
                box.append((lo, lo + ext))
            self.queries.append(Query(box=tuple(box), levels=self.levels,
                                      rank=rank))
        weights = [1.0 / (r + 1) ** self.s for r in range(population)]
        total = sum(weights)
        self._weights = [w / total for w in weights]
        self._rng = random.Random(seed + 1)
        self._lock = threading.Lock()

    def sample(self) -> Query:
        """Draw one query, Zipf-weighted by popularity rank."""
        with self._lock:
            return self._rng.choices(self.queries, self._weights)[0]

    def sequence(self, n: int) -> list[Query]:
        """The next ``n`` draws (deterministic for a fixed seed +
        call history)."""
        return [self.sample() for _ in range(n)]


@dataclass
class LoadReport:
    """Client-side result of one :meth:`LoadGenerator.run`.

    Latencies are exact (sorted client-side samples, seconds), not
    bucket estimates; ``saturated`` means the open-loop generator could
    not sustain the offered rate — the fleet's capacity is below it.
    """

    offered_rate: float
    achieved_rate: float
    duration_s: float
    requests: int
    errors: int
    verified: int
    mismatches: int
    p50_s: float | None
    p90_s: float | None
    p99_s: float | None
    mean_s: float | None
    max_s: float | None
    max_lag_s: float         # worst send-time slip behind schedule
    error_messages: list[str] = field(default_factory=list)

    @property
    def saturated(self) -> bool:
        """True when achieved throughput fell >10 % under offered."""
        return self.achieved_rate < 0.9 * self.offered_rate

    def to_dict(self) -> dict:
        """JSON-safe summary (what the bench merges into its rows)."""
        return {
            "offered_rate": self.offered_rate,
            "achieved_rate": round(self.achieved_rate, 3),
            "duration_s": round(self.duration_s, 4),
            "requests": self.requests,
            "errors": self.errors,
            "verified": self.verified,
            "mismatches": self.mismatches,
            "saturated": self.saturated,
            "p50_ms": None if self.p50_s is None
            else round(self.p50_s * 1000.0, 3),
            "p90_ms": None if self.p90_s is None
            else round(self.p90_s * 1000.0, 3),
            "p99_ms": None if self.p99_s is None
            else round(self.p99_s * 1000.0, 3),
            "mean_ms": None if self.mean_s is None
            else round(self.mean_s * 1000.0, 3),
            "max_ms": None if self.max_s is None
            else round(self.max_s * 1000.0, 3),
            "max_lag_ms": round(self.max_lag_s * 1000.0, 3),
        }


def client_fetch(client):
    """Adapt a :class:`~repro.serving.client.RegionClient` (or anything
    with ``regions(boxes, levels)``) into the ``fetch(query)`` callable
    :class:`LoadGenerator` drives.

    :returns: ``fetch(query) -> list[ROILevel]`` (the per-level crops of
        the query's single box).
    """
    def fetch(query: Query):
        return client.regions([query.box], levels=list(query.levels))[0]
    return fetch


class LoadGenerator:
    """Open-loop load driver with bounded concurrency and sampled
    bit-identity verification.

    :param fetch: ``fetch(query) -> list[ROILevel]`` — issues one
        request (see :func:`client_fetch`).  Exceptions count as errors;
        they never abort the run.
    :param workload: the :class:`ZipfWorkload` to draw queries from.
    :param rate: offered request rate, requests/second.  The schedule is
        fixed up front (request *i* due at ``i/rate``); a fleet that
        cannot keep up shows ``achieved_rate < rate``.
    :param concurrency: worker threads — the client-side in-flight
        bound.  Open-loop semantics hold as long as workers are
        available; when all are busy past a request's due time, the
        request is sent late and the slip is reported as ``max_lag_s``.
    :param verify_reader: optional local reader (``read_level_box(level,
        box)`` on the same snapshot) for bit-identity sampling.
    :param verify_fraction: fraction of requests to verify (0 disables).
    :param seed: RNG seed for the verify-sampling decisions.
    """

    def __init__(self, fetch, workload: ZipfWorkload, *,
                 rate: float = 50.0, concurrency: int = 4,
                 verify_reader=None, verify_fraction: float = 0.1,
                 seed: int = 0):
        if rate <= 0:
            raise ValueError("rate must be positive")
        if concurrency <= 0:
            raise ValueError("concurrency must be positive")
        self.fetch = fetch
        self.workload = workload
        self.rate = float(rate)
        self.concurrency = int(concurrency)
        self.verify_reader = verify_reader
        self.verify_fraction = float(verify_fraction)
        self._seed = int(seed)

    def _verify(self, query: Query, rois) -> bool:
        """Bit-identity of one response against the local reader."""
        rd = self.verify_reader
        for roi in rois:
            local = rd.read_level_box(roi.level, roi.box)
            if not np.array_equal(np.asarray(roi.data), local):
                return False
        return True

    def run(self, n_requests: int, *,
            actions: dict | None = None) -> LoadReport:
        """Drive ``n_requests`` through the fleet and report.

        Blocks until every request completed (or errored).  Thread-safe
        against the fetch function only to the extent the underlying
        client is — :class:`~repro.serving.client.RegionClient` keeps
        one keep-alive connection per thread, so the default stack is
        safe at any concurrency.

        :param actions: optional ``{request_index: callable}`` — the
            worker that claims index *i* runs ``actions[i]()`` once,
            inline, before waiting for the request's due time.  This is
            how a benchmark injects a mid-run control-plane event (e.g.
            a live reshard) at a deterministic point in the request
            stream.  An action that raises is recorded as an error
            (tagged ``action@i``), so a zero-errors gate catches it.
        :returns: the :class:`LoadReport` (exact client-side
            percentiles, error/mismatch counts, saturation).
        """
        n = int(n_requests)
        actions = dict(actions or {})
        queries = self.workload.sequence(n)
        rng = random.Random(self._seed)
        verify_mask = [self.verify_reader is not None
                       and rng.random() < self.verify_fraction
                       for _ in range(n)]
        latencies: list[float] = []
        errors: list[str] = []
        verified = mismatches = 0
        max_lag = 0.0
        lock = threading.Lock()
        next_idx = [0]
        t0 = time.perf_counter()

        def worker() -> None:
            nonlocal verified, mismatches, max_lag
            while True:
                with lock:
                    i = next_idx[0]
                    if i >= n:
                        return
                    next_idx[0] += 1
                action = actions.pop(i, None) if actions else None
                if action is not None:
                    try:
                        action()
                    except Exception as exc:  # noqa: BLE001 — record
                        with lock:
                            if len(errors) < 20:
                                errors.append(
                                    f"action@{i} "
                                    f"{type(exc).__name__}: {exc}")
                            else:
                                errors.append("")
                due = i / self.rate
                now = time.perf_counter() - t0
                if now < due:
                    time.sleep(due - now)
                    lag = 0.0
                else:
                    lag = now - due       # behind schedule: send late,
                t_send = time.perf_counter()  # report the slip honestly
                try:
                    rois = self.fetch(queries[i])
                    dt = time.perf_counter() - t_send
                    ok = None
                    if verify_mask[i]:
                        ok = self._verify(queries[i], rois)
                except Exception as exc:   # noqa: BLE001 — count, go on
                    dt = time.perf_counter() - t_send
                    with lock:
                        latencies.append(dt)
                        if len(errors) < 20:
                            errors.append(f"{type(exc).__name__}: {exc}")
                        else:
                            errors.append("")
                        max_lag = max(max_lag, lag)
                    continue
                with lock:
                    latencies.append(dt)
                    max_lag = max(max_lag, lag)
                    if ok is not None:
                        verified += 1
                        if not ok:
                            mismatches += 1

        threads = [threading.Thread(target=worker, daemon=True,
                                    name=f"loadgen-{k}")
                   for k in range(self.concurrency)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        lats = sorted(latencies)
        n_err = len(errors)
        return LoadReport(
            offered_rate=self.rate,
            achieved_rate=(n / wall) if wall > 0 else 0.0,
            duration_s=wall,
            requests=n,
            errors=n_err,
            verified=verified,
            mismatches=mismatches,
            p50_s=_percentile(lats, 0.50),
            p90_s=_percentile(lats, 0.90),
            p99_s=_percentile(lats, 0.99),
            mean_s=(sum(lats) / len(lats)) if lats else None,
            max_s=lats[-1] if lats else None,
            max_lag_s=max_lag,
            error_messages=[e for e in errors if e][:20],
        )
