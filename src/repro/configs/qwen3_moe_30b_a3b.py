"""qwen3-moe-30b-a3b — 128-expert top-8 MoE.

[hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768/expert vocab=151936, MoE 128e top-8.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151936, n_experts=128, experts_per_token=8,
    notes="128 experts: strongest SHE analogue (many small blocks)",
)
