"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
input_mode=embeddings: EnCodec frame embeddings are the stubbed frontend.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, input_mode="embeddings", act="gelu",
    notes="EnCodec codebook head (vocab=2048); frame frontend stubbed",
)
