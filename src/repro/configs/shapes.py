"""Assigned input shapes (one set, shared by all 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache/state), ``prefill_32k`` lowers the prefill step,
``train_4k`` lowers ``train_step`` — per the assignment brief.
"""
from __future__ import annotations

from .base import ShapeConfig

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", seq_len=4_096,
                            global_batch=256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", seq_len=32_768,
                               global_batch=32),
    "decode_32k": ShapeConfig("decode_32k", "decode", seq_len=32_768,
                              global_batch=128),
    "long_500k": ShapeConfig("long_500k", "decode", seq_len=524_288,
                             global_batch=1),
}


def shapes_for(cfg) -> dict[str, ShapeConfig]:
    """Cells that actually run for this arch.

    ``long_500k`` needs sub-quadratic attention: it runs for the ssm/hybrid
    families (O(1) or O(shared-KV) serve state) and is recorded as
    ``skipped/full-attention`` for the pure full-attention decoders
    (DESIGN.md §Arch-applicability)."""
    out = dict(SHAPES)
    if not cfg.supports_long_context:
        out.pop("long_500k")
    return out
