"""Per-architecture configs (assignment pool) + registry + shapes."""
from .base import ModelConfig, RunConfig, ShapeConfig  # noqa: F401
from .registry import ARCH_IDS, get_config, smoke_config  # noqa: F401
from .shapes import SHAPES, shapes_for  # noqa: F401
