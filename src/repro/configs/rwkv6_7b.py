"""rwkv6-7b "Finch" — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab_size=65536, rwkv_head=64,
    notes="attention-free: long_500k runs on O(1) matrix state; TAC's "
          "spatial partitioning inapplicable to the dense 2D state",
)
