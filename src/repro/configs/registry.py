"""Architecture registry: ``--arch <id>`` → ModelConfig.

Exact configs from the assignment brief (public-literature sources noted in
each module).  ``smoke_config(id)`` returns the reduced same-family variant
used by the per-arch CPU smoke tests (small layers/width/experts/vocab).
"""
from __future__ import annotations

from dataclasses import replace
from importlib import import_module

from .base import ModelConfig

ARCH_IDS = [
    "granite_moe_1b_a400m",
    "qwen3_moe_30b_a3b",
    "deepseek_7b",
    "llama3_405b",
    "starcoder2_3b",
    "qwen1_5_32b",
    "rwkv6_7b",
    "internvl2_76b",
    "musicgen_medium",
    "zamba2_2_7b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: 2 layer-groups, narrow width, tiny vocab."""
    cfg = get_config(arch)
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = min(cfg.n_kv_heads, n_heads) if n_heads else 0
    if n_heads and cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads  # preserve MHA-ness
    layers = 2 * max(cfg.shared_attn_every, 1)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=layers,
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=32 if n_heads else 0,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head=32,
        rwkv_head=32,
        shared_attn_every=min(cfg.shared_attn_every, 2),
    )
