"""zamba2-2.7b — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]
54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Shared attention (one param set) applied every 6 Mamba2 layers.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, ssm_state=64, ssm_expand=2, ssm_head=64,
    shared_attn_every=6,
    notes="long_500k runs: Mamba2 O(1) state + shared-attn KV; "
          "54 layers = 9 groups of 6",
)
