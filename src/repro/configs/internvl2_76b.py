"""internvl2-76b — InternViT frontend (stub) + InternLM2 backbone.

[arXiv:2404.16821; unverified]
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
input_mode=embeddings: input_specs() provides precomputed patch embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, input_mode="embeddings",
    notes="backbone only; vision frontend stubbed per the brief",
)
