"""Model / run configuration schema.

One :class:`ModelConfig` per assigned architecture (``repro/configs/<id>.py``)
plus reduced smoke variants.  Input shapes are the four assigned cells
(``shapes.py``).  Everything is a frozen dataclass so configs hash cleanly
into jit static args.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["ModelConfig", "ShapeConfig", "RunConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # --- SSM / RWKV ---
    ssm_state: int = 0          # Mamba2 d_state (hybrid family)
    ssm_expand: int = 2
    ssm_head: int = 64
    rwkv_head: int = 64
    # --- hybrid (Zamba2): shared attention block every k core layers ---
    shared_attn_every: int = 0
    # --- modality frontend (vlm/audio): stubbed embeddings in ---
    input_mode: str = "tokens"  # tokens | embeddings
    act: str = "swiglu"         # swiglu | gelu
    norm_eps: float = 1e-5
    # numerics
    dtype: str = "bfloat16"
    # notes for DESIGN.md §Arch-applicability
    notes: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if serve-time state is O(1) in context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    # Parameter counts are derived from the materialized abstract param tree
    # (see repro.models.model.param_counts) — no duplicate analytic formulas.


@dataclass(frozen=True)
class ShapeConfig:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclass(frozen=True)
class RunConfig:
    """Per-(arch × shape × mesh) execution knobs — the perf surface."""

    microbatches: int = 1       # gradient-accumulation steps per train step
    remat: str = "layer"        # none | layer | zero  (activation checkpointing)
    fsdp: bool = False          # shard params/optimizer over the data axis
    seq_shard: bool = False     # shard sequence dim (SP) for long-context
    grad_compress: bool = False # error-bounded int8 grads on the pod axis
    kv_quant: bool = False      # int8 KV cache with per-token scales
    scan_layers: bool = True    # lax.scan over stacked layer params
    optimizer: str = "adamw"    # adamw | adafactor (factored 2nd moment)
    optimizer_dtype: str = "float32"   # moments dtype
    grad_accum_dtype: str = "float32"  # microbatch gradient accumulator
    logits_fp32: bool = True
