"""starcoder2-3b — GQA kv=2, RoPE, gelu MLP, biases. [arXiv:2402.19173; hf]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, act="gelu", qkv_bias=True,
    notes="kv=2 < model-axis width: KV heads replicate, batch/seq shard",
)
