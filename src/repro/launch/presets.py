"""Per-(arch × shape) RunConfig presets — the deployable execution knobs.

These are the *baseline* configurations the dry-run proves out (memory fit
on 16 GB/chip v5e); the §Perf hillclimb starts from here.  Napkin math for
the big cells lives in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from dataclasses import replace

from ..configs.base import RunConfig

__all__ = ["preset"]

_DEFAULT = RunConfig(microbatches=1, remat="layer", fsdp=False,
                     seq_shard=False, kv_quant=False)

# arch → shape-kind → overrides
_TABLE: dict[str, dict[str, dict]] = {
    "granite-moe-1b-a400m": {
        "train": dict(microbatches=8),
    },
    "qwen3-moe-30b-a3b": {
        "train": dict(microbatches=4, fsdp=True),
        "prefill": dict(fsdp=True, seq_shard=True),
        "decode": dict(fsdp=True, seq_shard=True),
    },
    "deepseek-7b": {
        "train": dict(microbatches=4, fsdp=True),
        # kv=32 divides the model axis: cache shards on heads (seq_shard
        # would fight the head-parallel attention einsum)
    },
    "llama3-405b": {
        # Adam moments don't fit a single pod for 405B even in bf16:
        # Adafactor (factored 2nd moment) + bf16 grad accumulation
        "train": dict(microbatches=32, fsdp=True, seq_shard=True,
                      optimizer="adafactor", optimizer_dtype="bfloat16",
                      grad_accum_dtype="bfloat16"),
        "prefill": dict(fsdp=True, seq_shard=True),
        # kv=8 < 16-wide model axis → cache must shard over seq; int8 halves
        "decode": dict(fsdp=True, seq_shard=True, kv_quant=True),
    },
    "starcoder2-3b": {
        "train": dict(microbatches=8),
    },
    "qwen1.5-32b": {
        "train": dict(microbatches=8, fsdp=True, seq_shard=True),
        "prefill": dict(fsdp=True),
        # MHA kv=40 at 32k × batch 128 is 5.5 TB of cache: int8 + sequence
        # sharding is the baseline deployment choice for this cell
        "decode": dict(fsdp=True, seq_shard=True, kv_quant=True),
    },
    "rwkv6-7b": {
        "train": dict(microbatches=4, fsdp=True),
    },
    "internvl2-76b": {
        # mb4 = smallest accumulation count that fits (14.7 GB): FSDP
        # re-gather traffic scales with mb (EXPERIMENTS §Perf Cell B)
        "train": dict(microbatches=4, fsdp=True, seq_shard=True,
                      optimizer_dtype="bfloat16"),
        "prefill": dict(fsdp=True, seq_shard=True),
        "decode": dict(fsdp=True, seq_shard=True, kv_quant=True),
    },
    "musicgen-medium": {
        "train": dict(microbatches=4),
        # kv=24 indivisible by 16 → cache seq-sharded; int8 on top
        "decode": dict(seq_shard=True, kv_quant=True),
    },
    "zamba2-2.7b": {
        "train": dict(microbatches=4),
        "decode": dict(seq_shard=True),
        "long": dict(seq_shard=True),
    },
}


def preset(cfg, shape) -> RunConfig:
    over = {}
    table = _TABLE.get(cfg.name, {})
    kind = shape.kind
    if shape.name.startswith("long_"):
        over = table.get("long", table.get(kind, {}))
    else:
        over = table.get(kind, {})
    run = replace(_DEFAULT, **over)
    if kind != "train":
        run = replace(run, microbatches=1, remat="none")
    return run
