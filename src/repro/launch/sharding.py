"""Logical-axis sharding rules (MaxText-style), with divisibility fallback.

Params and activations carry *logical* axis names ("embed", "heads",
"vocab", ...).  A :class:`ShardingRules` maps logical names → mesh axes;
resolution checks divisibility and falls back to replication per axis, so
e.g. starcoder2's kv=2 heads simply replicate on a 16-wide model axis
instead of failing.

Default rules:
  batch   → (pod, data)     activations
  embed   → fsdp axes       parameters (ZeRO-3) when RunConfig.fsdp
  heads/kv_heads/mlp/experts/vocab → model   (tensor/expert parallelism)
  seq     → model           (sequence parallelism, long-context decode)
  layers  → replicated      (stacked-scan leading axis)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.layers import ParamSpec

__all__ = ["ShardingRules", "rules_for", "param_shardings",
           "abstract_params"]


@dataclass(frozen=True)
class ShardingRules:
    table: dict = field(default_factory=dict)

    def mesh_axes(self, logical: str | None):
        if logical is None:
            return None
        return self.table.get(logical)

    def partition_spec(self, axes, shape=None, mesh=None, *,
                       unconstrained_fallback: bool = False) -> P:
        """Resolve logical axes → PartitionSpec with divisibility fallback.

        Parameters fall back to *replicated* (None); activations should
        pass ``unconstrained_fallback=True`` so unresolved dims become
        ``P.UNCONSTRAINED`` — a None there would force an all-gather to
        replicated, which is exactly wrong for e.g. 24 heads on a 16-wide
        model axis (XLA keeps the propagated sharding instead)."""
        fb = P.UNCONSTRAINED if unconstrained_fallback else None
        used = set()
        out = []
        for i, lg in enumerate(axes):
            ma = self.mesh_axes(lg)
            if ma is None:
                out.append(fb)
                continue
            ma_t = (ma,) if isinstance(ma, str) else tuple(ma)
            ma_t = tuple(a for a in ma_t
                         if mesh is None or a in mesh.axis_names)
            ma_t = tuple(a for a in ma_t if a not in used)
            if not ma_t:
                out.append(fb)
                continue
            if shape is not None and mesh is not None:
                size = int(np.prod([mesh.shape[a] for a in ma_t]))
                if shape[i] % size:
                    out.append(fb)   # fallback for this dim
                    continue
            used.update(ma_t)
            out.append(ma_t[0] if len(ma_t) == 1 else ma_t)
        if not unconstrained_fallback:
            while out and out[-1] is None:
                out.pop()
        return P(*out)


def rules_for(mesh, run) -> ShardingRules:
    """Build the rule table for a mesh + RunConfig."""
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    fsdp_axes = batch_axes if run.fsdp else None
    table = {
        "batch": batch_axes,
        "embed": fsdp_axes,            # None → params replicated on data
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "vocab": "model",
        "seq": "model" if run.seq_shard else None,
        "layers": None,
    }
    return ShardingRules(table={k: v for k, v in table.items()
                                if v is not None})


def param_shardings(specs, mesh, rules):
    """NamedSharding tree matching a ParamSpec tree."""
    def one(s: ParamSpec):
        return NamedSharding(mesh, rules.partition_spec(
            s.axes, shape=s.shape, mesh=mesh))
    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_params(specs, mesh=None, rules=None):
    """ShapeDtypeStruct tree, optionally with shardings (dry-run input)."""
    if mesh is None:
        return jax.tree.map(lambda s: s.sds(), specs,
                            is_leaf=lambda x: isinstance(x, ParamSpec))
    sh = param_shardings(specs, mesh, rules)
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.sds().dtype, sharding=ns),
        specs, sh, is_leaf=lambda x: isinstance(x, ParamSpec))
