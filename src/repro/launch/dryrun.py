import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (assignment brief: MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell:
``jax.jit(step).lower(*abstract).compile()`` must succeed on the 16×16
single-pod mesh AND the 2×16×16 multi-pod mesh;  ``memory_analysis()``
proves the per-device footprint fits 16 GB v5e HBM, ``cost_analysis()``
feeds §Roofline, and the optimized HLO gives the collective inventory.

The two ``os.environ`` lines above MUST run before any other import — jax
locks the device count at first init (and only this entry point gets the
512 placeholder devices; tests and benches see 1 CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
Results are cached per cell as JSON; existing files are skipped unless
``--force``.
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, get_config, shapes_for
from .cells import build_cell
from .hlo_analysis import collective_bytes
from .mesh import HARDWARE, make_production_mesh
from .presets import preset

__all__ = ["run_cell", "main"]


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None = None, force: bool = False,
             run_overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shapes = shapes_for(cfg)
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, cell_id + ".json") if out_dir else None
    if path and os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if shape_name not in shapes:
        rec = {"cell": cell_id, "status": "skipped/full-attention",
               "arch": arch, "shape": shape_name, "mesh": mesh_name}
        if path:
            json.dump(rec, open(path, "w"), indent=1)
        return rec

    shape = shapes[shape_name]
    run = preset(cfg, shape)
    if run_overrides:
        from dataclasses import replace
        run = replace(run, **run_overrides)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "run": {k: getattr(run, k) for k in
                                      ("microbatches", "remat", "fsdp",
                                       "seq_shard", "kv_quant", "grad_compress",
                                       "optimizer_dtype")}}
    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        step, aargs, meta = build_cell(cfg, shape, mesh, run)
        # donate the mutable state (params+opt for train, cache for decode)
        donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
        lowered = jax.jit(step, donate_argnums=donate).lower(*aargs)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        n_dev = 512 if multi_pod else 256
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            # arguments/outputs alias for donated state; peak ≈ args + temp
            "per_device_peak_bytes": int(ma.argument_size_in_bytes
                                         + ma.temp_size_in_bytes),
            "hbm_per_chip": HARDWARE["hbm_per_chip"],
            "fits": bool(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                         < HARDWARE["hbm_per_chip"]),
        }
        ca = compiled.cost_analysis()
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        pod_size = 256 if multi_pod else None
        rec["collectives"] = collective_bytes(compiled.as_text(),
                                              pod_size=pod_size)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if path:
        json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_skip = n_err = 0
    for arch in archs:
        shape_names = ([args.shape] if args.shape
                       else ["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
        for shape_name in shape_names:
            for mp in meshes:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               out_dir=args.out, force=args.force)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st.startswith("skipped")
                n_err += st == "error"
                mem = rec.get("memory", {})
                print(f"{rec['cell']:55s} {st:10s} "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"peak={mem.get('per_device_peak_bytes', 0)/1e9:.2f}GB "
                      f"fits={mem.get('fits', '-')}", flush=True)
                if st == "error":
                    print("   ", rec["error"][:300], flush=True)
    print(f"\nsummary: ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    main()
