"""Train-step construction: loss, microbatching, remat, sharded optimizer,
optional cross-pod gradient compression — plus a resilient training loop.

Two step builders:

  * :func:`make_train_step` — pure-pjit SPMD step (default): forward/
    backward under the mesh with logical-axis constraints, gradient
    all-reduce inserted by the partitioner, AdamW update on sharded state.
  * :func:`make_train_step_compressed` — ``shard_map`` step, *manual* over
    the (pod, data) batch axes and *auto* over ``model``: within-pod psum
    in bf16, int8 all-gather across pods, error feedback
    (:mod:`repro.optim.grad_compress`).

The training loop (:func:`train_loop`) wires in the resilience runtime:
atomic checkpoints, auto-resume, preemption handling, a step watchdog.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, RunConfig
from ..models import model as M
from ..models.layers import mesh_context, init_from_specs
from ..optim.adafactor import (AdafactorConfig, adafactor_init,
                               adafactor_update)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.grad_compress import compress_pod_reduce, init_error_feedback


def make_optimizer(run: RunConfig, opt_cfg=None):
    """(opt_cfg, init_fn, update_fn) for RunConfig.optimizer."""
    if run.optimizer == "adafactor":
        cfg = opt_cfg if isinstance(opt_cfg, AdafactorConfig) \
            else AdafactorConfig(moments_dtype=run.optimizer_dtype)
        return cfg, adafactor_init, adafactor_update
    cfg = opt_cfg if isinstance(opt_cfg, AdamWConfig) \
        else AdamWConfig(moments_dtype=run.optimizer_dtype)
    return cfg, adamw_init, adamw_update
from .sharding import abstract_params, param_shardings, rules_for

__all__ = ["loss_fn", "make_train_step", "make_train_step_compressed",
           "init_train_state", "train_loop", "batch_spec"]

_MOE_AUX_W = 0.01


def loss_fn(params, batch, cfg: ModelConfig, run: RunConfig, *,
            q_chunk=512, kv_chunk=1024, unroll_scans=False):
    """Next-token cross-entropy (+ MoE aux).  batch: tokens/embeds+labels."""
    kw = {}
    if cfg.input_mode == "tokens":
        kw["tokens"] = batch["tokens"]
    else:
        kw["embeds"] = batch["embeds"]
    logits, aux = M.forward(params, cfg, mode="train",
                            remat=(run.remat != "none"),
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            unroll_scans=unroll_scans, **kw)
    if run.logits_fp32:
        logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = ce + _MOE_AUX_W * aux["moe_aux"]
    return loss, {"ce": ce, "moe_aux": aux["moe_aux"]}


def _microbatched_grads(params, batch, cfg, run, **kw):
    """Gradient accumulation over ``run.microbatches`` splits of the batch."""
    mb = max(run.microbatches, 1)
    if mb == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, run, **kw)
        return loss, metrics, grads

    def split(x):
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

    mbatch = jax.tree.map(split, batch)

    def step(carry, mb_batch):
        loss_acc, grads_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb_batch, cfg, run, **kw)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
        return (loss_acc + loss, grads_acc), metrics

    acc_dt = jnp.dtype(run.grad_accum_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (loss_sum, grads), metrics = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), zeros), mbatch)
    grads = jax.tree.map(lambda g: g / mb, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / mb, metrics, grads


def batch_spec(cfg: ModelConfig, shape, mesh, rules):
    """ShapeDtypeStructs + shardings for one global batch."""
    B, S = shape.global_batch, shape.seq_len
    bspec = rules.partition_spec(("batch", None), shape=(B, S), mesh=mesh)
    sh = NamedSharding(mesh, bspec)
    out = {"labels": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=sh)}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=sh)
    else:
        sh3 = NamedSharding(mesh, rules.partition_spec(
            ("batch", None, None), mesh=mesh))
        out["embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.bfloat16, sharding=sh3)
    return out


def make_train_step(cfg: ModelConfig, run: RunConfig, mesh,
                    opt_cfg=None, *,
                    q_chunk=512, kv_chunk=1024, unroll_scans=False):
    """Pure-pjit train step: (params, opt_state, batch) → updated state."""
    opt_cfg, _, opt_update = make_optimizer(run, opt_cfg)
    rules = rules_for(mesh, run)

    def step(params, opt_state, batch):
        with mesh_context(mesh, rules):
            loss, metrics, grads = _microbatched_grads(
                params, batch, cfg, run, q_chunk=q_chunk, kv_chunk=kv_chunk,
                unroll_scans=unroll_scans)
            params, opt_state, stats = opt_update(
                params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **stats}

    return step, rules, opt_cfg


def make_train_step_compressed(cfg: ModelConfig, run: RunConfig, mesh,
                               opt_cfg: AdamWConfig | None = None, *,
                               q_chunk=512, kv_chunk=1024,
                               unroll_scans=False):
    """Per-pod-replica train step with int8 cross-pod gradient transport.

    Design (how real multi-pod DP works): every pod holds a full state
    replica — params/opt/error-feedback carry a leading ``(n_pods, …)``
    replica dim sharded over ``pod``.  The step is:

      1. ``vmap`` over the replica dim: each pod computes grads on its own
         batch shard with *no cross-pod collective in the backward* (the
         automatic psum only spans the within-pod ``data`` axis).
      2. A small ``shard_map`` over ``pod`` alone exchanges the gradients:
         error-feedback add, int8 quantize, **int8 all-gather across the
         DCN**, dequant + mean (:mod:`repro.optim.grad_compress`).
      3. Each pod applies the identical averaged update — replicas stay
         bit-identical, so the leading dim costs no extra memory per chip.

    Keeping the model code in plain pjit/vmap (no Manual axes around the
    scanned/rematted stack) sidesteps an XLA-CPU partial-manual
    partitioner bug, and is the cleaner factoring anyway.
    """
    opt_cfg, _, opt_update = make_optimizer(run, opt_cfg)
    rules = rules_for(mesh, run)
    n_pods = mesh.shape.get("pod", 1)
    if run.fsdp:
        raise ValueError("grad_compress path requires fsdp=False")

    def exchange(grads, ef):
        """int8+EF cross-pod reduction, pure-pjit formulation.

        Per-pod quantization is element-local (stays pod-sharded); the only
        cross-pod movement is a sharding constraint that replicates the
        **int8 codes** over the pod axis — XLA lowers it to an all-gather
        whose wire payload is int8+scales (4–16× less DCN traffic than an
        f32/bf16 gradient all-reduce).  Dequant + mean then run locally on
        every pod, and the error-feedback residual stays pod-local.
        """
        from ..optim.grad_compress import _dequant_leaf, _quant_leaf

        if n_pods <= 1:
            return compress_pod_reduce(grads, ef, pod_axis=None, n_pods=1)
        U = P.UNCONSTRAINED

        def one(g, e):
            gc = g.astype(jnp.float32) + e            # (n_pods, …), EF add
            q, scale = jax.vmap(_quant_leaf)(
                gc.reshape(n_pods, -1))               # int8 codes + scales
            local_deq = jax.vmap(
                lambda qq, ss: _dequant_leaf(qq, ss, gc.shape[1:]))(q, scale)
            new_e = gc - local_deq
            # pod-replicate the CODES: int8 crosses the DCN, not f32
            rep = lambda a: jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, P(*([None] + [U] * (a.ndim - 1)))))
            q_all, s_all = rep(q), rep(scale)
            deq = jax.vmap(
                lambda qq, ss: _dequant_leaf(qq, ss, gc.shape[1:]))(
                q_all, s_all)
            mean = deq.mean(axis=0, keepdims=True)
            mean = jnp.broadcast_to(mean, gc.shape).astype(g.dtype)
            return mean, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(ef)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def step(params_r, opt_r, ef_r, batch):
        with mesh_context(mesh, rules):
            def split(x):
                return x.reshape((n_pods, x.shape[0] // n_pods)
                                 + x.shape[1:])

            pod_batch = jax.tree.map(split, batch)

            def local(p, b):
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, b, cfg, run, q_chunk=q_chunk,
                                           kv_chunk=kv_chunk,
                                           unroll_scans=unroll_scans)
                return loss, metrics, g

            losses, metrics, grads = jax.vmap(local)(params_r, pod_batch)
            grads, ef_r = exchange(grads, ef_r)
            new_p, new_o, stats = jax.vmap(
                lambda p, g, o: opt_update(p, g, o, opt_cfg))(
                params_r, grads, opt_r)
            out_metrics = {"loss": losses.mean(),
                           **{k: v.mean() for k, v in metrics.items()},
                           **{k: v[0] for k, v in stats.items()}}
        return new_p, new_o, ef_r, out_metrics

    return step, rules, opt_cfg


def init_replica_state(cfg: ModelConfig, run: RunConfig, mesh, key,
                       opt_cfg=None):
    """(n_pods, …) pod-replicated params/opt/ef for the compressed step."""
    from ..optim.grad_compress import init_error_feedback

    n_pods = mesh.shape.get("pod", 1)
    params, opt_state = init_train_state(cfg, run, mesh, key, opt_cfg)
    ef = init_error_feedback(params)

    def rep(x):
        return jnp.broadcast_to(x[None], (n_pods,) + x.shape)

    params_r = jax.jit(lambda t: jax.tree.map(rep, t))(params)
    opt_r = jax.jit(lambda t: jax.tree.map(rep, t))(opt_state)
    ef_r = jax.jit(lambda t: jax.tree.map(rep, t))(ef)
    return params_r, opt_r, ef_r


def init_train_state(cfg: ModelConfig, run: RunConfig, mesh, key,
                     opt_cfg=None):
    """Materialize sharded params + optimizer state on the mesh."""
    specs = M.model_specs(cfg)
    rules = rules_for(mesh, run)
    shardings = param_shardings(specs, mesh, rules)
    opt_cfg, opt_init, _ = make_optimizer(run, opt_cfg)

    def init():
        return init_from_specs(specs, key)

    params = jax.jit(init, out_shardings=shardings)()
    opt_state = jax.jit(functools.partial(opt_init, cfg=opt_cfg))(params)
    return params, opt_state


def train_loop(cfg: ModelConfig, run: RunConfig, mesh, data_iter, *,
               steps: int, opt_cfg: AdamWConfig | None = None,
               checkpoint_dir: str | None = None, checkpoint_every: int = 50,
               resume: bool = True, key=None, watchdog_timeout: float = 0.0,
               log_every: int = 10):
    """Resilient training driver (used by examples + integration tests)."""
    from ..checkpoint.manager import CheckpointManager
    from ..runtime.resilience import PreemptionGuard, StepWatchdog

    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = opt_cfg or AdamWConfig(moments_dtype=run.optimizer_dtype)
    step_fn, rules, opt_cfg = make_train_step(cfg, run, mesh, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    params, opt_state = init_train_state(cfg, run, mesh, key, opt_cfg)

    start = 0
    mgr = None
    if checkpoint_dir:
        mgr = CheckpointManager(checkpoint_dir)
        if resume:
            restored = mgr.restore_latest(mesh=mesh,
                                          shardings=param_shardings(
                                              M.model_specs(cfg), mesh, rules))
            if restored is not None:
                params, opt_state, start = restored

    guard = PreemptionGuard()
    watchdog = StepWatchdog(timeout=watchdog_timeout)
    history = []
    for step in range(start, steps):
        batch = next(data_iter)
        with watchdog.step(step):
            params, opt_state, metrics = jit_step(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
        if mgr and (step + 1) % checkpoint_every == 0:
            mgr.save(step + 1, params, opt_state)
        if guard.should_stop:
            if mgr:
                mgr.save(step + 1, params, opt_state)
            break
    if mgr:
        mgr.wait()
    return params, opt_state, history
