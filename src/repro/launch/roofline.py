import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis (assignment brief §ROOFLINE ANALYSIS).

Terms per (arch × shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs  / (chips × 197 TF/s)
    memory term     = HLO_bytes  / (chips × 819 GB/s)
    collective term = coll_bytes / (chips × 50 GB/s)

**Methodology note (scan trip-count correction).**  ``cost_analysis()``
counts a ``lax.scan`` body ONCE regardless of trip count (verified in
EXPERIMENTS.md §Roofline), and this framework scans over layers,
microbatches, attention chunks and SSM chunks.  We therefore lower each
cell six times at reduced size — layer-units L ∈ {1, 2} × sequence
S ∈ {512, 1024, 2048} — with **every scan fully unrolled**
(``unroll_scans=True``) and ``microbatches=1``, so each variant's costs
are exact.  Costs decompose as

    F(L, S) = α(S) + L·β(S),      α, β quadratic in S

(α: embedding/head/optimizer-fixed, β: per-layer; S² captures attention),
which six points determine exactly.  The cell's roofline evaluates the fit
at the full depth & sequence.  Chunk sizes (flash q/kv, SSM, MoE groups)
are kept at deployed values so the recompute/remat structure — and hence
the MODEL_FLOPS/HLO_FLOPs waste ratio — is the deployed one.  The deploy
variant's compile (dryrun.py) provides memory analysis and the collective
*inventory*; collective totals come from the same 6-point fit.

``cost_analysis`` reports the per-device partitioned program, so the terms
divide by per-chip peaks directly; MODEL_FLOPS comparisons use
global = per-device × chips (calibrated at import by a sharded-matmul
probe the first time ``run_roofline`` executes).
"""
import argparse
import json
import time
import traceback
from dataclasses import replace

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, shapes_for
from ..models.model import param_counts
from .cells import build_cell, layer_unit, reduced_cfg
from .hlo_analysis import collective_bytes
from .mesh import HARDWARE, make_production_mesh
from .presets import preset

__all__ = ["run_roofline", "roofline_table", "main"]

_S_POINTS = (512, 1024, 2048)
_CHIPS = 256


def _variant_costs(cfg, shape, mesh, run, n_units, s):
    """Lower+compile one reduced variant; exact per-device costs."""
    vcfg = reduced_cfg(cfg, n_units)
    vshape = replace(shape, seq_len=s)
    vrun = replace(run, microbatches=1)
    step, aargs, _ = build_cell(vcfg, vshape, mesh, vrun,
                                unroll_scans=True)
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    compiled = jax.jit(step, donate_argnums=donate).lower(*aargs).compile()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            # ring-schedule per-device link traffic (hlo_analysis)
            "coll": float(coll["total_wire_bytes"])}


def _fit_quadratic(ss, ys):
    """Exact quadratic through 3 points."""
    A = np.stack([np.ones(3), np.asarray(ss, float),
                  np.asarray(ss, float) ** 2], axis=1)
    return np.linalg.solve(A, np.asarray(ys, float))


def _extrapolate(points, L_full, S_full):
    """points[(L, S)] = {flops, bytes, coll} → full-size estimates."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        betas, alphas = [], []
        for s in _S_POINTS:
            f1, f2 = points[(1, s)][key], points[(2, s)][key]
            betas.append(f2 - f1)          # per-layer-unit cost at S=s
            alphas.append(2 * f1 - f2)     # L-independent cost at S=s
        ca = _fit_quadratic(_S_POINTS, alphas)
        cb = _fit_quadratic(_S_POINTS, betas)
        alpha = ca[0] + ca[1] * S_full + ca[2] * S_full ** 2
        beta = cb[0] + cb[1] * S_full + cb[2] * S_full ** 2
        out[key] = max(alpha + L_full * beta, 0.0)
    return out


def run_roofline(arch: str, shape_name: str, *, out_dir=None, force=False,
                 run_overrides=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shapes = shapes_for(cfg)
    cell_id = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, cell_id + ".json") if out_dir else None
    if path and os.path.exists(path) and not force:
        return json.load(open(path))
    if shape_name not in shapes:
        rec = {"cell": cell_id, "status": "skipped/full-attention"}
        if path:
            json.dump(rec, open(path, "w"), indent=1)
        return rec
    shape = shapes[shape_name]
    run = preset(cfg, shape)
    if run_overrides:
        run = replace(run, **run_overrides)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name}
    try:
        mesh = make_production_mesh(multi_pod=False)
        t0 = time.time()
        points = {}
        for n_units in (1, 2):
            for s in _S_POINTS:
                points[(n_units, s)] = _variant_costs(
                    cfg, shape, mesh, run, n_units, s)
        rec["fit_points"] = {f"{l}x{s}": v for (l, s), v in points.items()}
        L_full = cfg.n_layers // layer_unit(cfg)
        full = _extrapolate(points, L_full, shape.seq_len)
        rec["variant_s"] = round(time.time() - t0, 1)

        hw = HARDWARE
        terms = {
            "compute_s": full["flops"] / hw["peak_flops_bf16"],
            "memory_s": full["bytes"] / hw["hbm_bw"],
            "collective_s": full["coll"] / hw["ici_bw"],
        }
        dominant = max(terms, key=terms.get)
        total_p, active_p = param_counts(cfg)
        # embeddings don't matmul in the 6ND sense — exclude the input table
        emb = cfg.vocab_size * cfg.d_model if cfg.input_mode == "tokens" else 0
        n_for_flops = active_p - emb
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mult = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
        model_flops = mult * n_for_flops * tokens
        hlo_flops_global = full["flops"] * _CHIPS
        rec.update({
            "per_device": full,
            "terms_s": terms,
            "dominant": dominant,
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": (model_flops / hlo_flops_global
                             if hlo_flops_global else 0.0),
            "bound_fraction": {k: v / max(sum(terms.values()), 1e-30)
                               for k, v in terms.items()},
            "roofline_fraction": (terms["compute_s"]
                                  / max(max(terms.values()), 1e-30)),
            "status": "ok",
        })
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
    if path:
        json.dump(rec, open(path, "w"), indent=1)
    return rec


def analytic_attention_flops(cfg, shape) -> float:
    """Useful (causal) attention flops per step, global — the term 6ND
    misses.  Counted for softmax-attention layers only (linear-attention
    recurrences are folded into the 'other' bucket and noted in the text).
    """
    if cfg.family == "ssm" or not cfg.n_heads:
        return 0.0
    L_attn = (cfg.n_layers // cfg.shared_attn_every
              if cfg.family == "hybrid" else cfg.n_layers)
    B, S = shape.global_batch, shape.seq_len
    Hhd = cfg.n_heads * cfg.head_dim
    if shape.kind == "decode":
        return 4 * B * S * Hhd * L_attn          # scores + pv, one token
    fwd = 2 * B * (S ** 2) * Hhd                 # 2 matmuls × causal half
    mult = 3 if shape.kind == "train" else 1     # bwd ≈ 2× fwd
    return fwd * mult * L_attn


def _model_flops_full(cfg, shape, model_flops_6nd) -> float:
    return model_flops_6nd + analytic_attention_flops(cfg, shape)


def roofline_table(out_dir: str) -> str:
    """Markdown §Roofline table from cached results."""
    import glob

    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append(f"| {r['cell']} | — | — | — | — | {r['status']} "
                        f"| — | — |")
            continue
        t = r["terms_s"]
        cfg = get_config(r["arch"])
        shape = shapes_for(cfg)[r["shape"]]
        mf_full = _model_flops_full(cfg, shape, r["model_flops"])
        ur_full = mf_full / max(r["hlo_flops_global"], 1e-30)
        rows.append(
            "| {cell} | {c:.2e} | {m:.2e} | {k:.2e} | {dom} | {ur:.2f} | "
            "{urf:.2f} | {rf:.2f} |".format(
                cell=r["cell"], c=t["compute_s"], m=t["memory_s"],
                k=t["collective_s"], dom=r["dominant"].replace("_s", ""),
                ur=r["useful_ratio"], urf=ur_full,
                rf=r["roofline_fraction"]))
    head = ("| cell | compute (s) | memory (s) | collective (s) | dominant "
            "| 6ND/HLO | (6ND+attn)/HLO | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--table", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    if args.table:
        print(roofline_table(args.out))
        return
    archs = [args.arch] if args.arch else ARCH_IDS
    for arch in archs:
        shape_names = ([args.shape] if args.shape
                       else ["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
        for sn in shape_names:
            rec = run_roofline(arch, sn, out_dir=args.out)
            if rec["status"] == "ok":
                t = rec["terms_s"]
                print(f"{rec['cell']:45s} comp={t['compute_s']:.2e}s "
                      f"mem={t['memory_s']:.2e}s coll={t['collective_s']:.2e}s"
                      f" dom={rec['dominant']:13s} 6ND/HLO="
                      f"{rec['useful_ratio']:.2f}", flush=True)
            else:
                print(f"{rec['cell']:45s} {rec['status']} "
                      f"{rec.get('error', '')[:200]}", flush=True)


if __name__ == "__main__":
    main()
