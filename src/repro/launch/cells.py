"""Cell builder: (arch × shape × mesh × RunConfig) → (step fn, abstract args).

Shared by the dry-run (deploy variants: chunked/scanned, memory-true) and
the roofline (flops variants: unrolled scans, reduced depth/seq — see
``roofline.py`` for why ``cost_analysis`` needs them).

Everything here is ShapeDtypeStruct-based — nothing allocates.
"""
from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, RunConfig, ShapeConfig
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init
from ..serving.engine import make_prefill_step, make_serve_step
from .sharding import abstract_params, param_shardings, rules_for
from .train import batch_spec, make_train_step

__all__ = ["build_cell", "reduced_cfg", "layer_unit"]


def layer_unit(cfg: ModelConfig) -> int:
    """The repeating depth unit for layer-count extrapolation."""
    return cfg.shared_attn_every if cfg.family == "hybrid" else 1


def reduced_cfg(cfg: ModelConfig, n_units: int) -> ModelConfig:
    return replace(cfg, n_layers=n_units * layer_unit(cfg))


def _with_sharding(tree_sds, tree_shard):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_shard)


def _opt_shardings(run, specs, psh, mesh, rules):
    """Optimizer-state shardings, built from the ParamSpec tree.

    AdamW moments follow their parameter exactly; Adafactor's factored
    second-moment vectors keep the parameter's surviving logical axes."""
    from ..models.layers import ParamSpec
    from ..optim.adafactor import _factored

    is_spec = lambda x: isinstance(x, ParamSpec)
    scalar = NamedSharding(mesh, P())
    if run.optimizer == "adafactor":
        def v_shard(s: ParamSpec):
            if _factored(s.shape):
                return {"vr": NamedSharding(mesh, rules.partition_spec(
                            s.axes[:-1], shape=s.shape[:-1], mesh=mesh)),
                        "vc": NamedSharding(mesh, rules.partition_spec(
                            s.axes[:-2] + s.axes[-1:],
                            shape=s.shape[:-2] + s.shape[-1:], mesh=mesh))}
            return {"v": NamedSharding(mesh, rules.partition_spec(
                s.axes, shape=s.shape, mesh=mesh))}

        out = {"v": jax.tree.map(v_shard, specs, is_leaf=is_spec),
               "step": scalar}
        # (beta1 = 0 by default → no first moment)
        return out
    return {"mu": psh, "nu": psh, "step": scalar}


def _state_shardings(cfg, state_sds, mesh, rules):
    """Shardings for a serve-time state tree, resolved per leaf shape."""
    def leaf_axes(path, a):
        nd = a.ndim
        if cfg.family == "ssm":
            # wkv (L,B,nh,hd,hd) | shift (L,B,d)
            return {5: ("layers", "batch", "heads", None, None),
                    3: ("layers", "batch", None)}[nd]
        if cfg.family == "hybrid":
            if path and path[0] == "kv":
                return {5: ("layers", "batch", "seq", "kv_heads", None),
                        4: ("layers", "batch", "seq", "kv_heads")}[nd]
            # mamba ssm (G,k,B,nh,hd,ns) | conv (G,k,B,K-1,C)
            return {6: ("layers", None, "batch", "heads", None, None),
                    5: ("layers", None, "batch", None, "heads")}[nd]
        # dense kv: k/v (L,B,S,Hkv,hd); scales (L,B,S,Hkv)
        return {5: ("layers", "batch", "seq", "kv_heads", None),
                4: ("layers", "batch", "seq", "kv_heads")}[nd]

    def resolve(path, a):
        return NamedSharding(mesh, rules.partition_spec(
            leaf_axes(path, a), shape=a.shape, mesh=mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_sds)
    out = []
    for kp, a in flat:
        path = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in kp)
        out.append(resolve(path, a))
    return jax.tree_util.tree_unflatten(treedef, out)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, run: RunConfig, *,
               q_chunk=512, kv_chunk=1024, unroll_scans=False):
    """Returns (step_fn, abstract_args: tuple, meta: dict)."""
    rules = rules_for(mesh, run)
    specs = M.model_specs(cfg)
    aparams = abstract_params(specs, mesh, rules)
    psh = param_shardings(specs, mesh, rules)
    meta = {"rules": rules, "specs": specs}

    if shape.kind == "train":
        from .train import make_optimizer, make_train_step_compressed
        if run.grad_compress:
            # per-pod-replica layout: leading (n_pods,) dim sharded on pod
            step, rules, opt_cfg = make_train_step_compressed(
                cfg, run, mesh, q_chunk=q_chunk, kv_chunk=kv_chunk,
                unroll_scans=unroll_scans)
            _, opt_init, _ = make_optimizer(run, opt_cfg)
            n_pods = mesh.shape.get("pod", 1)

            def _replicate(sds_tree, sh_tree):
                def one(s, sh):
                    spec = P(*(("pod",) + tuple(sh.spec)))
                    return jax.ShapeDtypeStruct(
                        (n_pods,) + s.shape, s.dtype,
                        sharding=NamedSharding(mesh, spec))
                return jax.tree.map(one, sds_tree, sh_tree)

            aparams_r = _replicate(
                jax.tree.map(lambda s: s.sds(), specs,
                             is_leaf=lambda x: hasattr(x, "sds")), psh)
            opt_sds = jax.eval_shape(
                functools.partial(opt_init, cfg=opt_cfg),
                jax.tree.map(lambda s: s.sds(), specs,
                             is_leaf=lambda x: hasattr(x, "sds")))
            opt_sh = _opt_shardings(run, specs, psh, mesh, rules)
            aopt_r = _replicate(opt_sds, opt_sh)
            ef_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                jax.tree.map(lambda s: s.sds(), specs,
                             is_leaf=lambda x: hasattr(x, "sds")))
            aef_r = _replicate(ef_sds, psh)
            abatch = batch_spec(cfg, shape, mesh, rules)
            return step, (aparams_r, aopt_r, aef_r, abatch), meta

        step, rules, opt_cfg = make_train_step(
            cfg, run, mesh,
            q_chunk=q_chunk, kv_chunk=kv_chunk, unroll_scans=unroll_scans)
        _, opt_init, _ = make_optimizer(run, opt_cfg)
        opt_sds = jax.eval_shape(
            functools.partial(opt_init, cfg=opt_cfg), aparams)
        aopt = _with_sharding(opt_sds,
                              _opt_shardings(run, specs, psh, mesh, rules))
        abatch = batch_spec(cfg, shape, mesh, rules)
        return step, (aparams, aopt, abatch), meta

    B = shape.global_batch
    bspec = NamedSharding(mesh, rules.partition_spec(
        ("batch", None), shape=(B, 1), mesh=mesh))
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, run, mesh, rules, q_chunk=q_chunk,
                                 kv_chunk=kv_chunk,
                                 unroll_scans=unroll_scans)
        if cfg.input_mode == "tokens":
            abatch = {"tokens": jax.ShapeDtypeStruct(
                (B, shape.seq_len), jnp.int32, sharding=bspec)}
        else:
            sh3 = NamedSharding(mesh, rules.partition_spec(
                ("batch", None, None), shape=(B, 1, 1), mesh=mesh))
            abatch = {"embeds": jax.ShapeDtypeStruct(
                (B, shape.seq_len, cfg.d_model), jnp.bfloat16, sharding=sh3)}
        return step, (aparams, abatch), meta

    # decode: one new token against a cache of capacity seq_len
    step = make_serve_step(cfg, run, mesh, rules, kv_chunk=kv_chunk,
                           unroll_scans=unroll_scans)
    state_sds = jax.eval_shape(
        lambda: M.init_decode_state(cfg, B, shape.seq_len,
                                    quantized=run.kv_quant))
    astate = _with_sharding(
        state_sds, _state_shardings(cfg, state_sds, mesh, rules))
    if cfg.input_mode == "tokens":
        abatch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                                 sharding=bspec)}
    else:
        sh3 = NamedSharding(mesh, rules.partition_spec(
            ("batch", None, None), shape=(B, 1, 1), mesh=mesh))
        abatch = {"embeds": jax.ShapeDtypeStruct(
            (B, 1, cfg.d_model), jnp.bfloat16, sharding=sh3)}
    aclen = jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh, P()))
    return step, (aparams, astate, abatch, aclen), meta
