"""Production mesh construction (assignment brief: MULTI-POD DRY-RUN §1).

A function, not a module-level constant — importing this module never
touches jax device state.  Hardware model: TPU v5e pods, 256 chips/pod,
(data, model) = (16, 16) per pod; the multi-pod mesh adds a leading "pod"
axis across the (slow) DCN/inter-pod links.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh", "HARDWARE"]

# TPU v5e constants for the roofline (assignment brief §ROOFLINE ANALYSIS)
HARDWARE = {
    "peak_flops_bf16": 197e12,     # FLOP/s per chip
    "hbm_bw": 819e9,               # B/s per chip
    "ici_bw": 50e9,                # B/s per link
    "hbm_per_chip": 16e9,          # bytes
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
