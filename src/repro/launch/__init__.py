"""Launch layer: mesh, sharding rules, train/serve steps, dry-run, roofline."""
