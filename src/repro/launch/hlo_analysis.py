"""Compiled-HLO analysis: collective-traffic accounting.

``cost_analysis`` has no collective-bytes entry, so we parse the optimized
HLO text (assignment brief §ROOFLINE): every ``all-gather`` /
``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` / ``collective-permute``
op contributes its *operand* bytes.  Post-optimization HLO prints operands
as bare names, so operand sizes are derived from the op's output shape and
its replica-group size G:

    all-gather      operand = output / G
    all-reduce      operand = output
    reduce-scatter  operand = output × G
    all-to-all      operand = output
    collective-permute operand = output

``wire_bytes`` additionally estimates per-device link traffic under a ring
schedule (all-reduce 2·(G−1)/G·full, all-gather/reduce-scatter
(G−1)/G·full) — this is what the roofline's collective term uses.

Ops are classified intra-pod (ICI) vs cross-pod (DCN) from replica groups:
a group whose members span ≥ pod_size device ids crosses pods.

Caveat (EXPERIMENTS.md §Roofline): ops inside ``while`` bodies are counted
once; roofline totals therefore come from depth-extrapolated *unrolled*
variants, with this parse as the per-op inventory / cross-check.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\])[^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_OPERAND_FACTOR = {  # operand bytes as a multiple of output bytes
    "all-gather": lambda g: 1.0 / max(g, 1),
    "all-reduce": lambda g: 1.0,
    "reduce-scatter": lambda g: float(g),
    "all-to-all": lambda g: 1.0,
    "collective-permute": lambda g: 1.0,
}

_WIRE_FACTOR = {  # ring-schedule per-device traffic vs FULL tensor bytes
    "all-gather": lambda g, out: out * (g - 1) / max(g, 1),
    "all-reduce": lambda g, out: 2.0 * out * (g - 1) / max(g, 1),
    "reduce-scatter": lambda g, out: out * (g - 1),  # out is the shard
    "all-to-all": lambda g, out: out * (g - 1) / max(g, 1),
    "collective-permute": lambda g, out: out,
}


def parse_shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_info(line: str):
    """(group_size, spans_pods(ids, pod_size) callable input ids)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[...]
        return int(m.group(2)), None
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1), ids
    return 1, None


def collective_bytes(hlo_text: str, *, pod_size: int | None = None) -> dict:
    out_b = defaultdict(int)
    wire_b = defaultdict(float)
    counts = defaultdict(int)
    dcn = defaultdict(int)
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        if "-done(" in line:
            continue  # async pair: count the -start only
        out_shape = m.group(1)
        nbytes = parse_shape_bytes(out_shape)
        g, ids = _group_info(line)
        operand = int(nbytes * _OPERAND_FACTOR[op](g))
        out_b[op] += operand
        wire_b[op] += _WIRE_FACTOR[op](g, float(nbytes))
        counts[op] += 1
        if pod_size and ids and (max(ids) - min(ids)) >= pod_size:
            dcn[op] += operand
        elif pod_size and ids is None and g > 256:
            dcn[op] += operand
    return {"bytes": dict(out_b), "counts": dict(counts),
            "wire_bytes": {k: int(v) for k, v in wire_b.items()},
            "dcn_bytes": dict(dcn),
            "total_bytes": int(sum(out_b.values())),
            "total_wire_bytes": int(sum(wire_b.values())),
            "total_dcn_bytes": int(sum(dcn.values()))}
