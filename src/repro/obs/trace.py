"""``repro.obs.trace`` — nested per-stage timing spans + request IDs.

A :class:`Span` is a named stopwatch that can hold child spans; the
``trace(name)`` helper opens a child of whatever span is active on the
*current thread* — so instrumented library code (the decode planner, the
writer's encode stage) never needs a span handle threaded through its
signature.  When no root span is active, ``trace()`` hands back a shared
no-op object whose ``__enter__``/``__exit__`` do nothing — the disabled
cost is one thread-local attribute read.

The span stack is thread-local on purpose: worker threads (the router's
scatter-gather pool, the parallel writer's encoder threads) do not
inherit the caller's root span.  Code that fans out collects child
summaries explicitly — e.g. ``ShardedRegionRouter`` opens one root per
batch, runs each shard group under its own root *in the pool thread*,
and grafts the finished summaries back into the batch root.

Request IDs (:func:`new_request_id`) are 16 hex chars from
``os.urandom`` — unique enough to grep a fleet's access logs, cheap
enough to mint per batch.  They ride the :data:`REQUEST_ID_HEADER`
HTTP header from router to shards.
"""
from __future__ import annotations

import os
import threading
import time

__all__ = ["Span", "trace", "root_span", "current_span",
           "new_request_id", "REQUEST_ID_HEADER"]

#: HTTP header carrying the request ID from router to shard (and echoed
#: back in every response).
REQUEST_ID_HEADER = "X-Repro-Request-Id"

_local = threading.local()


def new_request_id() -> str:
    """A 16-hex-char ID for correlating one batch across the fleet."""
    return os.urandom(8).hex()


class Span:
    """One named, timed region with optional nested children.

    Use as a context manager.  ``duration`` is in seconds and is only
    meaningful after ``__exit__``.  ``summary()`` flattens the finished
    tree into a JSON-friendly dict suitable for response metadata.
    """

    __slots__ = ("name", "t0", "duration", "children", "meta", "_parent")

    def __init__(self, name: str):
        self.name = name
        self.t0 = 0.0
        self.duration = 0.0
        self.children: list["Span"] = []
        self.meta: dict = {}
        self._parent: "Span | None" = None

    def __enter__(self) -> "Span":
        parent = getattr(_local, "span", None)
        if parent is not None:
            parent.children.append(self)
        self._parent = parent
        _local.span = self
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.duration = time.perf_counter() - self.t0
        # spans nest strictly on one thread, so the saved parent is the
        # span that was active at __enter__
        _local.span = self._parent

    def add_child(self, child: "Span") -> None:
        """Graft a finished span (e.g. from a pool thread) under this one."""
        self.children.append(child)

    def summary(self) -> dict:
        """The finished tree as ``{name, ms, [meta], [stages]}``."""
        out: dict = {"name": self.name,
                     "ms": round(self.duration * 1000.0, 3)}
        if self.meta:
            out.update(self.meta)
        if self.children:
            out["stages"] = [c.summary() for c in self.children]
        return out


class _NullSpan:
    """Shared do-nothing span handed out when tracing is inactive."""

    __slots__ = ()
    name = ""
    duration = 0.0
    children: list = []
    meta: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def add_child(self, child) -> None:
        pass

    def summary(self) -> dict:
        return {}


_NULL = _NullSpan()


class _RootCtx:
    """Context manager installing ``span`` as this thread's root."""

    __slots__ = ("span", "_saved_span", "_saved_root")

    def __init__(self, span: Span):
        self.span = span

    def __enter__(self) -> Span:
        self._saved_span = getattr(_local, "span", None)
        self._saved_root = getattr(_local, "root", None)
        _local.root = self.span
        _local.span = self.span
        self.span.t0 = time.perf_counter()
        return self.span

    def __exit__(self, *exc) -> None:
        self.span.duration = time.perf_counter() - self.span.t0
        _local.span = self._saved_span
        _local.root = self._saved_root


def root_span(name: str) -> _RootCtx:
    """Open a *root* span on this thread: every ``trace()`` call made
    below it (on the same thread) attaches to its tree.  Used by the
    HTTP handler per request and the router per batch."""
    return _RootCtx(Span(name))


def trace(name: str):
    """A child span of the active span on this thread — or a shared
    no-op when no root is active (the common, uninstrumented case)."""
    if getattr(_local, "span", None) is None:
        return _NULL
    return Span(name)


def current_span() -> Span | None:
    """The innermost active span on this thread, if any."""
    return getattr(_local, "span", None)
