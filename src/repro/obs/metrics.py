"""``repro.obs.metrics`` — the default registry and the metric catalog.

Every instrumented component records into one module-level
:class:`~repro.obs.registry.MetricsRegistry`.  That is deliberate:

  * Lifetime totals must survive a ``RegionServer`` hot swap (the server
    object is rebuilt; the registry is not) — the same property the
    sub-block cache's hit/miss counters already have.
  * One ``GET /v1/metrics`` scrape covers everything in the process: a
    shard's cache + planner + server latency, and — when a router runs
    in the same process, as the tests' two-shard fleets do — the
    router's fan-out series too.

The catalog below is the single source of truth for metric names; the
``docs/observability.md`` table is machine-checked against it.  Bucket
choices: request/stage latencies share :data:`~repro.obs.registry.
DEFAULT_TIME_BUCKETS` (100 µs–10 s) so quantiles are comparable across
stages.
"""
from __future__ import annotations

import time

from .registry import DEFAULT_TIME_BUCKETS, MetricsRegistry
from .trace import trace as _trace

__all__ = [
    "REGISTRY", "set_enabled", "is_enabled", "timed",
    "COMPRESS_STAGE_SECONDS", "COMPRESS_LEVEL_SECONDS",
    "WRITER_LEVEL_SECONDS", "WRITER_BYTES", "WRITER_LEVELS",
    "PLANNER_SUBBLOCKS", "PLANNER_DECODE_SECONDS", "PLANNER_DECODED_BYTES",
    "ENTROPY_DECODE_SECONDS",
    "SERVER_REQUEST_SECONDS", "SERVER_REGIONS",
    "SERVER_BACKPRESSURE", "SERVER_DECODE_UNITS", "SERVER_QUEUE_DEPTH",
    "CACHE_HITS", "CACHE_MISSES", "CACHE_EVICTIONS",
    "CACHE_ENTRIES", "CACHE_BYTES", "CACHE_BUDGET_BYTES",
    "HANDOFF_KEYS", "HANDOFF_BYTES",
    "ROUTER_SHARD_SECONDS", "ROUTER_BATCHES", "ROUTER_SHARD_REQUESTS",
    "ROUTER_ENDPOINT_FAILURES", "ROUTER_LOCAL_FALLBACKS",
    "ROUTER_RETRIES", "ROUTER_DEMOTIONS", "ROUTER_BATCH_SECONDS",
    "HTTP_REQUESTS", "HTTP_REQUEST_SECONDS",
    "VARIANT_REQUESTS", "VARIANT_FALLBACKS", "VARIANT_UNSATISFIED",
    "VARIANT_LABEL_BUDGET",
    "SLO_FIRING", "SLO_STATE", "SLO_VALUE",
]

#: The process-wide default registry.  Components import this; tests
#: that need isolation construct their own ``MetricsRegistry``.
REGISTRY = MetricsRegistry()


def set_enabled(on: bool) -> None:
    """Master switch for the default registry (and thus all built-in
    instrumentation).  Used by the overhead benchmark to measure the
    uninstrumented baseline."""
    REGISTRY.enabled = bool(on)


def is_enabled() -> bool:
    return REGISTRY.enabled


class timed:
    """Time a region into a histogram child — and, when a root span is
    active on this thread, into a same-named trace span too.

    ``with timed(WRITER_LEVEL_SECONDS.labels("encode"), "encode"): ...``
    is the one instrumentation idiom the hot paths use: the metric feeds
    the scrape surface, the span feeds per-request response metadata.
    The trace half is the shared no-op outside a root span, and the
    histogram's ``observe`` is a no-op when the registry is disabled.
    """

    __slots__ = ("_hist", "_span", "_t0")

    def __init__(self, hist_child, span_name: str | None = None):
        self._hist = hist_child
        self._span = _trace(span_name) if span_name else None
        self._t0 = 0.0

    def __enter__(self) -> "timed":
        if self._span is not None:
            self._span.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe(time.perf_counter() - self._t0)
        if self._span is not None:
            self._span.__exit__(*exc)


# --------------------------- compression ---------------------------------

COMPRESS_STAGE_SECONDS = REGISTRY.histogram(
    "tacz_compress_stage_seconds",
    "Per-stage wall time inside compress_level "
    "(stage: prequant | branch_score | entropy).",
    labels=("stage",))

COMPRESS_LEVEL_SECONDS = REGISTRY.histogram(
    "tacz_compress_level_seconds",
    "End-to-end compress_level wall time, labeled by the resolved "
    "strategy (gsp | opst | akdtree | nast).",
    labels=("strategy",))

# ------------------------------ writers ----------------------------------

WRITER_LEVEL_SECONDS = REGISTRY.histogram(
    "tacz_writer_level_seconds",
    "TACZWriter per-level stage wall time "
    "(stage: encode | pack | publish).",
    labels=("stage",))

WRITER_BYTES = REGISTRY.counter(
    "tacz_writer_bytes_total",
    "Compressed bytes appended to .tacz files (payload sections).")

WRITER_LEVELS = REGISTRY.counter(
    "tacz_writer_levels_total",
    "AMR levels encoded and appended by writers.")

# ------------------------------ planner ----------------------------------

PLANNER_SUBBLOCKS = REGISTRY.counter(
    "tacz_planner_subblocks_total",
    "Sub-blocks resolved by DecodePlanner.fetch "
    "(outcome: cached | decoded).",
    labels=("outcome",))

PLANNER_DECODE_SECONDS = REGISTRY.histogram(
    "tacz_planner_decode_seconds",
    "Wall time of the batched entropy-decode launches inside "
    "DecodePlanner.fetch.")

PLANNER_DECODED_BYTES = REGISTRY.counter(
    "tacz_planner_decoded_bytes_total",
    "Decoded float32 bytes produced by DecodePlanner.fetch "
    "(cache-miss path only).")

ENTROPY_DECODE_SECONDS = REGISTRY.histogram(
    "tacz_entropy_decode_seconds",
    "Wall time of EntropyEngine payload-decode launches inside "
    "TACZReader.decode_subblocks.")

# ------------------------------- server ----------------------------------

SERVER_REQUEST_SECONDS = REGISTRY.histogram(
    "tacz_server_request_seconds",
    "End-to-end RegionServer.get_regions latency per batch.")

SERVER_REGIONS = REGISTRY.counter(
    "tacz_server_regions_total",
    "Region boxes served by RegionServer.get_regions.")

# Admission control (repro.serving.core.AsyncServingCore): decode work
# is bounded; what the bound rejects or queues must be visible.

SERVER_BACKPRESSURE = REGISTRY.counter(
    "tacz_server_backpressure_total",
    "Batches rejected by decode admission control "
    "(reason: queue_full | draining).",
    labels=("reason",))

SERVER_DECODE_UNITS = REGISTRY.counter(
    "tacz_server_decode_units_total",
    "Per-level decode units executed by the AsyncServingCore worker "
    "pool (an oversized batch splits into one unit per level).")

SERVER_QUEUE_DEPTH = REGISTRY.gauge(
    "tacz_server_queue_depth",
    "Decode units currently admitted (queued + running) in the "
    "AsyncServingCore.")

# Cache gauges are refreshed from SubBlockCache.stats() at scrape/stat
# time (the cache keeps its own lifetime counters across hot swaps).
CACHE_HITS = REGISTRY.gauge(
    "tacz_cache_hits", "SubBlockCache lifetime hit count.")
CACHE_MISSES = REGISTRY.gauge(
    "tacz_cache_misses", "SubBlockCache lifetime miss count.")
CACHE_EVICTIONS = REGISTRY.gauge(
    "tacz_cache_evictions", "SubBlockCache lifetime eviction count.")
CACHE_ENTRIES = REGISTRY.gauge(
    "tacz_cache_entries", "Decoded bricks currently resident.")
CACHE_BYTES = REGISTRY.gauge(
    "tacz_cache_bytes", "Bytes of decoded bricks currently resident.")
CACHE_BUDGET_BYTES = REGISTRY.gauge(
    "tacz_cache_budget_bytes", "Configured cache byte budget.")


def refresh_cache_gauges(cache_stats: dict) -> None:
    """Copy a ``SubBlockCache.stats()`` dict into the cache gauges."""
    if not REGISTRY.enabled:
        return
    CACHE_HITS.labels().set(cache_stats.get("hits", 0))
    CACHE_MISSES.labels().set(cache_stats.get("misses", 0))
    CACHE_EVICTIONS.labels().set(cache_stats.get("evictions", 0))
    CACHE_ENTRIES.labels().set(cache_stats.get("entries", 0))
    CACHE_BYTES.labels().set(cache_stats.get("bytes", 0))
    CACHE_BUDGET_BYTES.labels().set(cache_stats.get("budget_bytes", 0))


# Cache handoff (live resharding): decoded bricks moved between shards
# so a grown fleet serves warm instead of cold-starting.

HANDOFF_KEYS = REGISTRY.counter(
    "tacz_cache_handoff_keys_total",
    "Decoded bricks moved by the cache-handoff protocol "
    "(direction: export | import).",
    labels=("direction",))

HANDOFF_BYTES = REGISTRY.counter(
    "tacz_cache_handoff_bytes_total",
    "Decoded-brick payload bytes moved by the cache-handoff protocol "
    "(direction: export | import).",
    labels=("direction",))


# ------------------------------- router ----------------------------------

ROUTER_SHARD_SECONDS = REGISTRY.histogram(
    "tacz_router_shard_seconds",
    "Per-shard fan-out wall time inside ShardedRegionRouter.get_regions "
    "(one observation per (shard, level) group).",
    labels=("shard",))

ROUTER_BATCHES = REGISTRY.counter(
    "tacz_router_batches_total",
    "Batches routed by ShardedRegionRouter.get_regions.")

ROUTER_SHARD_REQUESTS = REGISTRY.counter(
    "tacz_router_shard_requests_total",
    "Shard-group fetches issued by the router.")

ROUTER_ENDPOINT_FAILURES = REGISTRY.counter(
    "tacz_router_endpoint_failures_total",
    "Endpoint attempts that raised (before any retry/fallback).")

ROUTER_LOCAL_FALLBACKS = REGISTRY.counter(
    "tacz_router_local_fallbacks_total",
    "Shard groups served by the router's local reader fallback.")

ROUTER_RETRIES = REGISTRY.counter(
    "tacz_router_retries_total",
    "Endpoint attempts beyond the first within one shard group.")

ROUTER_DEMOTIONS = REGISTRY.counter(
    "tacz_router_endpoint_demotions_total",
    "healthy-to-unhealthy endpoint transitions recorded by the router.")

ROUTER_BATCH_SECONDS = REGISTRY.histogram(
    "tacz_router_batch_seconds",
    "End-to-end ShardedRegionRouter.get_regions latency per batch "
    "(scatter + gather + paste).")

# -------------------------------- http -----------------------------------

HTTP_REQUESTS = REGISTRY.counter(
    "tacz_http_requests_total",
    "HTTP requests served, by route and status code.",
    labels=("route", "status"))

HTTP_REQUEST_SECONDS = REGISTRY.histogram(
    "tacz_http_request_seconds",
    "HTTP request handling wall time, by route.",
    labels=("route",))

# ------------------------------- variants ---------------------------------
# Distortion-aware serving (repro.serving.variants / docs/tuning.md):
# which eb variants actually serve traffic, and how often the frontier
# machinery degrades (fallback) or refuses (unsatisfiable target).

#: Cardinality budget for the ``variant`` label: a fleet mixing many
#: variant sets cannot blow up a scrape — the 65th and later distinct
#: variant names collapse into ``variant="__other__"``.
VARIANT_LABEL_BUDGET = 64

VARIANT_REQUESTS = REGISTRY.counter(
    "tacz_variant_requests_total",
    "Region batches served per selected eb variant (label is the "
    "variant name; 'default' for single-snapshot servers; names beyond "
    "the cardinality budget collapse into '__other__').",
    labels=("variant",), max_series=VARIANT_LABEL_BUDGET)

VARIANT_FALLBACKS = REGISTRY.counter(
    "tacz_variant_fallbacks_total",
    "Distortion-target requests served by the default variant because "
    "the frontier section was missing or corrupt.")

VARIANT_UNSATISFIED = REGISTRY.counter(
    "tacz_variant_unsatisfied_total",
    "Distortion-target requests rejected because no variant satisfies "
    "the target (HTTP 400).")

# --------------------------------- slo ------------------------------------
# The SLO engine (repro.obs.slo) exports its alert state back into the
# registry, so the alert plane is itself scrapable.

SLO_FIRING = REGISTRY.gauge(
    "tacz_slo_firing",
    "1 while the named SLO rule is firing, else 0.",
    labels=("rule",))

SLO_STATE = REGISTRY.gauge(
    "tacz_slo_state",
    "Alert state of the named SLO rule "
    "(0=ok 1=pending 2=firing 3=resolved).",
    labels=("rule",))

SLO_VALUE = REGISTRY.gauge(
    "tacz_slo_value",
    "Last evaluated value of the named SLO rule's expression.",
    labels=("rule",))
