"""``repro.obs.registry`` — a dependency-free, thread-safe metrics registry.

Prometheus' data model, stdlib-only: a :class:`MetricsRegistry` holds
metric *families* (one name + help text + label names each); a family
holds *children* (one per label-value tuple); children are the objects
the hot paths touch — :class:`Counter` (monotonic), :class:`Gauge`
(set/inc/dec), and :class:`Histogram` (fixed cumulative buckets with
``sum``/``count``, plus quantile estimation for the ``/v1/stats``
surface).

Design points that matter for the serving fleet:

  * **Thread safety with exact totals.**  Every mutation takes the
    family's lock — 8 threads incrementing one counter 10k times each
    yield exactly 80k (test-asserted).  The lock is per family, so
    unrelated metrics never contend.
  * **Lifetime totals.**  Children live in the registry, not in the
    components that record to them — a :class:`~repro.serving.regions.
    RegionServer` hot-swapping its snapshot (or being rebuilt) keeps
    accumulating into the same series, exactly like the sub-block
    cache's hit/miss counters.
  * **A kill switch with negligible overhead.**  ``registry.enabled =
    False`` turns every ``inc``/``set``/``observe`` into one attribute
    check + return; the instrumentation overhead benchmark gates the
    *enabled* path at ≥0.95× the disabled throughput.
  * **Prometheus text exposition** (:meth:`MetricsRegistry.render`) in
    the ``text/plain; version=0.0.4`` format — ``# HELP``/``# TYPE``
    lines, escaped label values, ``_bucket{le=...}``/``_sum``/``_count``
    histogram series — servable straight from ``GET /v1/metrics``.
"""
from __future__ import annotations

import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_TIME_BUCKETS", "OVERFLOW_LABEL",
           "quantile_from_buckets"]

#: Default latency buckets (seconds): 100 µs … 10 s, roughly 1-2.5-5 per
#: decade — wide enough for a cold multi-level decode, fine enough to
#: resolve warm cache hits.
DEFAULT_TIME_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005,
                        0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                        10.0)

_NAME_OK = set("abcdefghijklmnopqrstuvwxyz"
               "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


def _check_name(name: str, what: str) -> str:
    if not name or name[0].isdigit() or not set(name) <= _NAME_OK:
        raise ValueError(f"invalid {what} name {name!r}")
    return name


def _escape(value: str) -> str:
    """Escape one label value for the text exposition format."""
    return (str(value).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _escape_help(text: str) -> str:
    """Escape a ``# HELP`` line's text (backslash and newline only —
    quotes are legal in help text, per the exposition spec)."""
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


def quantile_from_buckets(bounds, counts, q: float) -> float | None:
    """Estimate the ``q``-quantile of a fixed-bucket histogram.

    The shared estimator behind :meth:`Histogram.quantile` and the
    windowed fleet quantiles in :mod:`repro.obs.collect`: linear
    interpolation inside the bucket the rank falls into, with the
    overflow (+Inf) bucket clamped to the largest finite bound.

    :param bounds: finite ascending bucket upper bounds.
    :param counts: **non-cumulative** per-bucket counts; one longer than
        ``bounds`` (the last entry is the +Inf overflow bucket).
    :param q: quantile in ``[0, 1]``.
    :returns: the estimate, or None when the histogram holds no samples.
    :raises ValueError: if ``q`` is outside ``[0, 1]``.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError("quantile must be in [0, 1]")
    total = sum(counts)
    if total <= 0:
        return None
    rank = q * total
    cum = 0.0
    lo = 0.0
    for i, c in enumerate(counts[:-1]):
        hi = bounds[i]
        if cum + c >= rank and c > 0:
            frac = (rank - cum) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        cum += c
        lo = hi
    return bounds[-1] if len(bounds) else 0.0


def _fmt(v: float) -> str:
    """Render one sample value (integers without a trailing ``.0``)."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_text(names: tuple[str, ...], values: tuple[str, ...],
                 extra: str = "") -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    if extra:
        pairs.append(extra)
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _Child:
    """Base of one labeled series; mutations lock the family's lock."""

    __slots__ = ("_lock", "_reg")

    def __init__(self, lock: threading.Lock, reg: "MetricsRegistry"):
        self._lock = lock
        self._reg = reg


class Counter(_Child):
    """Monotonically increasing series (``rate()``-able in Prometheus)."""

    __slots__ = ("_value",)

    def __init__(self, lock, reg):
        super().__init__(lock, reg)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        """Add ``amount`` (must be ≥ 0) to the series."""
        if not self._reg.enabled:
            return
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Child):
    """A value that can go up and down (occupancy, budget, in-flight)."""

    __slots__ = ("_value",)

    def __init__(self, lock, reg):
        super().__init__(lock, reg)
        self._value = 0.0

    def set(self, value: float) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Child):
    """Fixed-bucket cumulative histogram (Prometheus semantics).

    ``observe(v)`` increments every bucket whose upper bound is ≥ v —
    stored non-cumulatively here and accumulated at render time, so one
    observation is one index lookup + three adds.  ``quantile(q)``
    estimates a quantile by linear interpolation inside the bucket the
    rank falls into — the same estimate ``histogram_quantile()`` would
    compute server-side, available locally for ``/v1/stats``.
    """

    __slots__ = ("_bounds", "_counts", "_sum", "_count")

    def __init__(self, lock, reg, bounds: tuple[float, ...]):
        super().__init__(lock, reg)
        self._bounds = bounds                    # finite, ascending
        self._counts = [0] * (len(bounds) + 1)   # last = +Inf overflow
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        """Record one sample."""
        if not self._reg.enabled:
            return
        v = float(value)
        # linear scan: bucket lists are short (≤ ~16) and almost every
        # latency sample lands in the first few buckets — cheaper than
        # bisect's function-call overhead at this size
        i = 0
        bounds = self._bounds
        n = len(bounds)
        while i < n and v > bounds[i]:
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> tuple[list[int], float, int]:
        """(non-cumulative bucket counts, sum, count) — one consistent view."""
        with self._lock:
            return list(self._counts), self._sum, self._count

    def quantile(self, q: float) -> float | None:
        """Estimated ``q``-quantile (0 ≤ q ≤ 1), or None with no samples.

        Linear interpolation within the bucket containing the rank; the
        overflow (+Inf) bucket clamps to the largest finite bound — the
        estimate is bucket-resolution coarse, by construction.
        """
        counts, _, _ = self.snapshot()
        return quantile_from_buckets(self._bounds, counts, q)

    def mean(self) -> float | None:
        """Mean of all observations, or None with no samples (never NaN
        — a just-started endpoint's stats surface must serve clean
        nulls, not ``0/0``)."""
        with self._lock:
            if self._count == 0:
                return None
            return self._sum / self._count


#: Label value every over-budget series collapses into (see
#: ``_Family.max_series``) — one bounded bucket instead of a scrape that
#: grows with every distinct label value a client invents.
OVERFLOW_LABEL = "__other__"


class _Family:
    """One metric name: help text, label names, and labeled children."""

    __slots__ = ("name", "help", "kind", "label_names", "_children",
                 "_lock", "_reg", "_bounds", "max_series", "_overflow")

    def __init__(self, reg, name, help_text, kind, label_names,
                 bounds=None, max_series=None):
        self.name = _check_name(name, "metric")
        self.help = str(help_text)
        self.kind = kind
        self.label_names = tuple(_check_name(n, "label")
                                 for n in label_names)
        if max_series is not None:
            max_series = int(max_series)
            if max_series < 1:
                raise ValueError("max_series must be >= 1")
            if not self.label_names:
                raise ValueError(
                    "max_series only applies to labeled families")
        self.max_series = max_series
        self._overflow = (OVERFLOW_LABEL,) * len(self.label_names)
        self._children: dict[tuple[str, ...], _Child] = {}
        self._lock = threading.Lock()
        self._reg = reg
        self._bounds = bounds

    def labels(self, *values) -> _Child:
        """The child series for one label-value tuple (created on first
        use).  A family with no labels has a single anonymous child.

        With ``max_series`` set, a *new* label tuple arriving once the
        family already holds that many distinct series is routed to the
        ``__other__`` overflow child instead — the cardinality budget
        that keeps one scrape bounded no matter how many distinct label
        values (e.g. eb-variant names across a fleet) show up.
        """
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, "
                f"got {len(values)} value(s)")
        key = tuple(str(v) for v in values)
        with self._lock:
            child = self._children.get(key)
            if (child is None and self.max_series is not None
                    and key != self._overflow
                    and sum(k != self._overflow
                            for k in self._children) >= self.max_series):
                key = self._overflow
                child = self._children.get(key)
            if child is None:
                if self.kind == "counter":
                    child = Counter(self._lock, self._reg)
                elif self.kind == "gauge":
                    child = Gauge(self._lock, self._reg)
                else:
                    child = Histogram(self._lock, self._reg, self._bounds)
                self._children[key] = child
            return child

    def children(self) -> dict[tuple[str, ...], _Child]:
        with self._lock:
            return dict(self._children)

    # -- no-label conveniences: delegate to the anonymous child ------------
    # (raise, via labels(), when the family actually declares labels)

    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self.labels().dec(amount)

    def set(self, value: float) -> None:
        self.labels().set(value)

    def observe(self, value: float) -> None:
        self.labels().observe(value)

    @property
    def value(self) -> float:
        return self.labels().value

    @property
    def count(self) -> int:
        return self.labels().count

    @property
    def sum(self) -> float:
        return self.labels().sum

    def quantile(self, q: float):
        return self.labels().quantile(q)

    def mean(self):
        return self.labels().mean()

    # ----------------------------- rendering ------------------------------

    def render(self, out: list[str]) -> None:
        out.append(f"# HELP {self.name} {_escape_help(self.help)}")
        out.append(f"# TYPE {self.name} {self.kind}")
        for key, child in sorted(self.children().items()):
            if self.kind == "histogram":
                counts, total, n = child.snapshot()
                cum = 0
                for bound, c in zip(self._bounds + (math.inf,), counts):
                    cum += c
                    lt = _labels_text(self.label_names, key,
                                      f'le="{_fmt(bound)}"')
                    out.append(f"{self.name}_bucket{lt} {cum}")
                lt = _labels_text(self.label_names, key)
                out.append(f"{self.name}_sum{lt} {_fmt(total)}")
                out.append(f"{self.name}_count{lt} {n}")
            else:
                lt = _labels_text(self.label_names, key)
                out.append(f"{self.name}{lt} {_fmt(child.value)}")


class MetricsRegistry:
    """A named collection of metric families with Prometheus exposition.

    Families are get-or-create: asking twice for the same name returns
    the same family (and raises if the kind/labels/help disagree — two
    call sites silently describing one series differently is a bug).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        #: master switch — ``False`` turns every mutation into a no-op
        #: (reads and rendering still work; see the overhead benchmark)
        self.enabled: bool = True

    # ----------------------------- families -------------------------------

    def _family(self, name, help_text, kind, label_names, bounds=None,
                max_series=None):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if (fam.kind != kind
                        or fam.label_names != tuple(label_names)
                        or (bounds is not None and fam._bounds != bounds)
                        or (max_series is not None
                            and fam.max_series != max_series)):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        f"kind/labels/buckets/max_series")
                return fam
            fam = _Family(self, name, help_text, kind, label_names, bounds,
                          max_series)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_text: str,
                labels: tuple[str, ...] = (),
                max_series: int | None = None) -> _Family:
        """Get or create a counter family.  ``max_series`` caps the
        number of distinct label tuples; later new tuples collapse into
        the ``__other__`` overflow series (see :data:`OVERFLOW_LABEL`)."""
        return self._family(name, help_text, "counter", labels,
                            max_series=max_series)

    def gauge(self, name: str, help_text: str,
              labels: tuple[str, ...] = (),
              max_series: int | None = None) -> _Family:
        """Get or create a gauge family (``max_series`` as in
        :meth:`counter`)."""
        return self._family(name, help_text, "gauge", labels,
                            max_series=max_series)

    def histogram(self, name: str, help_text: str,
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS,
                  max_series: int | None = None,
                  ) -> _Family:
        """Get or create a histogram family with fixed ``buckets``
        (finite ascending upper bounds; ``+Inf`` is implicit;
        ``max_series`` as in :meth:`counter`)."""
        bounds = tuple(float(b) for b in buckets)
        if list(bounds) != sorted(set(bounds)) or any(
                math.isinf(b) for b in bounds):
            raise ValueError("buckets must be finite, ascending, unique")
        return self._family(name, help_text, "histogram", labels, bounds,
                            max_series=max_series)

    def families(self) -> list[_Family]:
        with self._lock:
            return list(self._families.values())

    # ----------------------------- exposition -----------------------------

    def render(self) -> str:
        """The whole registry in Prometheus text exposition format."""
        out: list[str] = []
        for fam in self.families():
            fam.render(out)
        return "\n".join(out) + "\n" if out else ""

    def snapshot(self) -> dict:
        """JSON-friendly dump: ``{name: {labels_repr: value_or_hist}}``."""
        out: dict = {}
        for fam in self.families():
            series = {}
            for key, child in fam.children().items():
                k = ",".join(f"{n}={v}" for n, v in
                             zip(fam.label_names, key)) or "_"
                if fam.kind == "histogram":
                    counts, total, n = child.snapshot()
                    series[k] = {"count": n, "sum": total,
                                 "buckets": counts}
                else:
                    series[k] = child.value
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "series": series}
        return out
