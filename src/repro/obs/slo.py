"""``repro.obs.slo`` — a declarative SLO rule engine over the fleet
collector.

An :class:`SLORule` states an objective the fleet must hold — ``p99 of
tacz_server_request_seconds < 50 ms``, ``error rate < 0.1 %``,
``cache hit ratio > 0.8`` — as data, not code: a *rule kind* (one of
:data:`RULE_TYPES`, the registry ``docs/observability.md``'s rule table
is machine-checked against), a comparison, a threshold, and a
``for``-duration.  An :class:`SLOEngine` evaluates the rules against a
:class:`~repro.obs.collect.FleetCollector` and runs the Prometheus-style
alert state machine per rule:

    ``ok`` → (violating) → ``pending`` → (still violating after
    ``for_seconds``) → ``firing`` → (healthy again) → ``resolved``
    (one evaluation) → ``ok``

Two properties matter operationally:

  * **No data is not a transition.**  A rule whose value evaluates to
    None (no scrapes yet, empty window, just-started shard) keeps its
    current state — a fleet coming up must not flap pending/resolved
    before first traffic.
  * **Windowed, so firing rules can resolve.**  Latency rules read
    *windowed* histogram deltas from the collector, not lifetime
    histograms — once recent traffic is fast again, the p99 the rule
    sees recovers, and the rule walks back through ``resolved`` to
    ``ok``.  (A lifetime quantile never forgets one slow burst.)

Firing state is exported back into the process registry as gauges
(``tacz_slo_firing``/``tacz_slo_state``/``tacz_slo_value``, labeled by
rule name) so the alert plane is itself scrapable, and
:meth:`SLOEngine.report` renders the human-readable fleet verdict the
load-generator benchmark prints.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from . import metrics as obsm

__all__ = ["RULE_TYPES", "SLORule", "RuleState", "SLOEngine",
           "STATE_CODES"]

#: rule kind → one-line contract (the docs rule table mirrors this)
RULE_TYPES: dict[str, str] = {}
_EVALUATORS: dict[str, "callable"] = {}

#: alert states in escalation order, with the numeric codes
#: ``tacz_slo_state`` exports (0=ok 1=pending 2=firing 3=resolved)
STATE_CODES = {"ok": 0, "pending": 1, "firing": 2, "resolved": 3}

_OPS = {
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
    ">": lambda v, t: v > t,
    ">=": lambda v, t: v >= t,
}


def _rule_type(name: str, doc: str):
    def deco(fn):
        RULE_TYPES[name] = doc
        _EVALUATORS[name] = fn
        return fn
    return deco


# ----------------------------- evaluators ---------------------------------
# Each evaluator maps (collector, rule) -> float | None.  None means "no
# data in the window" and never drives a state transition.

@_rule_type("quantile",
            "windowed fleet quantile of a histogram metric "
            "(params: metric, q, window, labels)")
def _eval_quantile(collector, rule):
    p = rule.params
    return collector.quantile(p["metric"], p.get("q", 0.99),
                              p.get("labels"), window=p.get("window"))


@_rule_type("quantile_ratio",
            "ratio of two windowed quantiles of one histogram, e.g. "
            "p99/p50 tail spread (params: metric, q_hi, q_lo, window)")
def _eval_quantile_ratio(collector, rule):
    p = rule.params
    h = collector.histogram_delta(p["metric"], p.get("labels"),
                                  window=p.get("window"))
    if h is None or h.count == 0:
        return None
    hi = h.quantile(p.get("q_hi", 0.99))
    lo = h.quantile(p.get("q_lo", 0.50))
    if hi is None or lo is None or lo <= 0:
        return None
    return hi / lo


@_rule_type("rate",
            "windowed fleet-summed per-second counter rate "
            "(params: metric, window, labels)")
def _eval_rate(collector, rule):
    p = rule.params
    return collector.counter_rate(p["metric"], p.get("labels"),
                                  window=p.get("window"))


@_rule_type("ratio",
            "windowed delta share a/(a+b) of two monotonic series, e.g. "
            "cache hit ratio from hits/misses (params: metric_a, "
            "metric_b, window)")
def _eval_ratio(collector, rule):
    p = rule.params
    a = collector.counter_delta(p["metric_a"], p.get("labels_a"),
                                window=p.get("window"))
    b = collector.counter_delta(p["metric_b"], p.get("labels_b"),
                                window=p.get("window"))
    if a is None or b is None or a + b <= 0:
        return None
    return a / (a + b)


@_rule_type("error_rate",
            "windowed share of a labeled counter's increments whose "
            "label value falls outside the ok set, e.g. non-2xx HTTP "
            "(params: metric, label, ok_prefixes, window)")
def _eval_error_rate(collector, rule):
    p = rule.params
    metric = p.get("metric", "tacz_http_requests_total")
    label = p.get("label", "status")
    ok_prefixes = tuple(p.get("ok_prefixes", ("2",)))
    deltas = collector.counter_deltas_by_series(
        metric, window=p.get("window"))
    if deltas is None:
        return None
    total, bad = 0.0, 0.0
    for pairs, inc in deltas.items():
        value = dict(pairs).get(label, "")
        total += inc
        if not str(value).startswith(ok_prefixes):
            bad += inc
    if total <= 0:
        return None
    return bad / total


@_rule_type("gauge",
            "latest gauge value aggregated across up endpoints "
            "(params: metric, agg=max|min|sum, labels)")
def _eval_gauge(collector, rule):
    p = rule.params
    return collector.gauge(p["metric"], p.get("labels"),
                           agg=p.get("agg", "max"))


@_rule_type("up",
            "fraction of fleet endpoints currently up, from scrape "
            "success + /v1/health (params: none)")
def _eval_up(collector, rule):
    return collector.up_fraction()


# -------------------------------- rules -----------------------------------

@dataclass
class SLORule:
    """One declarative objective.

    :param name: unique rule name — the ``rule`` label on the exported
        ``tacz_slo_*`` gauges.
    :param kind: one of :data:`RULE_TYPES`.
    :param op: comparison the *healthy* fleet satisfies: ``"<"``,
        ``"<="``, ``">"``, ``">="`` (e.g. a latency rule is ``p99 <
        0.05`` — the rule *violates* when the comparison is false).
    :param threshold: right-hand side of the comparison.
    :param for_seconds: how long the rule must stay violating before
        ``pending`` escalates to ``firing`` (0 fires immediately).
    :param params: evaluator parameters (see each kind's line in
        :data:`RULE_TYPES`).
    """

    name: str
    kind: str
    op: str
    threshold: float
    for_seconds: float = 0.0
    params: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in RULE_TYPES:
            raise ValueError(
                f"unknown SLO rule kind {self.kind!r}; "
                f"known: {sorted(RULE_TYPES)}")
        if self.op not in _OPS:
            raise ValueError(f"unknown comparison {self.op!r}")

    def evaluate(self, collector) -> float | None:
        """This rule's current value against ``collector`` (no state)."""
        return _EVALUATORS[self.kind](collector, self)

    def satisfied(self, value: float | None) -> bool | None:
        """Whether ``value`` meets the objective (None with no data)."""
        if value is None:
            return None
        return _OPS[self.op](value, self.threshold)

    def describe(self) -> str:
        base = self.params.get("metric") or self.params.get("metric_a") \
            or self.kind
        return f"{self.kind}({base}) {self.op} {self.threshold:g}"


@dataclass
class RuleState:
    """Mutable alert state of one rule inside an engine."""

    rule: SLORule
    state: str = "ok"
    value: float | None = None
    pending_since: float | None = None
    last_transition: float | None = None
    ever_fired: bool = False
    evaluations: int = 0

    @property
    def satisfied(self) -> bool | None:
        return self.rule.satisfied(self.value)


class SLOEngine:
    """Evaluate a rule set against a fleet collector, tracking alert
    state and exporting it back into the metrics registry.

    :param collector: the :class:`~repro.obs.collect.FleetCollector`
        rules read from.
    :param rules: the :class:`SLORule` objectives (names must be
        unique).
    :param clock: time source for ``for``-duration tracking (monotonic;
        injectable so tests can step it).
    :param export: when True (default), every evaluation writes
        ``tacz_slo_firing``/``tacz_slo_state``/``tacz_slo_value``
        gauges labeled by rule name into the process registry.
    """

    def __init__(self, collector, rules, *, clock=time.monotonic,
                 export: bool = True):
        rules = list(rules)
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO rule names in {names}")
        self.collector = collector
        self.states: dict[str, RuleState] = {
            r.name: RuleState(rule=r) for r in rules}
        self._clock = clock
        self._export = export

    @property
    def rules(self) -> list[SLORule]:
        return [s.rule for s in self.states.values()]

    # ------------------------------ stepping -------------------------------

    def evaluate(self) -> dict[str, RuleState]:
        """Evaluate every rule once and step its state machine.

        Transitions (per rule, in order):

        * value None → state unchanged (no data is not evidence);
        * violating: ``ok``/``resolved`` → ``pending`` (stamp
          ``pending_since``); ``pending`` → ``firing`` once
          ``for_seconds`` elapsed; ``firing`` stays;
        * healthy: ``pending`` → ``ok`` (a blip shorter than
          ``for_seconds`` never alerts); ``firing`` → ``resolved``
          (visible for exactly one evaluation); ``resolved`` → ``ok``.

        :returns: the engine's state map (live objects, not copies).
        """
        now = self._clock()
        for st in self.states.values():
            st.evaluations += 1
            value = st.rule.evaluate(self.collector)
            if value is not None:
                st.value = value
            ok = st.rule.satisfied(value)
            if ok is None:
                self._export_rule(st)
                continue
            if not ok:
                if st.state in ("ok", "resolved"):
                    st.state = "pending"
                    st.pending_since = now
                    st.last_transition = now
                elif st.state == "pending" and \
                        now - st.pending_since >= st.rule.for_seconds:
                    st.state = "firing"
                    st.ever_fired = True
                    st.last_transition = now
            else:
                if st.state == "pending":
                    st.state = "ok"
                    st.pending_since = None
                    st.last_transition = now
                elif st.state == "firing":
                    st.state = "resolved"
                    st.pending_since = None
                    st.last_transition = now
                elif st.state == "resolved":
                    st.state = "ok"
                    st.last_transition = now
            self._export_rule(st)
        return self.states

    def _export_rule(self, st: RuleState) -> None:
        if not self._export:
            return
        name = st.rule.name
        obsm.SLO_FIRING.labels(name).set(1.0 if st.state == "firing"
                                         else 0.0)
        obsm.SLO_STATE.labels(name).set(STATE_CODES[st.state])
        if st.value is not None:
            obsm.SLO_VALUE.labels(name).set(st.value)

    # ------------------------------ verdicts -------------------------------

    def firing(self) -> list[str]:
        """Names of rules currently firing."""
        return [n for n, s in self.states.items() if s.state == "firing"]

    def passed(self) -> bool:
        """True when every rule's latest value meets its objective and
        nothing is pending/firing — the bench's pinned-SLO gate."""
        for st in self.states.values():
            if st.state in ("pending", "firing"):
                return False
            if st.satisfied is False:
                return False
        return True

    def verdict(self) -> dict:
        """Machine-readable per-rule verdict (what the bench merges
        into ``bench_summary.json``)."""
        rules = {}
        for name, st in self.states.items():
            rules[name] = {
                "objective": st.rule.describe(),
                "kind": st.rule.kind,
                "value": st.value,
                "threshold": st.rule.threshold,
                "op": st.rule.op,
                "state": st.state,
                "satisfied": st.satisfied,
                "ever_fired": st.ever_fired,
                "evaluations": st.evaluations,
            }
        return {"passed": self.passed(), "rules": rules}

    def report(self) -> str:
        """Human-readable fleet report — one row per rule plus the
        endpoint up/down roll call."""
        lines = ["SLO fleet report", "================"]
        up = [n for n in self.collector.endpoints if self.collector.up(n)]
        down = [n for n in self.collector.endpoints if n not in up]
        lines.append(f"endpoints: {len(up)}/{len(self.collector.endpoints)}"
                     f" up" + (f" (down: {', '.join(down)})" if down
                               else ""))
        width = max((len(n) for n in self.states), default=4)
        for name, st in self.states.items():
            value = "n/a" if st.value is None else f"{st.value:.6g}"
            mark = {"ok": "PASS", "resolved": "PASS",
                    "pending": "WARN", "firing": "FAIL"}[st.state]
            lines.append(
                f"  [{mark}] {name:<{width}}  {st.rule.describe():<44}"
                f" value={value} state={st.state}")
        lines.append(f"overall: {'PASS' if self.passed() else 'FAIL'}")
        return "\n".join(lines)
