"""``repro.obs`` — dependency-free observability for the TACZ pipeline.

Three pieces, all stdlib-only:

  * :mod:`repro.obs.registry` — a thread-safe ``MetricsRegistry`` with
    counters, gauges, and fixed-bucket histograms, rendering Prometheus
    text exposition and estimating quantiles from the buckets.
  * :mod:`repro.obs.trace` — a ``Span``/``trace()`` context-manager API
    for nested per-stage timings, plus request IDs and the
    ``X-Repro-Request-Id`` header name.
  * :mod:`repro.obs.metrics` — the process-wide default ``REGISTRY``
    and the metric catalog every instrumented component records into.
  * :mod:`repro.obs.expo` — parser for the Prometheus text exposition;
    ``to_snapshot(parse(reg.render()))`` round-trips ``reg.snapshot()``
    exactly (property-tested), so anything that can scrape
    ``/v1/metrics`` can be programmatically read.
  * :mod:`repro.obs.collect` — ``FleetCollector``: polls N endpoints
    into ring-buffer time series, computes counter rates/deltas and
    windowed histogram quantiles across scrapes, aggregates per-shard
    series into fleet totals, and dumps JSON snapshots.
  * :mod:`repro.obs.slo` — declarative SLO rules (``p99 < 50ms``,
    ``error_rate < 0.1%``, …) with pending→firing→resolved alert state,
    evaluated against the collector and exported back as gauges.

See ``docs/observability.md`` for the full catalog, the tracing model,
and the SLO rule table.
"""
from . import metrics
from .collect import FleetCollector, Scrape
from .expo import ParsedFamily, ParsedHistogram
from .metrics import REGISTRY, is_enabled, set_enabled, timed
from .registry import (DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram,
                       MetricsRegistry, quantile_from_buckets)
from .slo import RULE_TYPES, SLOEngine, SLORule
from .trace import (REQUEST_ID_HEADER, Span, current_span, new_request_id,
                    root_span, trace)
from . import expo

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_TIME_BUCKETS", "quantile_from_buckets",
    "Span", "trace", "root_span", "current_span",
    "new_request_id", "REQUEST_ID_HEADER",
    "REGISTRY", "metrics", "set_enabled", "is_enabled", "timed",
    "expo", "ParsedFamily", "ParsedHistogram",
    "FleetCollector", "Scrape",
    "SLOEngine", "SLORule", "RULE_TYPES",
]
