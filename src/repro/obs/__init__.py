"""``repro.obs`` — dependency-free observability for the TACZ pipeline.

Three pieces, all stdlib-only:

  * :mod:`repro.obs.registry` — a thread-safe ``MetricsRegistry`` with
    counters, gauges, and fixed-bucket histograms, rendering Prometheus
    text exposition and estimating quantiles from the buckets.
  * :mod:`repro.obs.trace` — a ``Span``/``trace()`` context-manager API
    for nested per-stage timings, plus request IDs and the
    ``X-Repro-Request-Id`` header name.
  * :mod:`repro.obs.metrics` — the process-wide default ``REGISTRY``
    and the metric catalog every instrumented component records into.

See ``docs/observability.md`` for the full catalog and the tracing
model.
"""
from . import metrics
from .metrics import REGISTRY, is_enabled, set_enabled, timed
from .registry import (DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram,
                       MetricsRegistry)
from .trace import (REQUEST_ID_HEADER, Span, current_span, new_request_id,
                    root_span, trace)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_TIME_BUCKETS",
    "Span", "trace", "root_span", "current_span",
    "new_request_id", "REQUEST_ID_HEADER",
    "REGISTRY", "metrics", "set_enabled", "is_enabled", "timed",
]
