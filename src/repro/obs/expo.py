"""``repro.obs.expo`` — parser for the Prometheus text exposition.

PR 7 put a ``GET /v1/metrics`` scrape on every region endpoint; this
module is the read side of that wire contract: :func:`parse` turns the
``text/plain; version=0.0.4`` body back into typed families and samples,
and :func:`to_snapshot` reduces the parsed form to exactly the shape
:meth:`repro.obs.registry.MetricsRegistry.snapshot` produces — the
round trip ``to_snapshot(parse(reg.render())) == reg.snapshot()`` is
property-tested over metric names, label escaping edge cases, and
``+Inf`` buckets.

Design points:

  * **Typed, not stringly.**  A scrape becomes ``{name:``
    :class:`ParsedFamily```}``; counter/gauge series are floats keyed by
    their label pairs, histogram series are :class:`ParsedHistogram`
    objects that keep the bucket *bounds* (recovered from the ``le``
    labels) alongside de-cumulated per-bucket counts — which is what
    lets :mod:`repro.obs.collect` compute windowed quantiles from
    scrape deltas.
  * **Escaping round-trips.**  Label values (and help text) are
    unescaped with the inverse of the renderer's rules (``\\\\``,
    ``\\n``, ``\\"``), so a label value containing quotes, backslashes,
    or newlines survives scrape → parse intact.
  * **Lenient where the spec is.**  Samples with no preceding ``# TYPE``
    line are collected as ``untyped``; unknown comment lines and blank
    lines are skipped; a malformed sample line raises ``ValueError``
    with the offending line (a truncated scrape should fail loudly, not
    silently drop series).

``RegionClient.metrics()`` returns this module's parsed form;
``RegionClient.metrics_text()`` keeps the raw body.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

__all__ = ["ParsedHistogram", "ParsedFamily", "parse", "to_snapshot"]

#: label pairs of one series, in exposition order — ``()`` for the
#: anonymous child of a label-less family
LabelPairs = tuple[tuple[str, str], ...]


@dataclass
class ParsedHistogram:
    """One histogram series reassembled from its ``_bucket``/``_sum``/
    ``_count`` sample lines.

    ``bounds`` are the finite ``le`` values in ascending order;
    ``counts`` are **non-cumulative** per-bucket counts with the +Inf
    overflow last (``len(counts) == len(bounds) + 1``) — the same layout
    :meth:`repro.obs.registry.Histogram.snapshot` returns.
    """

    bounds: tuple[float, ...] = ()
    counts: list[int] = field(default_factory=list)
    sum: float = 0.0
    count: int = 0
    # builder state: cumulative counts keyed by le, folded in finalize()
    _cum: dict[float, int] = field(default_factory=dict, repr=False)

    def finalize(self) -> None:
        """De-cumulate the collected ``le`` buckets into ``counts``.

        :raises ValueError: if cumulative counts decrease with ``le``
            (a corrupt scrape) or the +Inf bucket is missing.
        """
        if math.inf not in self._cum:
            raise ValueError("histogram series has no +Inf bucket")
        finite = sorted(b for b in self._cum if not math.isinf(b))
        self.bounds = tuple(finite)
        counts, prev = [], 0
        for b in finite + [math.inf]:
            cum = self._cum[b]
            if cum < prev:
                raise ValueError(
                    f"histogram bucket counts decrease at le={b}")
            counts.append(cum - prev)
            prev = cum
        self.counts = counts
        self._cum.clear()

    def quantile(self, q: float) -> float | None:
        """Bucket-interpolated quantile (None with zero observations)."""
        from .registry import quantile_from_buckets
        return quantile_from_buckets(self.bounds, self.counts, q)


@dataclass
class ParsedFamily:
    """One metric family recovered from a scrape."""

    name: str
    kind: str = "untyped"
    help: str = ""
    #: label names in exposition order (first-seen sample; ``le`` never
    #: appears — it is folded into :class:`ParsedHistogram`)
    label_names: tuple[str, ...] = ()
    #: series keyed by their label pairs — floats for counters/gauges,
    #: :class:`ParsedHistogram` for histograms
    series: dict[LabelPairs, "float | ParsedHistogram"] = \
        field(default_factory=dict)

    def get(self, **labels) -> "float | ParsedHistogram | None":
        """The series matching exactly these labels, or None."""
        key = tuple((n, str(labels[n])) for n in self.label_names
                    if n in labels)
        if len(key) != len(labels):          # unknown label name given
            return None
        return self.series.get(key)


def _unescape(value: str) -> str:
    """Inverse of the renderer's label-value escaping."""
    if "\\" not in value:
        return value
    out, i, n = [], 0, len(value)
    while i < n:
        c = value[i]
        if c == "\\" and i + 1 < n:
            nxt = value[i + 1]
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
            if nxt in ("\\", '"'):
                out.append(nxt)
                i += 2
                continue
        out.append(c)
        i += 1
    return "".join(out)


def _parse_labels(text: str, line: str) -> list[tuple[str, str]]:
    """Parse the inside of one ``{...}`` label block."""
    pairs: list[tuple[str, str]] = []
    i, n = 0, len(text)
    while i < n:
        eq = text.find("=", i)
        if eq < 0 or eq + 1 >= n or text[eq + 1] != '"':
            raise ValueError(f"malformed label block in line {line!r}")
        name = text[i:eq].strip()
        # scan the quoted value, honoring backslash escapes
        j = eq + 2
        buf = []
        while j < n:
            c = text[j]
            if c == "\\" and j + 1 < n:
                buf.append(c)
                buf.append(text[j + 1])
                j += 2
                continue
            if c == '"':
                break
            buf.append(c)
            j += 1
        else:
            raise ValueError(f"unterminated label value in line {line!r}")
        pairs.append((name, _unescape("".join(buf))))
        i = j + 1
        if i < n and text[i] == ",":
            i += 1
    return pairs


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    return float(s)


def _split_sample(line: str) -> tuple[str, list[tuple[str, str]], float]:
    """One sample line → (name, label pairs, value)."""
    brace = line.find("{")
    if brace >= 0:
        close = line.rfind("}")
        if close < brace:
            raise ValueError(f"malformed sample line {line!r}")
        name = line[:brace]
        labels = _parse_labels(line[brace + 1:close], line)
        rest = line[close + 1:].strip()
    else:
        name, _, rest = line.partition(" ")
        labels = []
        rest = rest.strip()
    if not name or not rest:
        raise ValueError(f"malformed sample line {line!r}")
    # ignore an optional trailing timestamp (we never render one, but
    # other exporters may)
    value = rest.split()[0]
    return name, labels, _parse_value(value)


def parse(text: str) -> dict[str, ParsedFamily]:
    """Parse one exposition body into typed families.

    :param text: a ``text/plain; version=0.0.4`` scrape body (e.g. the
        return of :meth:`MetricsRegistry.render` or
        ``RegionClient.metrics_text()``).
    :returns: ``{family_name: ParsedFamily}`` in document order.
        Histogram families carry fully reassembled
        :class:`ParsedHistogram` series; a family declared by ``# TYPE``
        with no samples appears with empty ``series`` (a valid state —
        e.g. a catalog family before first traffic).
    :raises ValueError: on a malformed sample line, a histogram series
        missing its +Inf bucket, or decreasing cumulative buckets.
    """
    families: dict[str, ParsedFamily] = {}

    def family(name: str) -> ParsedFamily:
        fam = families.get(name)
        if fam is None:
            fam = families[name] = ParsedFamily(name)
        return fam

    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)   # '#', kw, name, text...
            if len(parts) >= 3 and parts[1] == "HELP":
                family(parts[2]).help = _unescape(
                    parts[3] if len(parts) > 3 else "")
            elif len(parts) >= 4 and parts[1] == "TYPE":
                family(parts[2]).kind = parts[3]
            continue                      # other comments: skipped
        name, labels, value = _split_sample(line)

        # histogram sample names carry a suffix; resolve to the family
        # declared by # TYPE (falls back to the raw name → untyped).
        # An exact-name non-histogram family wins first, so a counter
        # that merely *ends* in _sum/_count next to a histogram with the
        # matching base name is never misattributed.
        base, suffix = name, ""
        exact = families.get(name)
        if exact is None or exact.kind == "histogram":
            for cand_suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(cand_suffix):
                    cand = name[:-len(cand_suffix)]
                    if families.get(cand) is not None \
                            and families[cand].kind == "histogram":
                        base, suffix = cand, cand_suffix
                        break
        fam = family(base)

        if fam.kind == "histogram" and suffix:
            pairs = tuple((n, v) for n, v in labels if n != "le")
            if not fam.label_names and pairs:
                fam.label_names = tuple(n for n, _ in pairs)
            h = fam.series.get(pairs)
            if h is None:
                h = fam.series[pairs] = ParsedHistogram()
            if suffix == "_bucket":
                le = next((v for n, v in labels if n == "le"), None)
                if le is None:
                    raise ValueError(
                        f"histogram bucket without le: {line!r}")
                h._cum[_parse_value(le)] = int(value)
            elif suffix == "_sum":
                h.sum = value
            else:
                h.count = int(value)
        else:
            pairs = tuple(labels)
            if not fam.label_names and pairs:
                fam.label_names = tuple(n for n, _ in pairs)
            fam.series[pairs] = value

    for fam in families.values():
        if fam.kind == "histogram":
            for h in fam.series.values():
                h.finalize()
    return families


def to_snapshot(families: dict[str, ParsedFamily]) -> dict:
    """Reduce parsed families to the exact
    :meth:`MetricsRegistry.snapshot` shape.

    ``to_snapshot(parse(reg.render())) == reg.snapshot()`` is the
    round-trip contract (property-tested): counters/gauges become
    floats, histograms become ``{"count", "sum", "buckets"}`` with
    non-cumulative bucket counts, and series keys use the snapshot's
    ``"k=v,k2=v2"`` (or ``"_"``) label encoding.
    """
    out: dict = {}
    for fam in families.values():
        series: dict = {}
        for pairs, v in fam.series.items():
            key = ",".join(f"{n}={val}" for n, val in pairs) or "_"
            if isinstance(v, ParsedHistogram):
                series[key] = {"count": v.count, "sum": v.sum,
                               "buckets": list(v.counts)}
            else:
                series[key] = v
        out[fam.name] = {"type": fam.kind, "help": fam.help,
                         "series": series}
    return out
