"""``repro.obs.collect`` — fleet-wide metric collection over the scrape
surface.

A :class:`FleetCollector` polls N region endpoints (shards + router) on
an interval, parses each ``GET /v1/metrics`` body through
:mod:`repro.obs.expo`, and keeps a bounded ring buffer of scrapes per
endpoint.  On top of that buffer it computes what a one-endpoint scrape
cannot:

  * **up/down** — an endpoint is *up* when its last poll succeeded and
    its ``GET /v1/health`` body (when the endpoint serves one) reports
    ``status != "down"``;
  * **counter deltas and rates** across scrapes, with counter-reset
    handling (a restarted endpoint's counters drop to ~0; the delta
    treats the post-reset value as the increment instead of going
    negative);
  * **windowed histogram deltas** — the bucket-count difference between
    the newest scrape and the oldest scrape inside the window, which is
    what windowed quantiles (``p99 over the last 30 s``) are computed
    from (the SLO engine's latency rules ride this, so a firing rule can
    *resolve* once recent traffic is fast again — lifetime histograms
    never forget);
  * **fleet aggregation** — per-endpoint series merged by label key:
    counters and histogram buckets *sum*, gauges report *max* and *min*
    (summing a ``budget_bytes`` gauge across shards is meaningful,
    summing a ``p50`` is not — the caller picks);
  * **machine-readable JSON snapshots** (:meth:`snapshot`,
    :meth:`dump_json`) — per-endpoint state plus the fleet aggregate,
    the artifact the load-generator benchmark uploads.

The collector is transport-agnostic: pass ``fetch=`` to scrape anything
that can produce an exposition body (the tests inject fakes; the default
uses :class:`repro.serving.client.RegionClient`).  Polling can be driven
manually (:meth:`poll` — deterministic, what tests do) or on a
background thread (:meth:`start`/:meth:`stop`).
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field

from . import expo

__all__ = ["Scrape", "FleetCollector"]


@dataclass
class Scrape:
    """One poll of one endpoint."""

    ts: float
    ok: bool
    families: dict[str, expo.ParsedFamily] = field(default_factory=dict)
    health: dict | None = None
    error: str = ""


def _default_fetch(url: str, timeout: float):
    """Scrape one endpoint over HTTP: metrics body + optional health."""
    from repro.serving.client import RegionClient
    cli = RegionClient(url, timeout=timeout)
    text = cli.metrics_text()
    try:
        health = cli.health()
    except Exception:        # health endpoint absent or failing: metrics
        health = None        # alone still make the endpoint scrapable
    return text, health


def _series_value(fam: expo.ParsedFamily | None, pairs) :
    if fam is None:
        return None
    return fam.series.get(tuple(pairs))


def _label_pairs(fam: expo.ParsedFamily, labels: dict) -> tuple:
    """Order a labels dict by the family's label order."""
    names = fam.label_names or tuple(labels)
    return tuple((n, str(labels[n])) for n in names if n in labels)


class FleetCollector:
    """Poll a fleet of scrape endpoints into ring-buffer time series.

    :param endpoints: ``{name: base_url}`` — shard servers, routers,
        anything serving ``GET /v1/metrics``.
    :param window: ring-buffer depth per endpoint (scrapes, not
        seconds); the oldest scrape bounds the largest usable
        rate/quantile window.
    :param timeout: per-scrape socket timeout, seconds.
    :param fetch: ``fetch(url, timeout) -> (metrics_text, health_dict)``
        override for tests/other transports.
    :param clock: timestamp source for scrape ``ts`` (monotonic).
    """

    def __init__(self, endpoints: dict[str, str], *, window: int = 120,
                 timeout: float = 5.0, fetch=None, clock=time.monotonic):
        if not endpoints:
            raise ValueError("FleetCollector needs at least one endpoint")
        self.endpoints = {str(k): str(v) for k, v in endpoints.items()}
        self.timeout = float(timeout)
        self._fetch = fetch or _default_fetch
        self._clock = clock
        self._buffers: dict[str, deque[Scrape]] = {
            name: deque(maxlen=int(window)) for name in self.endpoints}
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.polls = 0

    # ------------------------------ polling --------------------------------

    def poll(self) -> dict[str, Scrape]:
        """Scrape every endpoint once (concurrently — one slow endpoint
        must not stall the fleet's sampling cadence).

        :returns: ``{endpoint_name: Scrape}`` for this round; failures
            come back as ``ok=False`` scrapes with the error text, they
            never raise.
        """
        results: dict[str, Scrape] = {}

        def one(name: str, url: str) -> None:
            ts = self._clock()
            try:
                text, health = self._fetch(url, self.timeout)
                results[name] = Scrape(ts, True, expo.parse(text), health)
            except Exception as exc:   # noqa: BLE001 — isolate endpoints
                results[name] = Scrape(ts, False, error=str(exc))

        threads = [threading.Thread(target=one, args=item, daemon=True)
                   for item in self.endpoints.items()]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        with self._lock:
            for name, scrape in results.items():
                self._buffers[name].append(scrape)
            self.polls += 1
        return results

    def start(self, interval: float = 5.0) -> None:
        """Poll on a daemon thread every ``interval`` seconds until
        :meth:`stop` (idempotent — a running collector is left alone)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(interval):
                self.poll()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fleet-collector")
        self._thread.start()

    def stop(self) -> None:
        """Stop the background polling thread (if any) and join it."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    # ------------------------------ reading --------------------------------

    def scrapes(self, endpoint: str) -> list[Scrape]:
        """This endpoint's buffered scrapes, oldest first."""
        with self._lock:
            return list(self._buffers[endpoint])

    def latest(self, endpoint: str) -> Scrape | None:
        """The newest scrape of one endpoint (successful or not)."""
        with self._lock:
            buf = self._buffers[endpoint]
            return buf[-1] if buf else None

    def up(self, endpoint: str) -> bool:
        """True when the endpoint's last poll succeeded and its health
        body (when present) does not report ``status: "down"``."""
        s = self.latest(endpoint)
        if s is None or not s.ok:
            return False
        if s.health is not None and s.health.get("status") == "down":
            return False
        return True

    def up_fraction(self) -> float:
        """Fraction of endpoints currently up (0..1)."""
        names = list(self.endpoints)
        return sum(self.up(n) for n in names) / len(names)

    def _window_pair(self, endpoint: str, window: float | None,
                     ) -> tuple[Scrape, Scrape] | None:
        """(baseline, newest) successful scrapes spanning ≤ ``window``
        seconds — baseline is the oldest successful scrape still inside
        the window.  None without two successful scrapes."""
        oks = [s for s in self.scrapes(endpoint) if s.ok]
        if len(oks) < 2:
            return None
        newest = oks[-1]
        cutoff = -math.inf if window is None else newest.ts - window
        base = None
        for s in oks[:-1]:
            if s.ts >= cutoff:
                base = s
                break
        if base is None:
            return None
        return base, newest

    # ----------------------------- counters --------------------------------

    def counter_delta(self, metric: str, labels: dict | None = None, *,
                      window: float | None = None,
                      endpoint: str | None = None) -> float | None:
        """Counter increase over the window (fleet-summed by default).

        Reset-safe: when the newest value is below the baseline (an
        endpoint restarted), the post-reset value itself is the
        increment.  ``endpoint=None`` sums the per-endpoint deltas over
        *up* endpoints.

        :returns: the delta, or None when no endpoint has two
            successful scrapes covering the window.
        """
        if endpoint is not None:
            names = [endpoint]
        else:
            names = [n for n in self.endpoints if self.up(n)]
        total, seen = 0.0, False
        for name in names:
            pair = self._window_pair(name, window)
            if pair is None:
                continue
            base, newest = pair
            fam_new = newest.families.get(metric)
            if fam_new is None:
                continue
            fam_old = base.families.get(metric)
            for pairs, v_new in fam_new.series.items():
                if labels is not None and tuple(
                        _label_pairs(fam_new, labels)) != pairs:
                    continue
                if isinstance(v_new, expo.ParsedHistogram):
                    continue
                v_old = _series_value(fam_old, pairs)
                if v_old is None or not isinstance(v_old, float):
                    v_old = 0.0
                total += v_new if v_new < v_old else v_new - v_old
                seen = True
        return total if seen else None

    def counter_deltas_by_series(self, metric: str, *,
                                 window: float | None = None,
                                 ) -> dict[tuple, float] | None:
        """Per-label-series counter deltas over the window, fleet-summed.

        Same reset handling as :meth:`counter_delta`, but keyed by label
        pairs instead of collapsed — what the SLO engine's ``error_rate``
        rule uses to split ``tacz_http_requests_total`` increments by
        their ``status`` label.

        :returns: ``{label_pairs: delta}`` or None when no endpoint has
            two successful scrapes covering the window.
        """
        out: dict[tuple, float] = {}
        seen = False
        for name in self.endpoints:
            if not self.up(name):
                continue
            pair = self._window_pair(name, window)
            if pair is None:
                continue
            base, newest = pair
            fam_new = newest.families.get(metric)
            if fam_new is None:
                continue
            fam_old = base.families.get(metric)
            for pairs, v_new in fam_new.series.items():
                if isinstance(v_new, expo.ParsedHistogram):
                    continue
                v_old = _series_value(fam_old, pairs)
                if v_old is None or not isinstance(v_old, float):
                    v_old = 0.0
                inc = v_new if v_new < v_old else v_new - v_old
                out[pairs] = out.get(pairs, 0.0) + inc
                seen = True
        return out if seen else None

    def counter_rate(self, metric: str, labels: dict | None = None, *,
                     window: float | None = None,
                     endpoint: str | None = None) -> float | None:
        """Per-second counter rate over the window (delta / elapsed).

        Elapsed time is measured from the scrape timestamps actually
        used, not the nominal window.
        """
        if endpoint is not None:
            names = [endpoint]
        else:
            names = [n for n in self.endpoints if self.up(n)]
        total, elapsed = 0.0, 0.0
        for name in names:
            pair = self._window_pair(name, window)
            if pair is None:
                continue
            d = self.counter_delta(metric, labels, window=window,
                                   endpoint=name)
            if d is None:
                continue
            total += d
            elapsed = max(elapsed, pair[1].ts - pair[0].ts)
        if elapsed <= 0:
            return None
        return total / elapsed

    # ----------------------------- histograms ------------------------------

    def histogram_delta(self, metric: str, labels: dict | None = None, *,
                        window: float | None = None,
                        endpoint: str | None = None,
                        ) -> expo.ParsedHistogram | None:
        """Windowed, fleet-summed histogram increase.

        The newest scrape's buckets minus the baseline scrape's, merged
        (bucket-wise sum) across matching series and across up
        endpoints.  A count drop (endpoint restart) falls back to the
        newest scrape's absolute buckets for that series.

        :returns: a :class:`~repro.obs.expo.ParsedHistogram` holding the
            window's observations only, or None when no data covers the
            window or bucket bounds disagree across series.
        """
        if endpoint is not None:
            names = [endpoint]
        else:
            names = [n for n in self.endpoints if self.up(n)]
        bounds: tuple[float, ...] | None = None
        counts: list[int] = []
        total_sum, total_count, seen = 0.0, 0, False
        for name in names:
            pair = self._window_pair(name, window)
            if pair is None:
                continue
            base, newest = pair
            fam_new = newest.families.get(metric)
            if fam_new is None or fam_new.kind != "histogram":
                continue
            fam_old = base.families.get(metric)
            for pairs, h_new in fam_new.series.items():
                if labels is not None and tuple(
                        _label_pairs(fam_new, labels)) != pairs:
                    continue
                if not isinstance(h_new, expo.ParsedHistogram):
                    continue
                h_old = _series_value(fam_old, pairs)
                if (isinstance(h_old, expo.ParsedHistogram)
                        and h_old.count <= h_new.count
                        and h_old.bounds == h_new.bounds):
                    d_counts = [a - b for a, b in
                                zip(h_new.counts, h_old.counts)]
                    d_sum = h_new.sum - h_old.sum
                    d_count = h_new.count - h_old.count
                    if any(c < 0 for c in d_counts):
                        continue          # corrupt pair: skip the series
                else:                     # reset or first sight
                    d_counts = list(h_new.counts)
                    d_sum, d_count = h_new.sum, h_new.count
                if bounds is None:
                    bounds = h_new.bounds
                    counts = [0] * len(d_counts)
                elif bounds != h_new.bounds:
                    return None           # incomparable bucket layouts
                counts = [a + b for a, b in zip(counts, d_counts)]
                total_sum += d_sum
                total_count += d_count
                seen = True
        if not seen or bounds is None:
            return None
        out = expo.ParsedHistogram(bounds=bounds, counts=counts,
                                   sum=total_sum, count=total_count)
        return out

    def quantile(self, metric: str, q: float,
                 labels: dict | None = None, *,
                 window: float | None = None,
                 endpoint: str | None = None) -> float | None:
        """Windowed fleet quantile from histogram bucket deltas.

        ``None`` means *no observations in the window* — callers (the
        SLO engine, ``/v1/stats`` consumers) must treat that as "no
        data", never as zero.
        """
        h = self.histogram_delta(metric, labels, window=window,
                                 endpoint=endpoint)
        if h is None or h.count == 0:
            return None
        return h.quantile(q)

    # ------------------------------ gauges ---------------------------------

    def gauge(self, metric: str, labels: dict | None = None, *,
              agg: str = "max",
              endpoint: str | None = None) -> float | None:
        """Latest gauge value aggregated across up endpoints.

        :param agg: ``"max"``, ``"min"``, or ``"sum"`` — gauges do not
            have one universally correct fleet aggregation, so the
            caller chooses (the fleet snapshot reports max and min).
        """
        if agg not in ("max", "min", "sum"):
            raise ValueError(f"unknown gauge aggregation {agg!r}")
        if endpoint is not None:
            names = [endpoint]
        else:
            names = [n for n in self.endpoints if self.up(n)]
        values: list[float] = []
        for name in names:
            s = self.latest(name)
            if s is None or not s.ok:
                continue
            fam = s.families.get(metric)
            if fam is None:
                continue
            for pairs, v in fam.series.items():
                if labels is not None and tuple(
                        _label_pairs(fam, labels)) != pairs:
                    continue
                if isinstance(v, expo.ParsedHistogram):
                    continue
                values.append(v)
        if not values:
            return None
        if agg == "max":
            return max(values)
        if agg == "min":
            return min(values)
        return sum(values)

    # ---------------------------- aggregation ------------------------------

    def fleet_families(self) -> dict[str, dict]:
        """Latest scrapes aggregated across up endpoints.

        Counters and histogram buckets/sums/counts are summed per label
        key; gauges report ``{"max": ..., "min": ...}``.  The result is
        JSON-safe (histogram series carry their ``bounds``).

        :returns: ``{metric: {"type", "help", "series": {label_key:
            value}}}`` with label keys in the registry snapshot's
            ``"k=v,..."``/``"_"`` encoding.
        """
        agg: dict[str, dict] = {}
        for name in self.endpoints:
            if not self.up(name):
                continue
            s = self.latest(name)
            for fname, fam in s.families.items():
                out = agg.setdefault(fname, {"type": fam.kind,
                                             "help": fam.help,
                                             "series": {}})
                for pairs, v in fam.series.items():
                    key = ",".join(f"{n}={val}" for n, val in pairs) or "_"
                    if isinstance(v, expo.ParsedHistogram):
                        cur = out["series"].get(key)
                        if cur is None:
                            out["series"][key] = {
                                "count": v.count, "sum": v.sum,
                                "bounds": list(v.bounds),
                                "buckets": list(v.counts)}
                        elif cur.get("bounds") == list(v.bounds):
                            cur["count"] += v.count
                            cur["sum"] += v.sum
                            cur["buckets"] = [
                                a + b for a, b in zip(cur["buckets"],
                                                      v.counts)]
                    elif fam.kind == "gauge":
                        cur = out["series"].get(key)
                        if cur is None:
                            out["series"][key] = {"max": v, "min": v}
                        else:
                            cur["max"] = max(cur["max"], v)
                            cur["min"] = min(cur["min"], v)
                    else:                  # counter / untyped: sum
                        out["series"][key] = \
                            out["series"].get(key, 0.0) + v
        return agg

    def snapshot(self) -> dict:
        """Machine-readable fleet state: per-endpoint status + latest
        per-endpoint snapshot + the fleet aggregate.

        This is the JSON artifact the load-generator benchmark dumps
        (:meth:`dump_json`) and CI uploads.
        """
        endpoints: dict[str, dict] = {}
        for name, url in self.endpoints.items():
            s = self.latest(name)
            endpoints[name] = {
                "url": url,
                "up": self.up(name),
                "scrapes": len(self.scrapes(name)),
                "last_ts": None if s is None else s.ts,
                "error": "" if s is None else s.error,
                "health": None if s is None else s.health,
                "metrics": (expo.to_snapshot(s.families)
                            if s is not None and s.ok else None),
            }
        return {"polls": self.polls,
                "up_fraction": self.up_fraction(),
                "endpoints": endpoints,
                "fleet": self.fleet_families()}

    def dump_json(self, path: str) -> str:
        """Write :meth:`snapshot` to ``path`` (atomic tmp + replace).

        :returns: the path written.
        """
        import os
        snap = self.snapshot()
        tmp = str(path) + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, str(path))
        return str(path)
