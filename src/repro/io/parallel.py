"""``repro.io.parallel`` — multi-part parallel TACZ writer + reader.

The single-file :class:`~repro.io.writer.TACZWriter` funnels every level
through one encoder thread — on multi-host AMR runs (AMRIC, Wang et al.
2023) the write path is the bottleneck, and PR 4's sharded *read* path
still had to gather all levels into one file before it could serve them.
This module is the write-side analogue of that sharding:

  * :class:`ParallelTACZWriter` — fans each level's sub-block stack out
    to N workers (threads or forked processes).  The sub-block partition
    is computed **once** (``repro.core.hybrid.partition_level``) and the
    ``(level, sub_block)`` keys are split by the same rendezvous hashing
    the serving-side :class:`~repro.serving.sharded.ShardMap` uses
    (``repro.io.placement``), so a deployment can align shard servers
    with part files.  Each worker compresses and streams *its* slice of
    every level into its own ``part-XXXX.tacz`` via the existing
    :class:`~repro.io.writer.TACZWriter`; :meth:`~ParallelTACZWriter.
    close` then publishes an atomic, CRC'd ``manifest.json``
    (``repro.io.manifest``) binding the parts into one logical snapshot.
    The batched compressor is per-brick independent, so every brick's
    codes — and therefore every decoded value — are bit-identical to the
    single-writer path regardless of the part count.
  * :class:`MultiPartReader` — presents the parts as one
    :class:`~repro.io.reader.TACZReader`: same ``read`` / ``read_roi`` /
    ``subblock_keys`` / ``level_signature`` surface over a merged index,
    with per-part files opened lazily and every payload (and its level's
    codebook/mask sections) read from the part that holds it — a shard
    aligned with its part never touches other parts' bytes.

Crash consistency: part files publish atomically (tmp + ``os.replace``)
and the manifest publishes last — a killed writer leaves
``part-*.tacz.tmp`` litter (``repro.io.manifest.stale_parts``) and the
previous snapshot (or nothing) intact; a re-run truncates the litter and
converges to a valid snapshot.

Use ``repro.io.open_snapshot`` to open either kind of snapshot, and
``write_multipart`` as the one-shot mirror of ``repro.io.write``.
"""
from __future__ import annotations

import copy
import dataclasses
import multiprocessing
import os
import queue
import sys
import threading

import numpy as np

from repro.core import entropy
from repro.core.amr import AMRDataset
from repro.core.blocks import extract_subblock
from repro.core.gsp import gsp_pad
from repro.core.hybrid import (AMRCompressionResult, LevelArtifacts,
                               LevelResult, compress_level, partition_level)
from repro.core.she import she_encode
from repro.obs import metrics as obsm

from . import frontier as frt
from . import manifest as mfst
from . import placement
from .reader import WHOLE_LEVEL, TACZReader
from .writer import TACZWriter, resolve_payload_codec

__all__ = ["MultiPartReader", "ParallelTACZWriter", "fork_safe",
           "write_multipart"]

#: Strategy names whose levels carry per-sub-block payloads (the key
#: universe is per-brick); everything else is a single whole-level payload.
_SHE_STRATEGY_NAMES = ("opst", "akdtree", "nast")

_ABORT = "__abort__"

_EMPTY_RECON = np.empty((0, 0, 0), dtype=np.float32)


def fork_safe() -> bool:
    """Whether process workers may fork this interpreter.

    Forking is the fast path (no re-import in the children); it becomes
    unsafe once XLA backends are *initialized* — their thread pools do
    not survive a fork.  A merely-imported jax is fine: spawn would
    otherwise re-import the whole stack per worker for no protection.
    """
    if "fork" not in multiprocessing.get_all_start_methods():
        return False
    if "jax" not in sys.modules:
        return True
    try:
        from jax._src import xla_bridge
        return not xla_bridge._backends
    except Exception:   # pragma: no cover - private-API drift: be safe
        return False


# --------------------------------------------------------------------------
# worker side
# --------------------------------------------------------------------------


def _unpack_mask(head: dict) -> np.ndarray:
    """Rebuild the level's bool mask from the packed task head."""
    shape = tuple(head["orig_shape"])
    packed = head["mask_packed"]
    if packed is None:
        return np.ones(shape, dtype=bool)
    bits = np.unpackbits(np.frombuffer(packed, dtype=np.uint8),
                         count=int(np.prod(shape)))
    return bits.astype(bool).reshape(shape)


def _task_to_level(task: dict) -> LevelResult:
    """Materialize one queued task into a packable :class:`LevelResult`.

    Task kinds:

      * ``"packed"`` — an already-compressed slice (shared codebook);
        pass through.
      * ``"she"`` — this part's bricks of one SHE level: run the batched
        SHE pipeline over just them (per-brick codes are bit-identical
        to the full-level run; the codebook is part-local).
      * ``"gsp"`` — the whole single-payload level (this part owns its
        ``WHOLE_LEVEL`` key): the stock ``compress_level`` path.
      * ``"stub"`` — this part owns nothing of the level: head + mask
        only, so every part records every level at the same index.
    """
    kind = task["kind"]
    if kind == "packed":
        return task["lr"]
    head = task["head"]
    mask = _unpack_mask(head)
    if kind == "stub":
        art = LevelArtifacts(mask=mask, orig_shape=tuple(head["orig_shape"]),
                             grid_shape=tuple(head["grid_shape"]),
                             unit=head["unit"], sz_block=head["sz_block"],
                             subblocks=[], results=[], codebook=None)
        return LevelResult(strategy=head["strategy"],
                           algorithm=head["algorithm"], she=False,
                           payload_bits=0, codebook_bits=0, meta_bits=0,
                           recon=_EMPTY_RECON, n_values=head["n_values"],
                           density=head["density"], eb=head["eb"],
                           ratio=head["ratio"], artifacts=art)
    if kind == "gsp":
        return compress_level(task["data"], mask, eb=head["eb"],
                              unit=head["unit"],
                              algorithm=head["algorithm"], she=False,
                              strategy="gsp", sz_block=head["sz_block"],
                              batched=head["batched"], ratio=head["ratio"],
                              keep_artifacts=True,
                              lorenzo_engine=head["lorenzo_engine"],
                              entropy_engine=head.get("entropy_engine",
                                                      "auto"))
    if kind == "she":
        enc = she_encode(task["bricks"], head["eb"],
                         block=head["sz_block"], shared=True,
                         batched=head["batched"],
                         lorenzo_engine=head["lorenzo_engine"],
                         entropy_engine=head.get("entropy_engine", "auto"))
        art = LevelArtifacts(mask=mask, orig_shape=tuple(head["orig_shape"]),
                             grid_shape=tuple(head["grid_shape"]),
                             unit=head["unit"], sz_block=head["sz_block"],
                             subblocks=list(task["subblocks"]),
                             results=enc.results, codebook=enc.codebook)
        return LevelResult(strategy=head["strategy"],
                           algorithm=head["algorithm"], she=True,
                           payload_bits=enc.payload_bits,
                           codebook_bits=enc.codebook_bits,
                           meta_bits=enc.meta_bits, recon=_EMPTY_RECON,
                           n_values=head["n_values"],
                           density=head["density"], eb=head["eb"],
                           n_subblocks=len(task["subblocks"]),
                           ratio=head["ratio"], artifacts=art)
    raise ValueError(f"unknown task kind {kind!r}")


def _part_worker(pi: int, part_path: str, payload_codec: str,
                 entropy_engine: str, task_q, result_q) -> None:
    """One part's worker loop (thread or process body).

    Streams tasks into this part's own :class:`TACZWriter` until the
    close sentinel (``None`` → publish the part, report its identity) or
    the abort sentinel (drop the tmp).  Any failure aborts the part and
    reports the error — the producer then never publishes a manifest.
    """
    w = None
    try:
        # background=False: this loop IS the dedicated worker — a second
        # encoder thread per part would only contend for the GIL
        w = TACZWriter(part_path, payload_codec=payload_codec,
                       entropy_engine=entropy_engine, background=False)
        while True:
            task = task_q.get()
            if task is None:
                break
            if isinstance(task, str) and task == _ABORT:
                w.abort()
                result_q.put(("aborted", pi, None, None, None))
                return
            w.add_compressed(_task_to_level(task))
        # two-phase commit, phase 1: finalize + fsync the tmp but do NOT
        # rename — the producer renames every part only once all of them
        # reported, so a failing sibling never leaves a previously
        # published snapshot half-replaced
        tmp = w.close(publish=False)
        # the obs summary rides the ok tuple: a forked worker's registry
        # dies with the process, so its stage totals go home this way
        result_q.put(("ok", pi, w.index_crc, os.path.getsize(tmp),
                      w.obs_summary()))
    except BaseException as exc:  # report, never hang the producer
        if w is not None:
            try:
                w.abort()
            except Exception:   # pragma: no cover - secondary failure
                pass
        try:
            result_q.put(("err", pi, f"{type(exc).__name__}: {exc}",
                          None, None))
        except Exception:       # pragma: no cover - broken pipe on crash
            pass


# --------------------------------------------------------------------------
# producer side
# --------------------------------------------------------------------------


class ParallelTACZWriter:
    """Streaming multi-part TACZ writer with N part workers.

    ``add_level(data, mask)`` partitions the level once, then hands each
    worker the bricks its part owns — compression, entropy coding, the
    lossless byte pass, and file I/O all run per part, concurrently.
    ``add_compressed(lr)`` skips the compression stage and fans out
    payload *slices* of an existing result (all parts then share the
    level's compress-time codebook, so part payload bytes are identical
    to the single-file container's).  ``close()`` publishes every part,
    then the manifest — the snapshot's atomic commit point.

    Levels are dispatched to every part in arrival order, so part files
    stay level-aligned (every part records every level, empty slices as
    head-plus-mask stubs).

    :param path: snapshot *directory* (created if missing); parts are
        ``part-0000.tacz`` ... inside it.
    :param parts: worker/part count (≥ 1).
    :param seed: rendezvous placement salt, recorded in the manifest —
        a :class:`~repro.serving.sharded.ShardMap` built from the
        manifest's ``partition`` config assigns each shard exactly one
        part's keys.
    :param mode: ``"thread"`` (portable default) or ``"process"``
        (forked workers — real CPU parallelism for the numpy/entropy
        stages, which hold the GIL too finely for threads to scale).
    :param eb: default absolute error bound for :meth:`add_level`.
    :param unit: finest-level unit-block edge (per-level units follow
        the ``compress_amr`` domain-tracking rule).
    :param algorithm: prediction algorithm (``"lor_reg"`` etc.).
    :param she: per-sub-block payloads (required for non-gsp levels).
    :param strategy: partitioning strategy override.
    :param sz_block: Lorenzo/regression block edge in cells.
    :param batched: run the batched SHE pipeline in workers.
    :param lorenzo_engine: ``"auto"``/``"numpy"``/``"pallas"`` —
        resolved once on the producer so forked workers never probe
        accelerator backends themselves.
    :param payload_codec: v2 lossless byte pass, as in ``TACZWriter``.
    :param entropy_engine: :mod:`repro.core.entropy` engine for the
        Huffman encode stage in workers (``"auto"``/``"numpy"``/
        ``"batched"``/``"pallas"``) — resolved once on the producer,
        like ``lorenzo_engine``; output bytes are engine-independent.
    :param queue_depth: per-part task queue bound (backpressure).
    :raises ValueError: on bad ``parts``/``mode``/``payload_codec``.
    :raises OSError: if the snapshot directory cannot be created.
    """

    def __init__(self, path, *, parts: int = 2, seed: int = 0,
                 mode: str = "thread", eb: float | None = None,
                 unit: int = 8, algorithm: str = "lor_reg",
                 she: bool = True, strategy: str | None = None,
                 sz_block: int = 6, batched: bool = True,
                 lorenzo_engine: str = "auto", payload_codec: str = "auto",
                 entropy_engine: str = "auto", queue_depth: int = 2):
        if parts < 1:
            raise ValueError("need at least one part")
        if mode not in ("thread", "process"):
            raise ValueError(f"unknown worker mode {mode!r}")
        resolve_payload_codec(payload_codec)   # fail fast on bad names
        entropy.check_engine_name(entropy_engine)
        self.path = os.fspath(path)
        self.parts = int(parts)
        self.seed = int(seed)
        self.mode = mode
        self._payload_codec = payload_codec
        self._defaults = dict(eb=eb, unit=unit, algorithm=algorithm, she=she,
                              strategy=strategy, sz_block=sz_block,
                              batched=batched, lorenzo_engine=lorenzo_engine,
                              entropy_engine=entropy_engine)
        self._part_ids = [mfst.part_stem(i) for i in range(self.parts)]
        self._frontier: frt.Frontier | None = None
        self._n_levels = 0
        self._subblocks_per_level: list[int] = []
        self._part_levels: list[list[list[int]]] = [[] for _ in
                                                    range(self.parts)]
        self._finalized = False
        self._aborted = False
        self._engine: str | None = None   # resolved lorenzo engine
        self._entropy_eng: str | None = None   # resolved entropy engine
        os.makedirs(self.path, exist_ok=True)

        # resolve once on the producer, before any worker forks: the
        # workers take the concrete engine name and never probe jax
        ent_eng = self._resolve_entropy_engine()
        depth = max(1, int(queue_depth))
        if mode == "process":
            # fork is the fast path; once XLA backends are live in this
            # process their thread pools make forking unsafe — fall back
            # to spawn (workers then re-import the stack at startup)
            ctx = multiprocessing.get_context(
                "fork" if fork_safe() else "spawn")
            self._result_q = ctx.Queue()
            self._task_qs = [ctx.Queue(maxsize=depth)
                             for _ in range(self.parts)]
            self._workers = [
                ctx.Process(target=_part_worker,
                            args=(pi, self._part_path(pi), payload_codec,
                                  ent_eng, self._task_qs[pi],
                                  self._result_q),
                            daemon=True)
                for pi in range(self.parts)]
        else:
            self._result_q = queue.Queue()
            self._task_qs = [queue.Queue(maxsize=depth)
                             for _ in range(self.parts)]
            self._workers = [
                threading.Thread(target=_part_worker,
                                 args=(pi, self._part_path(pi),
                                       payload_codec, ent_eng,
                                       self._task_qs[pi], self._result_q),
                                 daemon=True)
                for pi in range(self.parts)]
        self._results: dict[int, tuple] = {}
        #: per-part writer obs summaries, filled in by :meth:`close`
        #: (``{part_index: {levels, encode_seconds, pack_seconds,
        #: publish_seconds, bytes}}``)
        self.worker_obs: dict[int, dict] = {}
        for w in self._workers:
            w.start()

    # ------------------------------ plumbing -------------------------------

    def _part_path(self, pi: int) -> str:
        return os.path.join(self.path, mfst.part_name(pi))

    def _worker_alive(self, pi: int) -> bool:
        return self._workers[pi].is_alive()

    def _drain_results(self) -> None:
        while True:
            try:
                msg = self._result_q.get_nowait()
            except (queue.Empty, OSError, ValueError):
                return             # empty, or already released at shutdown
            self._results[msg[1]] = msg

    def _check_failures(self) -> None:
        self._drain_results()
        errs = [f"{mfst.part_name(pi)}: {msg[2]}"
                for pi, msg in sorted(self._results.items())
                if msg[0] == "err"]
        dead = [mfst.part_name(pi) for pi in range(self.parts)
                if not self._worker_alive(pi) and pi not in self._results]
        if dead:
            errs.append(f"worker(s) died without reporting: "
                        f"{', '.join(dead)}")
        if errs:
            raise RuntimeError("parallel TACZ write failed — manifest not "
                               "published: " + "; ".join(errs))

    def _dispatch(self, pi: int, task, check: bool = True) -> None:
        """Enqueue one task, never blocking forever on a dead worker.

        ``check=False`` (shutdown path) only watches worker ``pi`` — a
        sibling's failure must not keep this worker from receiving its
        close/abort sentinel.
        """
        q = self._task_qs[pi]
        while True:
            try:
                q.put(task, timeout=0.2)
                return
            except queue.Full:
                if check:
                    self._check_failures()
                if not self._worker_alive(pi):
                    raise RuntimeError(
                        f"part writer {mfst.part_name(pi)} died mid-stream")

    def _check_live(self) -> None:
        if self._finalized or self._aborted:
            raise ValueError("writer is closed")
        self._check_failures()

    def _resolve_engine(self) -> str:
        if self._engine is None:
            eng = self._defaults["lorenzo_engine"]
            if eng == "auto":
                # resolve once on the producer so workers never probe the
                # accelerator; don't *import* jax just to probe — pulling
                # it in before a fork is exactly the hazard this avoids
                if "jax" in sys.modules:
                    from repro.core.sz import _tpu_attached
                    eng = "pallas" if _tpu_attached() else "numpy"
                else:
                    eng = "numpy"
            self._engine = eng
        return self._engine

    def _resolve_entropy_engine(self) -> str:
        if self._entropy_eng is None:
            eng = self._defaults["entropy_engine"]
            if eng == "auto":
                # same fork-safety rule as _resolve_engine: probe the
                # accelerator only if jax is already imported; the
                # batched numpy engine is the universal fallback
                if "jax" in sys.modules:
                    from repro.core.sz import _tpu_attached
                    eng = "pallas" if _tpu_attached() else "batched"
                else:
                    eng = "batched"
            self._entropy_eng = eng
        return self._entropy_eng

    def _owners(self, li: int, keys: list[tuple[int, int]],
                ) -> list[list[int]]:
        """Per part: the sorted global sub-block indices it owns of level
        ``li`` (``[0]``/``[]`` for single-payload levels)."""
        by_part: list[list[int]] = [[] for _ in range(self.parts)]
        pos = {pid: pi for pi, pid in enumerate(self._part_ids)}
        for gsbi, key in keys:
            owner = placement.owner(self._part_ids, self.seed, key)
            by_part[pos[owner]].append(gsbi)
        return by_part

    def _record_level(self, n_subblocks: int,
                      by_part: list[list[int]]) -> None:
        self._n_levels += 1
        self._subblocks_per_level.append(int(n_subblocks))
        for pi in range(self.parts):
            self._part_levels[pi].append(by_part[pi])

    # ------------------------------ producer -------------------------------

    def add_level(self, data: np.ndarray, mask: np.ndarray | None = None, *,
                  eb: float | None = None, ratio: int = 1,
                  unit: int | None = None) -> None:
        """Partition one raw level and fan its bricks out to the workers.

        Semantics match :meth:`TACZWriter.add_level` (snapshot taken
        immediately, same eb/unit defaulting rule); only *where* the
        compression runs differs — each worker compresses the bricks its
        part owns, against the one partition computed here.

        :raises ValueError: if no error bound is available, or the
            configured strategy has no per-sub-block payloads
            (``she=False`` with a non-gsp strategy is not indexable).
        """
        self._check_live()
        d = self._defaults
        eb = d["eb"] if eb is None else eb
        if eb is None:
            raise ValueError("no error bound: pass eb= here or to the writer")
        if unit is None:
            unit = max(2, int(d["unit"]) // max(int(ratio), 1))
        data = np.array(data, dtype=np.float32, copy=True)
        mask = (data != 0) if mask is None else np.array(mask, dtype=bool,
                                                         copy=True)
        grid, strategy, density, subblocks = partition_level(
            data, mask, unit=unit, algorithm=d["algorithm"], she=d["she"],
            strategy=d["strategy"])
        if strategy != "gsp" and not (d["she"]
                                      and d["algorithm"] == "lor_reg"):
            raise ValueError(
                "the merged-4D non-SHE path is not indexable; compress "
                "with she=True (TAC+) or strategy='gsp'")
        li = self._n_levels
        if strategy == "gsp":
            _, ggrid = gsp_pad(data, mask, unit=unit)
            grid_shape = tuple(ggrid.data.shape)
        else:
            grid_shape = tuple(grid.data.shape)
        head = dict(strategy=strategy, algorithm=d["algorithm"],
                    eb=float(eb), ratio=int(ratio), unit=int(unit),
                    sz_block=int(d["sz_block"]),
                    orig_shape=tuple(data.shape), grid_shape=grid_shape,
                    density=float(density), n_values=int(mask.sum()),
                    batched=bool(d["batched"]),
                    lorenzo_engine=self._resolve_engine(),
                    entropy_engine=self._resolve_entropy_engine(),
                    mask_packed=(None if mask.all()
                                 else np.packbits(mask.ravel()).tobytes()))
        if strategy == "gsp":
            keys = [(0, (li, WHOLE_LEVEL))]
            by_part = self._owners(li, keys)
            for pi in range(self.parts):
                if by_part[pi]:
                    self._dispatch(pi, {"kind": "gsp", "head": head,
                                        "data": data})
                else:
                    self._dispatch(pi, {"kind": "stub", "head": head})
            self._record_level(1, by_part)
            return
        keys = [(i, (li, i)) for i in range(len(subblocks))]
        by_part = self._owners(li, keys)
        for pi in range(self.parts):
            idxs = by_part[pi]
            if not idxs:
                self._dispatch(pi, {"kind": "stub", "head": head})
                continue
            owned = [subblocks[i] for i in idxs]
            bricks = [np.ascontiguousarray(extract_subblock(grid, sb))
                      for sb in owned]
            self._dispatch(pi, {"kind": "she", "head": head,
                                "subblocks": owned, "bricks": bricks})
        self._record_level(len(subblocks), by_part)

    def add_compressed(self, lr: LevelResult) -> None:
        """Fan an already-compressed level's payload slices out to the
        parts (shared codebook — part payload bytes equal the single-file
        container's, so ``level_signature`` matches it too).

        :raises ValueError: if ``lr`` has no serialization artifacts.
        """
        self._check_live()
        art = lr.artifacts
        if art is None:
            raise ValueError(
                "LevelResult has no serialization artifacts — the merged-4D "
                "non-SHE path is not indexable (compress with she=True or "
                "strategy='gsp'), and compression must run with "
                "keep_artifacts=True")
        li = self._n_levels
        if lr.strategy in _SHE_STRATEGY_NAMES and art.subblocks:
            n = len(art.subblocks)
            keys = [(i, (li, i)) for i in range(n)]
        else:
            n = 1
            keys = [(0, (li, WHOLE_LEVEL))]
        by_part = self._owners(li, keys)
        for pi in range(self.parts):
            self._dispatch(pi, {"kind": "packed",
                                "lr": _slice_level(lr, by_part[pi])})
        self._record_level(n, by_part)

    def set_frontier(self, frontier: frt.Frontier | None) -> None:
        """Attach a rate–distortion frontier to the snapshot.  It is
        recorded under the manifest's optional ``"frontier"`` key (the
        manifest CRC covers it) — the multi-part mirror of
        :meth:`TACZWriter.set_frontier`."""
        self._check_live()
        self._frontier = frontier

    # ------------------------------ lifecycle ------------------------------

    def close(self) -> str:
        """Publish every part, then the manifest (the commit point).

        Two-phase: workers only *finalize* their tmp files; the renames
        into place happen here, after every worker reported success —
        followed by the manifest.  A worker failure at any point before
        the rename loop therefore leaves a previously published
        snapshot in the same directory fully intact (its tmps become
        stale litter a re-run truncates).

        :returns: the snapshot directory path.
        :raises RuntimeError: if any part worker failed or was killed —
            the manifest is then *not* published and no part file is
            replaced.
        """
        if self._finalized:
            return self.path
        if self._aborted:
            raise ValueError("writer was aborted")
        # a worker already known dead/failed must not let the others
        # finalize; abort them instead
        self._drain_results()
        healthy = all(self._worker_alive(pi) or self._results.get(
            pi, ("",))[0] == "ok" for pi in range(self.parts))
        self._shutdown(None if healthy else _ABORT)
        self._check_failures()
        missing = [mfst.part_name(pi) for pi in range(self.parts)
                   if self._results.get(pi, ("",))[0] != "ok"]
        if missing:
            raise RuntimeError(
                "parallel TACZ write failed — manifest not published: no "
                "result from " + ", ".join(missing))
        # phase 2: every part finalized — rename them all into place
        for pi in range(self.parts):
            final = self._part_path(pi)
            os.replace(final + ".tmp", final)
        parts = []
        for pi in range(self.parts):
            _, _, index_crc, size, obs_sum = self._results[pi]
            parts.append({"name": mfst.part_name(pi), "size": int(size),
                          "index_crc": int(index_crc) & 0xFFFFFFFF,
                          "levels": self._part_levels[pi]})
            self.worker_obs[pi] = obs_sum or {}
            if self.mode == "process" and obs_sum:
                # thread-mode workers already recorded into this process's
                # registry; forked workers recorded into their own, so
                # fold the reported totals in here (one observation per
                # part and stage — totals are exact, bucket shapes are
                # per-part aggregates)
                for stage in ("encode", "pack", "publish"):
                    sec = obs_sum.get(f"{stage}_seconds", 0.0)
                    if sec:
                        obsm.WRITER_LEVEL_SECONDS.labels(stage).observe(sec)
                obsm.WRITER_LEVELS.inc(obs_sum.get("levels", 0))
                obsm.WRITER_BYTES.inc(obs_sum.get("bytes", 0))
        body = {"magic": mfst.MANIFEST_MAGIC,
                "version": mfst.MANIFEST_VERSION,
                "n_levels": self._n_levels,
                "subblocks": self._subblocks_per_level,
                "partition": {"algorithm": placement.ALGORITHM,
                              "seed": self.seed,
                              "shards": list(self._part_ids)},
                "parts": parts}
        if self._frontier is not None:
            body["frontier"] = self._frontier.to_dict()
        mfst.write_atomic(self.path, body)
        self._clean_stale({p["name"] for p in parts})
        self._finalized = True
        return self.path

    def abort(self) -> None:
        """Drop every part's tmp file; never publishes a manifest."""
        if self._finalized or self._aborted:
            self._aborted = True
            return
        self._aborted = True
        self._shutdown(_ABORT)

    def _shutdown(self, sentinel) -> None:
        for pi in range(self.parts):
            if self._worker_alive(pi):
                try:
                    self._dispatch(pi, sentinel, check=False)
                except RuntimeError:   # died while we queued — close() sees it
                    pass
        for w in self._workers:
            w.join()
        self._drain_results()
        if self.mode == "process":
            # a dead worker leaves its queue's feeder thread blocked on a
            # full pipe; cancel it or interpreter exit hangs on join
            for q in self._task_qs:
                q.close()
                q.cancel_join_thread()
            self._result_q.close()
            self._result_q.cancel_join_thread()

    def _clean_stale(self, keep: set) -> None:
        """After a successful publish: drop tmp litter and part files the
        new manifest no longer references (e.g. a re-publish with fewer
        parts)."""
        for name in mfst.stale_parts(self.path):
            try:
                os.remove(os.path.join(self.path, name))
            except OSError:     # pragma: no cover - already gone
                pass
        for name in os.listdir(self.path):
            if (name not in keep and name.endswith(".tacz")
                    and mfst._PART_RE.match(name)):
                try:
                    os.remove(os.path.join(self.path, name))
                except OSError:  # pragma: no cover - already gone
                    pass

    def __enter__(self) -> "ParallelTACZWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


def _slice_level(lr: LevelResult, idxs: list[int]) -> LevelResult:
    """A shallow per-part copy of ``lr`` holding only the payloads in
    ``idxs`` (global sub-block indices; ``[0]`` keeps a single-payload
    level, ``[]`` makes a stub).  The codebook, mask, and head fields
    are shared — and the recon is dropped (workers never need it)."""
    art = lr.artifacts
    a2 = copy.copy(art)
    if art.subblocks:
        a2.subblocks = [art.subblocks[i] for i in idxs]
        a2.results = [art.results[i] for i in idxs]
    elif not idxs:
        a2.subblocks, a2.results, a2.codebook = [], [], None
    lr2 = copy.copy(lr)
    lr2.artifacts = a2
    lr2.recon = _EMPTY_RECON
    return lr2


def write_multipart(path, obj, *, parts: int = 2, seed: int = 0,
                    mode: str = "thread", eb=None,
                    frontier: frt.Frontier | None = None, **kwargs) -> str:
    """One-shot multi-part mirror of :func:`repro.io.write`.

    ``obj`` may be an :class:`AMRCompressionResult` (payload slices fan
    out; compression already happened) or an :class:`AMRDataset` (each
    part worker compresses its own slice of every level; ``eb``
    required, scalar or per-level).  ``frontier`` attaches an optional
    rate–distortion frontier to the manifest.

    :returns: the snapshot directory path.
    """
    if isinstance(obj, AMRCompressionResult):
        with ParallelTACZWriter(path, parts=parts, seed=seed, mode=mode,
                                **kwargs) as w:
            for lr in obj.levels:
                w.add_compressed(lr)
            if frontier is not None:
                w.set_frontier(frontier)
        return w.path
    if isinstance(obj, AMRDataset):
        if eb is None:
            raise ValueError("writing a raw AMRDataset needs eb=")
        ebs = eb if isinstance(eb, (list, tuple)) else [eb] * obj.n_levels
        if len(ebs) != obj.n_levels:
            raise ValueError("need one error bound per level")
        with ParallelTACZWriter(path, parts=parts, seed=seed, mode=mode,
                                **kwargs) as w:
            for lvl, e in zip(obj.levels, ebs):
                w.add_level(lvl.data, lvl.mask, eb=float(e), ratio=lvl.ratio)
            if frontier is not None:
                w.set_frontier(frontier)
        return w.path
    raise TypeError(f"cannot write {type(obj).__name__} as multi-part TACZ")


# --------------------------------------------------------------------------
# reader side
# --------------------------------------------------------------------------


class MultiPartReader(TACZReader):
    """One logical :class:`TACZReader` over a multi-part snapshot.

    The constructor validates the manifest (magic, version, body CRC),
    parses every part's CRC'd index, checks it against the manifest's
    recorded ``index_crc``, and merges the per-part sub-block records
    into one index at their manifest-recorded *global* positions — so
    the merged key universe (``subblock_keys``), geometry, and
    ``level_signature`` behave exactly like the single-file reader's.

    Part *files* are then opened lazily: a payload decode opens only the
    part that holds the payload, and a level's codebook/mask sections
    are read from an already-open part (every part duplicates them).  A
    shard server aligned with its part therefore never opens — let alone
    reads — other parts (see :attr:`open_parts`).

    ``index_crc`` is the manifest CRC: the generation identity the
    serving layer's hot-swap checks compare (``probe_index_crc`` returns
    the same value for the directory).

    :param src: snapshot directory or its ``manifest.json`` path.
    :param entropy_engine: :mod:`repro.core.entropy` engine each part
        reader decodes Huffman payloads with (all engines bit-identical).
    :raises ValueError: on a missing/corrupt manifest, a part whose
        bytes do not match the manifest (stale or torn republish), or
        inconsistent level heads across parts.
    :raises OSError: if the manifest or a part cannot be read.
    """

    def __init__(self, src, *, entropy_engine: str = "auto"):
        entropy.check_engine_name(entropy_engine)
        self._entropy_engine = entropy_engine
        src = os.fspath(src)
        self._dir = (os.path.dirname(src)
                     if os.path.basename(src) == mfst.MANIFEST_NAME
                     else src)
        self.manifest = mfst.load(src)
        self.index_crc = int(self.manifest["crc32"]) & 0xFFFFFFFF
        # the manifest's optional frontier mirrors the single-file TACF
        # section; a malformed body degrades to None, never a raise
        self.frontier: frt.Frontier | None = None
        self.frontier_error: str | None = None
        if "frontier" in self.manifest:
            try:
                self.frontier = frt.Frontier.from_dict(
                    self.manifest["frontier"])
            except (ValueError, KeyError, TypeError) as exc:
                self.frontier_error = str(exc)
        self._part_names = mfst.referenced_parts(self.manifest)
        if not self._part_names:
            raise ValueError("multi-part manifest references no parts")
        n_levels = int(self.manifest["n_levels"])
        counts = [int(c) for c in self.manifest["subblocks"]]
        if len(counts) != n_levels:
            raise ValueError("corrupt manifest: level count mismatch")

        part_levels, versions = [], []
        for p in self.manifest["parts"]:
            rd = TACZReader(os.path.join(self._dir, p["name"]))
            try:
                if rd.index_crc != (int(p["index_crc"]) & 0xFFFFFFFF):
                    raise ValueError(
                        f"part {p['name']} does not match the manifest "
                        f"(index CRC mismatch — torn or stale republish)")
                if len(rd.levels) != n_levels:
                    raise ValueError(
                        f"part {p['name']} holds {len(rd.levels)} levels, "
                        f"manifest says {n_levels}")
                part_levels.append(rd.levels)
                versions.append(rd.version)
            finally:
                rd.close()
        self.version = max(versions)

        self.levels = []
        self._where: dict[tuple[int, int], tuple[int, int]] = {}
        self._sbmap: dict[int, tuple[int, int]] = {}
        self._home: list[int] = []
        for li in range(n_levels):
            heads = [self._head_key(pl[li]) for pl in part_levels]
            if len(set(heads)) != 1:
                raise ValueError(
                    f"parts disagree on level {li}'s head — not slices of "
                    f"one snapshot")
            slots: list = [None] * counts[li]
            per_part_n = []
            for pi, pl in enumerate(part_levels):
                idxs = self.manifest["parts"][pi]["levels"][li]
                e = pl[li]
                if len(idxs) != len(e.subblocks):
                    raise ValueError(
                        f"part {self._part_names[pi]} level {li}: manifest "
                        f"lists {len(idxs)} payloads, index has "
                        f"{len(e.subblocks)}")
                per_part_n.append(len(idxs))
                for lsbi, gsbi in enumerate(idxs):
                    gsbi = int(gsbi)
                    if not 0 <= gsbi < counts[li] or slots[gsbi] is not None:
                        raise ValueError(
                            f"corrupt manifest: level {li} sub-block "
                            f"{gsbi} out of range or claimed twice")
                    sb = e.subblocks[lsbi]
                    slots[gsbi] = sb
                    self._where[(li, gsbi)] = (pi, lsbi)
                    self._sbmap[id(sb)] = (pi, lsbi)
            if any(s is None for s in slots):
                raise ValueError(
                    f"corrupt manifest: level {li} has unclaimed sub-blocks")
            home = max(range(len(part_levels)),
                       key=lambda pi: (per_part_n[pi], -pi))
            self._home.append(home)
            self.levels.append(dataclasses.replace(part_levels[home][li],
                                                   subblocks=slots))
        # base-class state the inherited read surface expects
        self._codebooks = {}
        self._masks = {}
        self._io_lock = threading.Lock()
        self._parts: list[TACZReader | None] = [None] * len(self._part_names)
        self._parts_lock = threading.Lock()

    @staticmethod
    def _head_key(e) -> tuple:
        return (e.shape, e.grid_shape, e.strategy, e.algorithm, e.unit,
                e.sz_block, e.ratio, e.eb, e.n_values, e.payload_compressor)

    # ------------------------------ plumbing -------------------------------

    @property
    def n_parts(self) -> int:
        """Number of part files the manifest binds."""
        return len(self._part_names)

    @property
    def part_names(self) -> list[str]:
        """Part file names, in part order."""
        return list(self._part_names)

    @property
    def partition(self) -> dict:
        """The manifest's placement config — feed it to
        ``ShardMap.from_dict`` to align shard servers with parts."""
        return dict(self.manifest["partition"])

    @property
    def open_parts(self) -> list[int]:
        """Indices of the parts whose files are currently open — the
        observable form of the locality guarantee (a part-aligned shard
        serving only its own keys opens only its own part)."""
        with self._parts_lock:
            return [pi for pi, rd in enumerate(self._parts)
                    if rd is not None]

    def _part(self, pi: int) -> TACZReader:
        with self._parts_lock:
            rd = self._parts[pi]
            if rd is None:
                p = self.manifest["parts"][pi]
                rd = TACZReader(os.path.join(self._dir, p["name"]),
                                entropy_engine=self._entropy_engine)
                if rd.index_crc != (int(p["index_crc"]) & 0xFFFFFFFF):
                    rd.close()
                    raise ValueError(
                        f"part {p['name']} changed under the reader "
                        f"(index CRC mismatch)")
                self._parts[pi] = rd
            return rd

    def _meta_part(self, li: int) -> int:
        """Part to read level ``li``'s *mask* section from: any
        already-open part (every part stores an identical copy of the
        mask — stubs included), else the level's home part (the one
        holding most of its payloads).  Codebooks are NOT interchangeable
        this way: they are part-local for worker-compressed snapshots
        and absent from stub parts, which is why payload decode always
        delegates whole into the owning part."""
        with self._parts_lock:
            for pi, rd in enumerate(self._parts):
                if rd is not None:
                    return pi
        return self._home[li]

    def close(self) -> None:
        """Close every opened part file."""
        with self._parts_lock:
            for rd in self._parts:
                if rd is not None:
                    rd.close()
            self._parts = [None] * len(self._part_names)

    def _read_at(self, off: int, length: int) -> bytes:
        raise ValueError("MultiPartReader has no single backing file — "
                         "reads go through its parts")

    # ------------------------------ decoding -------------------------------

    def _codebook(self, li: int):
        # codebooks are part-local (each worker-compressed part built its
        # own over its own bricks; stub parts have none) — a merged-level
        # codebook is meaningless, so decode must go through the owning
        # part (subblock_codes/_decode_subblock delegate whole)
        raise ValueError(
            "multi-part codebooks are per part — decode sub-blocks via "
            "subblock_codes()/read_*, which route into the owning part")

    def _mask(self, li: int):
        if li not in self._masks:
            self._masks[li] = self._part(self._meta_part(li))._mask(li)
        return self._masks[li]

    def _decode_subblock(self, li: int, sb, shape, limit=None):
        pi, lsbi = self._sbmap[id(sb)]
        part = self._part(pi)
        return part._decode_subblock(li, part.levels[li].subblocks[lsbi],
                                     shape, limit=limit)

    def subblock_codes(self, li: int, sbi: int, limit: int | None = None):
        """(codes, betas) of global sub-block ``sbi`` — decoded from the
        part that owns it (see :meth:`TACZReader.subblock_codes`)."""
        pi, lsbi = self._where[(li, int(sbi))]
        return self._part(pi).subblock_codes(li, lsbi, limit)

    def decode_subblocks(self, li: int, sbis, limits=None):
        """Batched :meth:`subblock_codes` over global indices: the batch
        is split by owning part, each part decodes its slice in one
        ``EntropyEngine`` launch, and results return in input order."""
        sbis = [int(s) for s in sbis]
        by_part: dict[int, list[int]] = {}
        for pos, sbi in enumerate(sbis):
            pi, _ = self._where[(li, sbi)]
            by_part.setdefault(pi, []).append(pos)
        out: list = [None] * len(sbis)
        for pi, positions in by_part.items():
            local = [self._where[(li, sbis[p])][1] for p in positions]
            lims = (None if limits is None
                    else [limits[p] for p in positions])
            for p, pair in zip(positions,
                               self._part(pi).decode_subblocks(
                                   li, local, lims)):
                out[p] = pair
        return out

    def verify(self) -> bool:
        """Verify every part's sections and payloads (each part's index
        CRC was already checked against the manifest at open).

        :returns: True when every stored byte range checks out.
        :raises IOError: at the first corrupt byte range.
        """
        for pi in range(self.n_parts):
            self._part(pi).verify()
        return True
