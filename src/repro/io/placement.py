"""Rendezvous (highest-random-weight) placement of sub-block keys.

The single hash rule that both sides of the multi-host pipeline share:

  * the **write side** (``repro.io.parallel``) partitions each level's
    ``(level, sub_block)`` keys over the part files of a multi-part
    snapshot, and
  * the **serving side** (``repro.serving.sharded.ShardMap``) places the
    same keys onto shard servers.

Keeping the scoring function here — below both of them — is what lets a
deployment align shards with parts: a ``ShardMap`` built from a
multi-part manifest's ``partition`` config owns exactly the keys its
part file holds, so a shard never needs another part's payload bytes.

Every key scores each shard with a keyed 64-bit BLAKE2b of
``(seed, level, sub_block, shard_id)`` and is owned by the highest
score (ties broken by shard id).  The scheme is a pure function of
``(shards, seed, key)``: independent of shard-list order, process,
platform, and ``PYTHONHASHSEED``, and minimal under resizing (adding a
shard only moves keys onto it; removing one only moves the keys it
owned).
"""
from __future__ import annotations

import hashlib
import struct

__all__ = ["ALGORITHM", "owner", "score"]

#: Config-file identifier of this placement scheme.  Serialized shard
#: maps and multi-part manifests both record it; loaders must reject any
#: other value instead of silently placing keys elsewhere.
ALGORITHM = "rendezvous-blake2b64"


def score(seed: int, key: tuple[int, int], shard: str) -> int:
    """HRW score of ``shard`` for one ``(level, sub_block)`` key.

    :param seed: placement salt; changing it reshuffles every key.
    :param key: ``(level_index, sub_block_index)`` —
        ``repro.io.reader.WHOLE_LEVEL`` (-1) for single-payload levels.
    :param shard: shard (or part) identifier.
    :returns: an unsigned 64-bit score.
    """
    h = hashlib.blake2b(digest_size=8)
    h.update(struct.pack("<qqq", int(seed), int(key[0]), int(key[1])))
    h.update(shard.encode("utf-8"))
    return int.from_bytes(h.digest(), "little")


def owner(shards, seed: int, key: tuple[int, int]) -> str:
    """The highest-scoring shard for ``key`` (ties broken by shard id).

    :param shards: candidate shard identifiers (non-empty).
    :param seed: placement salt.
    :param key: ``(level_index, sub_block_index)``.
    :returns: the owning shard id.
    """
    return max(shards, key=lambda s: (score(seed, key, s), s))
