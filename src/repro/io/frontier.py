"""Rate–distortion frontiers: data model, distortion-target grammar, and
the optional ``TACF`` container section (ISSUE 9).

The autotuner (``repro.tuning``) searches per-level error bounds against
an application metric and records the resulting *frontier* — the list of
``(per-level eb vector, encoded bits, metric values)`` points it probed,
Pareto-pruned — so a serving layer can answer distortion-target requests
("the cheapest snapshot with ``psnr >= 60``") without re-measuring
anything.  This module owns the three pieces every layer shares:

  * :class:`FrontierPoint` / :class:`Frontier` — the data model and its
    canonical JSON form (the byte form both CRC schemes cover).
  * :func:`parse_target` / :class:`Target` — the distortion-target
    grammar (``metric{>=,<=,>,<}value``, e.g. ``"psnr>=60"``) and the
    cheapest-satisfying-point selection rule, including which direction
    each metric improves in (:data:`HIGHER_IS_BETTER`).
  * :func:`pack_section` / :func:`parse_section` — the framed ``TACF``
    byte section a single-file ``.tacz`` carries *between* its index and
    footer.  The footer locates only the index, so v1/v2 readers that
    predate the section skip it without noticing; new readers parse the
    gap and degrade to ``frontier = None`` on any corruption (the
    serving layer then falls back to the default variant and counts it).

Multi-part snapshots store the same ``Frontier.to_dict()`` body under
the manifest's optional ``"frontier"`` key instead — the manifest CRC
already covers it.  Byte-level spec: ``docs/tuning.md`` (cross-checked
by ``tests/test_docs.py``).
"""
from __future__ import annotations

import json
import re
import zlib
from dataclasses import dataclass, field
from struct import Struct

__all__ = ["FRONTIER_MAGIC", "FRONTIER_VERSION", "Frontier",
           "FrontierPoint", "HIGHER_IS_BETTER", "SECTION_HEAD_SIZE",
           "Target", "TargetUnsatisfiable", "pack_section",
           "parse_section", "parse_target"]

FRONTIER_MAGIC = b"TACF"
FRONTIER_VERSION = 1

#: Section framing: magic, version (u16), flags (u16, reserved), body
#: length (u32), body CRC32 (u32); the body is canonical JSON
#: (sorted keys, ``(",", ":")`` separators, UTF-8).
_SECTION_HEAD = Struct("<4sHHII")
SECTION_HEAD_SIZE = _SECTION_HEAD.size

#: Improvement direction per metric name: ``True`` → larger is better
#: (PSNR-style), ``False`` → smaller is better (error-style metrics).
#: ``psnr`` is over the stored AMR values (Metric 2); ``psnr_u`` is over
#: the uniform-resolution reconstruction — the post-analysis field where
#: coarse-level errors are amplified by upsampling, i.e. where per-level
#: tuning pays (paper §IV-F).  The selection rule and the autotuner both
#: consult this map; unknown metric names are rejected by
#: :func:`parse_target`.
HIGHER_IS_BETTER = {"psnr": True, "psnr_u": True, "max_abs_error": False,
                    "ps_error": False}

_OPS = {">=": lambda a, b: a >= b, "<=": lambda a, b: a <= b,
        ">": lambda a, b: a > b, "<": lambda a, b: a < b}

_TARGET_RE = re.compile(r"^\s*([a-z_][a-z_0-9]*)\s*(>=|<=|>|<)\s*"
                        r"([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)\s*$")


class TargetUnsatisfiable(ValueError):
    """No frontier point / variant satisfies the requested target.

    Serving layers map this to a clean HTTP 400 whose body names the
    target and the best value actually achievable (:attr:`best`).
    """

    def __init__(self, target: "Target", best: float | None = None):
        self.target = target
        self.best = best
        msg = f"no variant satisfies {target}"
        if best is not None:
            msg += f" (best available {target.metric}={best:g})"
        super().__init__(msg)


@dataclass(frozen=True)
class Target:
    """A parsed distortion target, e.g. ``psnr >= 60``."""

    metric: str
    op: str
    value: float

    def __str__(self) -> str:
        return f"{self.metric}{self.op}{self.value:g}"

    def satisfies(self, metrics: dict) -> bool:
        """Whether a point's measured ``metrics`` meet this target (a
        point that never measured :attr:`metric` does not)."""
        got = metrics.get(self.metric)
        if got is None:
            return False
        return _OPS[self.op](float(got), self.value)


def parse_target(spec: str) -> Target:
    """Parse ``"metric{>=,<=,>,<}value"`` (e.g. ``"psnr>=60"``).

    :raises ValueError: on a malformed spec or an unknown metric name.
    """
    m = _TARGET_RE.match(str(spec))
    if not m:
        raise ValueError(
            f"bad distortion target {spec!r} (want metric>=value, e.g. "
            f"'psnr>=60'; ops: >=, <=, >, <)")
    metric, op, value = m.group(1), m.group(2), float(m.group(3))
    if metric not in HIGHER_IS_BETTER:
        raise ValueError(
            f"unknown target metric {metric!r} (known: "
            f"{', '.join(sorted(HIGHER_IS_BETTER))})")
    return Target(metric=metric, op=op, value=value)


@dataclass
class FrontierPoint:
    """One rate–distortion point: a per-level eb vector, the encoded
    size it produced, and the application metrics measured from the
    decoded snapshot."""

    ebs: tuple[float, ...]          # per-level error bounds, finest first
    bits: int                       # total encoded bits at these ebs
    metrics: dict                   # {"psnr": ..., "max_abs_error": ...}

    def to_dict(self) -> dict:
        return {"ebs": [float(e) for e in self.ebs],
                "bits": int(self.bits),
                "metrics": {str(k): float(v)
                            for k, v in sorted(self.metrics.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "FrontierPoint":
        return cls(ebs=tuple(float(e) for e in d["ebs"]),
                   bits=int(d["bits"]),
                   metrics={str(k): float(v)
                            for k, v in d["metrics"].items()})


@dataclass
class Frontier:
    """A recorded rate–distortion frontier.

    ``points`` are sorted by increasing ``bits``; ``default`` indexes
    the point the snapshot was actually written at (the one served when
    no distortion target is given).
    """

    metric: str                      # the metric the tuner optimized for
    points: list[FrontierPoint] = field(default_factory=list)
    default: int = 0

    def to_dict(self) -> dict:
        return {"magic": FRONTIER_MAGIC.decode(),
                "version": FRONTIER_VERSION,
                "metric": str(self.metric),
                "default": int(self.default),
                "points": [p.to_dict() for p in self.points]}

    @classmethod
    def from_dict(cls, d: dict) -> "Frontier":
        if d.get("magic") != FRONTIER_MAGIC.decode():
            raise ValueError("not a TACZ frontier body")
        if int(d.get("version", 0)) > FRONTIER_VERSION:
            raise ValueError(
                f"unsupported frontier version {d.get('version')}")
        points = [FrontierPoint.from_dict(p) for p in d.get("points", [])]
        default = int(d.get("default", 0))
        if points and not 0 <= default < len(points):
            raise ValueError("frontier default index out of range")
        return cls(metric=str(d.get("metric", "")), points=points,
                   default=default)

    @property
    def default_point(self) -> FrontierPoint | None:
        """The point the snapshot was written at, if any."""
        if not self.points:
            return None
        return self.points[self.default]

    def best_value(self, metric: str) -> float | None:
        """The best value of ``metric`` any point achieves (direction
        per :data:`HIGHER_IS_BETTER`), or None if never measured."""
        vals = [p.metrics[metric] for p in self.points
                if metric in p.metrics]
        if not vals:
            return None
        return max(vals) if HIGHER_IS_BETTER.get(metric, False) \
            else min(vals)

    def select(self, target: Target | str) -> FrontierPoint:
        """The cheapest (fewest bits) point satisfying ``target``.

        :raises TargetUnsatisfiable: when no point qualifies.
        """
        if isinstance(target, str):
            target = parse_target(target)
        ok = [p for p in self.points if target.satisfies(p.metrics)]
        if not ok:
            raise TargetUnsatisfiable(target, self.best_value(target.metric))
        return min(ok, key=lambda p: p.bits)


# ------------------------------ wire section -------------------------------


def pack_section(frontier: Frontier) -> bytes:
    """Frame a frontier as the ``TACF`` byte section (head + canonical
    JSON body, body CRC32 in the head)."""
    body = json.dumps(frontier.to_dict(), sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    head = _SECTION_HEAD.pack(FRONTIER_MAGIC, FRONTIER_VERSION, 0,
                              len(body), zlib.crc32(body) & 0xFFFFFFFF)
    return head + body


def parse_section(buf: bytes) -> Frontier:
    """Parse a ``TACF`` section (as written by :func:`pack_section`).

    :param buf: the bytes between index end and footer start; trailing
        bytes beyond the framed body are rejected.
    :raises ValueError: on bad magic, an unsupported version, a length
        mismatch, a body CRC mismatch, or a malformed body.
    """
    if len(buf) < SECTION_HEAD_SIZE:
        raise ValueError("frontier section truncated (no head)")
    magic, version, _flags, body_len, body_crc = _SECTION_HEAD.unpack(
        buf[:SECTION_HEAD_SIZE])
    if magic != FRONTIER_MAGIC:
        raise ValueError("bad frontier section magic")
    if version > FRONTIER_VERSION:
        raise ValueError(f"unsupported frontier section version {version}")
    body = buf[SECTION_HEAD_SIZE:SECTION_HEAD_SIZE + body_len]
    if len(body) != body_len or len(buf) != SECTION_HEAD_SIZE + body_len:
        raise ValueError("frontier section truncated or oversized")
    if zlib.crc32(body) & 0xFFFFFFFF != body_crc:
        raise ValueError("frontier section body CRC mismatch")
    try:
        d = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise ValueError(f"malformed frontier body: {exc}") from exc
    return Frontier.from_dict(d)
