"""Multi-variant TACZ snapshot sets: catalog framing and selection.

A *variant set* is a directory holding several eb-variant snapshots of
the same dataset under one catalog::

    snap.taczv/
      variants.json          (published last, atomically — commit point)
      default.tacz           (one snapshot per variant; single-file or
      psnr60.tacz             multi-part directories both work)
      ...

Each variant entry records the snapshot file name, the per-level eb
vector it was compressed at, its encoded bits, and the application
metrics measured from its decoded form — i.e. one
:class:`repro.io.frontier.FrontierPoint` per variant, plus a name and a
file.  A distortion-target request (``"psnr>=60"``) selects the
cheapest variant whose recorded metrics satisfy the target; no target
selects the catalog's ``default``.

The catalog reuses the manifest's canonical-JSON CRC scheme
(``repro.io.manifest.manifest_crc``): magic ``"TACZV"``, version,
``crc32`` over the sorted-key JSON body sans the ``crc32`` key.  The
autotuner's :func:`repro.tuning.write_variant_set` is the writer;
:class:`repro.serving.variants.VariantServer` is the serving consumer.
Spec: ``docs/tuning.md`` (cross-checked by ``tests/test_docs.py``).
"""
from __future__ import annotations

import json
import os

from . import manifest as mfst
from .frontier import Target, TargetUnsatisfiable, parse_target

__all__ = ["VARIANTS_MAGIC", "VARIANTS_NAME", "VARIANTS_VERSION",
           "is_variant_set", "load_catalog", "select_variant",
           "variant_names", "write_catalog"]

VARIANTS_NAME = "variants.json"
VARIANTS_MAGIC = "TACZV"
VARIANTS_VERSION = 1


def _catalog_path(path: str) -> str:
    if os.path.basename(path) == VARIANTS_NAME:
        return path
    return os.path.join(path, VARIANTS_NAME)


def is_variant_set(path) -> bool:
    """True when ``path`` is a variant-set directory (or its catalog
    file) — the dispatch test ``repro.serving.serve`` uses."""
    if not isinstance(path, (str, os.PathLike)):
        return False
    path = os.fspath(path)
    if os.path.isdir(path):
        return os.path.exists(os.path.join(path, VARIANTS_NAME))
    return os.path.basename(path) == VARIANTS_NAME and os.path.exists(path)


def write_catalog(set_dir: str, body: dict) -> str:
    """Stamp magic/version/``crc32`` into ``body`` and publish the
    catalog atomically (tmp + fsync + ``os.replace``).

    :param set_dir: the variant-set directory (must exist).
    :param body: catalog body with ``default`` and ``variants`` keys;
        ``magic``/``version``/``crc32`` are overwritten.
    :returns: the catalog path.
    """
    body = dict(body)
    body["magic"] = VARIANTS_MAGIC
    body["version"] = VARIANTS_VERSION
    body.pop("crc32", None)
    body["crc32"] = mfst.manifest_crc(body)
    path = _catalog_path(set_dir)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(body, f, sort_keys=True, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_catalog(path: str) -> dict:
    """Read and validate a variant catalog (magic, version, CRC, and
    the structural minimum: a non-empty ``variants`` list whose
    ``default`` entry exists).

    :param path: variant-set directory or catalog file path.
    :raises ValueError: on bad magic, an unsupported version, a CRC
        mismatch, malformed JSON, or a missing default variant.
    :raises OSError: if the file cannot be read.
    """
    cpath = _catalog_path(os.fspath(path))
    with open(cpath, encoding="utf-8") as f:
        try:
            body = json.load(f)
        except json.JSONDecodeError as exc:
            raise ValueError(f"corrupt variant catalog {cpath}: "
                             f"{exc}") from exc
    if not isinstance(body, dict) or body.get("magic") != VARIANTS_MAGIC:
        raise ValueError(f"not a TACZ variant catalog: {cpath}")
    if int(body.get("version", 0)) > VARIANTS_VERSION:
        raise ValueError(
            f"unsupported variant catalog version {body.get('version')}")
    if int(body.get("crc32", -1)) != mfst.manifest_crc(body):
        raise ValueError(f"corrupt variant catalog {cpath}: CRC mismatch")
    variants = body.get("variants")
    if not variants or not isinstance(variants, list):
        raise ValueError(f"variant catalog {cpath} lists no variants")
    names = [str(v["name"]) for v in variants]
    if len(set(names)) != len(names):
        raise ValueError(f"variant catalog {cpath} repeats a name")
    if str(body.get("default")) not in names:
        raise ValueError(
            f"variant catalog {cpath}: default variant not in catalog")
    return body


def variant_names(catalog: dict) -> list[str]:
    """Variant names a catalog binds, in catalog order."""
    return [str(v["name"]) for v in catalog.get("variants", [])]


def select_variant(catalog: dict, target: Target | str | None) -> dict:
    """The catalog entry a request resolves to.

    No ``target`` → the catalog's default variant; otherwise the
    cheapest (fewest bits) variant whose recorded metrics satisfy the
    target.

    :raises TargetUnsatisfiable: when a target is given and no variant
        qualifies (carries the best achievable value).
    :raises ValueError: on a malformed target spec.
    """
    variants = catalog["variants"]
    if target is None:
        default = str(catalog["default"])
        return next(v for v in variants if str(v["name"]) == default)
    if isinstance(target, str):
        target = parse_target(target)
    ok = [v for v in variants
          if target.satisfies(v.get("metrics", {}))]
    if not ok:
        from .frontier import HIGHER_IS_BETTER
        vals = [v["metrics"][target.metric] for v in variants
                if target.metric in v.get("metrics", {})]
        best = None
        if vals:
            best = (max(vals) if HIGHER_IS_BETTER.get(target.metric, False)
                    else min(vals))
        raise TargetUnsatisfiable(target, best)
    return min(ok, key=lambda v: int(v.get("bits", 0)))
