"""TACZ writer: level serialization + a streaming, double-buffered writer.

Two entry points:

  * :func:`write` — one-shot: serialize an ``AMRCompressionResult`` (the
    output of ``repro.core.hybrid.compress_amr``) or compress-and-write an
    ``AMRDataset`` directly.
  * :class:`TACZWriter` — streaming: ``add_level(data, mask)`` hands raw
    levels to a background encoder thread (bounded queue → double
    buffering: the simulation produces level *i+1* while level *i* is
    being SHE-encoded and appended), and ``close()`` finalizes the index
    and publishes the file atomically via the checkpoint manager's
    tmp + ``os.replace`` pattern — a crashed write never leaves a
    half-valid ``.tacz`` behind.

Serializable levels are the TAC+ SHE path (per-sub-block payloads under
one shared-Huffman codebook per level — the random-access case), GSP /
global single-payload levels, and raw-code "global" tensor levels (see
``repro.io.tensor``).  The merged-4D non-SHE path interleaves sub-blocks
inside shared code streams, so it has no per-sub-block payload to index;
asking to serialize it raises with a pointer at ``she=True``.
"""
from __future__ import annotations

import os
import queue
import threading
import time
import weakref
import zlib

import numpy as np

from repro.core import entropy, huffman
from repro.obs import metrics as obsm
from repro.core.amr import AMRDataset
from repro.core.compat import HAVE_ZSTD, zstd_compress
from repro.core.hybrid import (AMRCompressionResult, LevelResult,
                               compress_level)
from repro.core.sz import SZResult

from . import format as fmt
from . import frontier as frt

__all__ = ["TACZWriter", "pack_level", "write"]


def resolve_payload_codec(codec: str) -> int:
    """Map a payload-codec name to its COMPRESSOR_* wire code.

    ``"auto"`` (the default everywhere) picks zstd when the optional
    ``zstandard`` module is importable and degrades to stdlib zlib
    otherwise (``repro.core.compat``); ``"none"`` disables the v2
    lossless pass, reproducing v1's raw packed-bits payloads.
    """
    if codec == "none":
        return fmt.COMPRESSOR_NONE
    if codec == "zlib":
        return fmt.COMPRESSOR_ZLIB
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise ModuleNotFoundError(
                "payload_codec='zstd' but zstandard is not installed "
                "(use 'auto' to fall back to zlib)")
        return fmt.COMPRESSOR_ZSTD
    if codec == "auto":
        return fmt.COMPRESSOR_ZSTD if HAVE_ZSTD else fmt.COMPRESSOR_ZLIB
    raise ValueError(f"unknown payload codec {codec!r}")


def _lossless_pass(buf: bytes, compressor: int) -> tuple[bytes, int]:
    """Apply the configured byte pass to one payload's code bytes.

    Size-reducing only: the compressed form is kept when strictly smaller,
    otherwise the raw bytes go to the wire as ``COMPRESSOR_NONE`` — the
    per-sub-block compressor field records what actually happened, so the
    reader never pays an inflate for a pass that lost.
    """
    if compressor == fmt.COMPRESSOR_NONE or len(buf) < 16:
        return buf, fmt.COMPRESSOR_NONE
    if compressor == fmt.COMPRESSOR_ZSTD:
        comp = zstd_compress(buf)
    else:
        comp = zlib.compress(buf, 6)
    if len(comp) < len(buf):
        return comp, compressor
    return buf, fmt.COMPRESSOR_NONE


def _branch_code(r: SZResult) -> int:
    b = (r.extras or {}).get("branch")
    if b == "reg":
        return fmt.BRANCH_REG
    if b == "lorenzo" or r.method == "lorenzo":
        return fmt.BRANCH_LORENZO
    if r.method == "interp":
        return fmt.BRANCH_INTERP
    raise ValueError(f"cannot serialize SZ method {r.method!r}")


def _betas_bytes(r: SZResult) -> bytes:
    if (r.extras or {}).get("branch") != "reg":
        return b""
    return np.ascontiguousarray(r.extras["betas"], dtype="<f4").tobytes()


def pack_level(lr: LevelResult, *, payload_codec: str = "auto",
               entropy_engine: str = "auto",
               ) -> tuple[bytes, fmt.LevelEntry]:
    """Serialize one compressed level into (section blob, index entry).

    Offsets inside the returned entry are blob-relative; the caller places
    the blob in the file and calls ``entry.shift_offsets(base)``.

    ``payload_codec`` selects the v2 lossless byte pass over each
    payload's packed-Huffman code bytes (betas prefixes stay raw):
    ``"auto"`` → zstd, or zlib when zstandard is missing; ``"none"``
    reproduces v1's raw payloads.  The pass is recorded per level
    (``payload_compressor``) and per sub-block (only where it shrank).

    GSP/global levels reuse the codebook and packed payload the
    compress-time entropy stage already materialized
    (``SZResult.extras["entropy"]``) instead of re-encoding the same
    single stream — the write-path memoization the ROADMAP tracked.

    Artifacts with an *empty* result list (a parallel part writer's stub
    for a level whose every sub-block lives in other parts) serialize to
    a head + mask section only: no codebook, no payloads.

    ``entropy_engine`` selects the :mod:`repro.core.entropy` engine that
    packs the level's payloads (one batched launch instead of one encode
    per sub-block); every engine emits byte-identical payloads.
    """
    art = lr.artifacts
    if art is None:
        raise ValueError(
            "level has no serialization artifacts — the merged-4D non-SHE "
            "path is not indexable; compress with she=True (TAC+) or "
            "strategy='gsp', and keep_artifacts=True")
    if lr.strategy not in fmt.STRATEGY_CODES:
        raise ValueError(f"unknown strategy {lr.strategy!r}")

    blob = bytearray()

    def append(section: bytes) -> tuple[int, int]:
        off = len(blob)
        blob.extend(section)
        return off, len(section)

    entry = fmt.LevelEntry(
        shape=tuple(int(s) for s in art.orig_shape),
        grid_shape=tuple(int(s) for s in art.grid_shape),
        strategy=fmt.STRATEGY_CODES[lr.strategy],
        algorithm=fmt.ALGO_CODES[lr.algorithm],
        unit=int(art.unit), sz_block=int(art.sz_block), ratio=int(lr.ratio),
        eb=float(lr.eb), n_values=int(lr.n_values), density=float(lr.density))

    # --- shared codebook section (one per level, paper Alg. 4) -------------
    # (omitted, codebook_len = 0, when this part holds no payloads at all)
    memo = None
    if not art.results:
        cb = None
    elif lr.she:
        cb = art.codebook
    else:
        # gsp/global levels: one payload.  The compress-time entropy stage
        # already built the (deterministic) codebook and packed bitstream —
        # reuse both when present; rebuild only for artifacts produced
        # without entropy accounting.
        r0 = art.results[0]
        ent = (r0.extras or {}).get("entropy")
        if (len(art.results) == 1 and ent is not None
                and ent.get("codebook") is not None):
            memo = ent
            cb = ent["codebook"]
        else:
            cb = huffman.build_codebook(np.asarray(r0.codes,
                                                   dtype=np.int64))
    if art.results:
        cb_bytes = huffman.serialize_codebook(cb)
        entry.codebook_off, entry.codebook_len = append(cb_bytes)
        entry.codebook_crc = zlib.crc32(cb_bytes)

    # --- validity mask section (packbits + zlib; omitted when all-True) ----
    mask = np.asarray(art.mask, dtype=bool)
    if not mask.all():
        mask_bytes = zlib.compress(np.packbits(mask.ravel()).tobytes(), 6)
        entry.mask_off, entry.mask_len = append(mask_bytes)
        entry.mask_crc = zlib.crc32(mask_bytes)
        entry.mask_compressor = fmt.COMPRESSOR_ZLIB

    # --- sub-block payloads (byte-aligned, independently decodable) --------
    level_comp = resolve_payload_codec(payload_codec)
    entry.payload_compressor = level_comp
    if not art.results:
        return bytes(blob), entry
    if art.subblocks:
        subblocks, results = art.subblocks, art.results
        origins = [sb.cell_origin(art.unit) for sb in subblocks]
        sizes = [sb.cell_size(art.unit) for sb in subblocks]
    else:
        # single payload covering the whole (padded) grid; origin/size are
        # informative for 3D levels only (higher ranks decode via shape)
        results = art.results
        origins = [(0, 0, 0)]
        gs = tuple(int(s) for s in art.grid_shape[:3])
        sizes = [gs + (1,) * (3 - len(gs))]
    if memo is not None:
        payloads = [(memo["packed"], memo["nbits"])]
    else:
        # one engine launch packs every sub-block payload of the level
        # (byte-identical framing to per-payload encode, any engine)
        payloads = entropy.get_engine(entropy_engine).encode_payloads(
            cb, [np.asarray(r.codes, dtype=np.int64) for r in results])
    for r, (packed, nbits), origin, size in zip(results, payloads,
                                                origins, sizes):
        betas = _betas_bytes(r)
        stored, comp = _lossless_pass(packed, level_comp)
        payload = betas + stored
        off, length = append(payload)
        entry.subblocks.append(fmt.SubBlockEntry(
            origin=tuple(int(o) for o in origin),
            size=tuple(int(s) for s in size),
            branch=_branch_code(r), codec=fmt.CODEC_HUFFMAN,
            compressor=comp,
            payload_off=off, payload_len=length, nbits=int(nbits),
            n_codes=int(np.asarray(r.codes).size), betas_len=len(betas),
            crc=zlib.crc32(payload)))
    return bytes(blob), entry


def build_container(packed: list[tuple[bytes, fmt.LevelEntry]], *,
                    version: int = fmt.TACZ_VERSION) -> bytes:
    """Assemble header + level blobs + index + footer into one buffer
    (the in-memory path used for checkpoint tensor blobs).  ``version``
    exists for back-compat tooling/tests that emit v1 indexes; payloads
    must then not rely on v2-only index fields."""
    out = bytearray(fmt.pack_header(version=version))
    entries = []
    for blob, entry in packed:
        entry.shift_offsets(len(out))
        out.extend(blob)
        entries.append(entry)
    index = fmt.pack_index(entries, version=version)
    index_off = len(out)
    out.extend(index)
    out.extend(fmt.pack_footer(index_off, len(index), fmt.index_crc(index)))
    return bytes(out)


_SENTINEL = object()


def _nudge(q: queue.Queue) -> None:
    """GC finalizer: wake the encoder thread of an abandoned writer."""
    try:
        q.put_nowait(_SENTINEL)
    except queue.Full:   # worker is mid-item; it re-checks liveness next get
        pass


def _reap_sync(f, tmp: str) -> None:
    """GC finalizer for a ``background=False`` writer abandoned without
    close()/abort(): close the fd and drop the never-published tmp."""
    try:
        f.close()
    except OSError:      # pragma: no cover - already closed
        pass
    try:
        os.remove(tmp)
    except OSError:
        pass


def _worker_loop(wref, q: queue.Queue, f, tmp: str) -> None:
    """Encoder-thread body.  Holds only a weakref to the writer so an
    abandoned ``TACZWriter`` (never ``close()``d) can be collected; on
    collection the thread wakes (via the ``weakref.finalize`` nudge or the
    next queued item), closes the fd, unlinks the tmp file, and exits —
    no thread/fd/tmp leak per failed write."""
    while True:
        item = q.get()
        w = wref()
        try:
            if item is _SENTINEL or w is None:
                if w is None:   # abandoned without close()/abort()
                    try:
                        f.close()
                    except OSError:  # pragma: no cover
                        pass
                    try:
                        os.remove(tmp)
                    except OSError:
                        pass
                return
            if w._err is None and not w._aborted:
                w._append_level(w._encode(item))
        except BaseException as exc:  # propagate to the producer thread
            if w is not None:
                w._err = exc
        finally:
            del w
            q.task_done()


class TACZWriter:
    """Streaming TACZ writer with a background encoder thread.

    ``add_level`` enqueues a snapshot of the level and returns immediately;
    a worker thread runs the batched SHE pipeline and appends the encoded
    sections.  The queue is bounded: with the default ``queue_depth=2``
    the producer can hold two snapshots queued while a third encodes
    (peak three in-flight levels); pass ``queue_depth=1`` for strict
    double buffering (one queued + one encoding).

    The file is written to ``<path>.tmp`` and moved into place by
    ``close()`` via ``os.replace`` — readers never observe a partial file.
    Use as a context manager; a writer dropped without ``close()`` /
    ``abort()`` is still reaped at GC time (encoder thread exits, fd
    closed, tmp unlinked) but the file is never published.

    :param path: destination ``.tacz`` path.
    :param eb: default absolute error bound for :meth:`add_level` (may
        also be passed per level).
    :param unit: default unit-block edge in cells; per-level units follow
        the ``compress_amr`` domain-tracking rule (see :meth:`add_level`).
    :param algorithm: prediction algorithm (``"lor_reg"``/``"lorenzo"``/
        ``"interp"``).
    :param she: encode SHE (per-sub-block payload) levels — required for
        random access; ``False`` only makes sense with ``strategy="gsp"``.
    :param strategy: partitioning strategy override (default: per-level
        auto selection).
    :param sz_block: Lorenzo/regression block edge in cells.
    :param batched: run the batched SHE pipeline (bit-identical, faster).
    :param lorenzo_engine: ``"auto"``/``"numpy"``/``"pallas"`` for the
        Lorenzo branch.
    :param payload_codec: v2 lossless byte pass — ``"auto"`` (zstd, zlib
        fallback), ``"zstd"``, ``"zlib"``, or ``"none"`` (v1 payloads).
    :param queue_depth: bounded encode queue length (≥1).
    :param background: run the encoder on a background thread (the
        double-buffering default).  ``background=False`` encodes inline
        in the calling thread — ``add_level`` then blocks but never
        contends for the GIL with a second thread, which is what a
        caller that *is already* a dedicated worker wants (each part
        worker of ``repro.io.parallel`` writes this way).
    :raises ValueError: on an unknown ``payload_codec`` name.
    :raises OSError: if the tmp file cannot be created.
    """

    def __init__(self, path: str, *, eb: float | None = None, unit: int = 8,
                 algorithm: str = "lor_reg", she: bool = True,
                 strategy: str | None = None, sz_block: int = 6,
                 batched: bool = True, lorenzo_engine: str = "auto",
                 entropy_engine: str = "auto",
                 payload_codec: str = "auto", queue_depth: int = 2,
                 background: bool = True):
        self.path = str(path)
        self._tmp = self.path + ".tmp"
        resolve_payload_codec(payload_codec)   # fail fast on bad names
        entropy.check_engine_name(entropy_engine)
        self._payload_codec = payload_codec
        self._entropy_engine = entropy_engine
        self._defaults = dict(eb=eb, unit=unit, algorithm=algorithm, she=she,
                              strategy=strategy, sz_block=sz_block,
                              batched=batched, lorenzo_engine=lorenzo_engine,
                              entropy_engine=entropy_engine)
        self._f = open(self._tmp, "wb")
        self._f.write(fmt.pack_header())
        self._off = fmt.HEADER_SIZE
        self._entries: list[fmt.LevelEntry] = []
        self._frontier: frt.Frontier | None = None
        #: index CRC of the published file (set by :meth:`close` — the
        #: same value ``probe_index_crc`` reads back from the footer)
        self.index_crc: int | None = None
        self._err: BaseException | None = None
        # plain per-writer stage totals (no registry round-trip): the
        # process-mode parallel writer ships these back over the result
        # queue so the producer can merge them into its own registry
        self._obs = {"levels": 0, "encode_seconds": 0.0,
                     "pack_seconds": 0.0, "publish_seconds": 0.0,
                     "bytes": 0}
        self._background = bool(background)
        self._finalized = False          # close() published the file
        self._aborted = False            # tmp dropped; writer unusable
        self._sentinel_sent = False
        if self._background:
            self._queue: queue.Queue = queue.Queue(
                maxsize=max(1, queue_depth))
            self._thread = threading.Thread(
                target=_worker_loop,
                args=(weakref.ref(self), self._queue, self._f, self._tmp),
                daemon=True)
            self._thread.start()
            self._reaper = weakref.finalize(self, _nudge, self._queue)
        else:
            self._queue = None
            self._thread = None
            # still reap an abandoned writer: close the fd, drop the tmp
            self._reaper = weakref.finalize(self, _reap_sync, self._f,
                                            self._tmp)

    # ------------------------------ producer -------------------------------

    def add_level(self, data: np.ndarray, mask: np.ndarray | None = None, *,
                  eb: float | None = None, ratio: int = 1,
                  unit: int | None = None) -> None:
        """Queue one raw level for encoding (snapshot taken immediately).

        ``unit`` defaults to ``max(2, default_unit // ratio)`` — the same
        domain-tracking rule ``compress_amr`` applies, so a streamed file
        decodes bit-identically to the one-shot path.
        """
        self._check_live()
        eb = self._defaults["eb"] if eb is None else eb
        if eb is None:
            raise ValueError("no error bound: pass eb= here or to the writer")
        if unit is None:
            unit = max(2, int(self._defaults["unit"]) // max(int(ratio), 1))
        data = np.array(data, dtype=np.float32, copy=True)
        mask = (data != 0) if mask is None else np.array(mask, dtype=bool,
                                                         copy=True)
        self._put(("raw", data, mask, float(eb), int(ratio), int(unit)))

    def add_compressed(self, lr: LevelResult) -> None:
        """Queue an already-compressed level (needs ``artifacts``)."""
        self._check_live()
        if lr.artifacts is None:
            raise ValueError(
                "LevelResult has no serialization artifacts — the merged-4D "
                "non-SHE path is not indexable (compress with she=True or "
                "strategy='gsp'), and compression must run with "
                "keep_artifacts=True")
        self._put(("level", lr))

    def set_frontier(self, frontier: frt.Frontier | None) -> None:
        """Attach a rate–distortion frontier (``repro.io.frontier``) to
        this snapshot.  ``close()`` then writes it as the optional
        ``TACF`` section between the index and the footer — the footer
        keeps framing only the index, so readers that predate the
        section skip it untouched."""
        self._check_live()
        self._frontier = frontier

    def close(self, *, publish: bool = True) -> str:
        """Drain the queue, write index + footer, publish atomically.

        Raises the background encoder's error (if any) — even when that
        error already surfaced through ``add_level`` — after dropping the
        tmp file; the destination path is never reported as written
        unless it actually was.

        ``publish=False`` finalizes the file completely (index, footer,
        fsync, fd closed) but leaves it at ``<path>.tmp`` and returns
        that tmp path — the multi-part writer's two-phase commit: every
        part finalizes first, and only when all of them succeeded are
        they renamed into place, so a failing sibling can never leave a
        previously published snapshot half-replaced.
        """
        if self._finalized:
            return self.path
        self._stop_worker()
        if self._aborted:
            raise ValueError("writer was aborted")
        try:
            if self._err is not None:
                raise self._err
            with obsm.timed(obsm.WRITER_LEVEL_SECONDS.labels("publish"),
                            "publish"):
                t0 = time.perf_counter()
                index = fmt.pack_index(self._entries)
                self._f.write(index)
                self.index_crc = fmt.index_crc(index)
                if self._frontier is not None:
                    # optional TACF section between index and footer —
                    # the footer frames only the index, so pre-frontier
                    # readers skip these bytes without noticing
                    self._f.write(frt.pack_section(self._frontier))
                self._f.write(fmt.pack_footer(self._off, len(index),
                                              self.index_crc))
                self._f.flush()
                os.fsync(self._f.fileno())
                self._f.close()
                if publish:
                    os.replace(self._tmp, self.path)
                self._obs["publish_seconds"] += time.perf_counter() - t0
        except BaseException:
            self.abort()
            raise
        self._finalized = True
        return self.path if publish else self._tmp

    def abort(self) -> None:
        """Drop the partial file (used on error paths)."""
        self._aborted = True
        self._stop_worker()
        try:
            self._f.close()
        except OSError:  # pragma: no cover - double close
            pass
        try:
            os.remove(self._tmp)
        except OSError:
            pass

    def __enter__(self) -> "TACZWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()

    # ------------------------------ worker ---------------------------------

    def _stop_worker(self) -> None:
        if not self._sentinel_sent:
            self._sentinel_sent = True
            self._reaper.detach()   # orderly shutdown owns cleanup now
            if self._background:
                self._queue.put(_SENTINEL)
        if self._thread is not None:
            self._thread.join()

    def _check_live(self) -> None:
        if self._finalized or self._aborted or self._sentinel_sent:
            raise ValueError("writer is closed")
        if self._err is not None:
            raise self._err

    def _put(self, item) -> None:
        if self._background:
            self._queue.put(item)
            return
        try:                  # inline encode: errors surface immediately
            self._append_level(self._encode(item))
        except BaseException as exc:
            self._err = exc   # close() must keep refusing to publish
            raise

    def _encode(self, item) -> LevelResult:
        if item[0] == "level":
            return item[1]
        _, data, mask, eb, ratio, unit = item
        d = self._defaults
        with obsm.timed(obsm.WRITER_LEVEL_SECONDS.labels("encode"),
                        "encode"):
            t0 = time.perf_counter()
            lr = compress_level(data, mask, eb=eb, unit=unit,
                                algorithm=d["algorithm"], she=d["she"],
                                strategy=d["strategy"],
                                sz_block=d["sz_block"],
                                batched=d["batched"],
                                lorenzo_engine=d["lorenzo_engine"],
                                entropy_engine=d["entropy_engine"],
                                ratio=ratio, keep_artifacts=True)
            self._obs["encode_seconds"] += time.perf_counter() - t0
            return lr

    def _append_level(self, lr: LevelResult) -> None:
        with obsm.timed(obsm.WRITER_LEVEL_SECONDS.labels("pack"), "pack"):
            t0 = time.perf_counter()
            blob, entry = pack_level(lr, payload_codec=self._payload_codec,
                                     entropy_engine=self._entropy_engine)
            entry.shift_offsets(self._off)
            self._f.write(blob)
            self._off += len(blob)
            self._entries.append(entry)
            self._obs["pack_seconds"] += time.perf_counter() - t0
            self._obs["levels"] += 1
            self._obs["bytes"] += len(blob)
        obsm.WRITER_LEVELS.inc()
        obsm.WRITER_BYTES.inc(len(blob))

    def obs_summary(self) -> dict:
        """Plain-dict stage totals for this writer (levels appended,
        encode/pack/publish seconds, payload bytes).  Process-mode part
        workers return this through the result queue so the producer can
        fold worker time into its own registry — worker processes have
        their own (unscraped) ``repro.obs`` registry."""
        return dict(self._obs)


def write(path: str, obj, *, eb: float | list[float] | None = None,
          frontier: frt.Frontier | None = None, **kwargs) -> str:
    """Write ``obj`` to a TACZ container at ``path``.

    ``obj`` may be an ``AMRCompressionResult`` (already compressed with
    ``keep_artifacts=True`` — the default) or an ``AMRDataset`` (compressed
    here, level by level, through the streaming writer; ``eb`` is required
    and may be per-level).  ``frontier`` attaches an optional rate–
    distortion frontier (``TACF`` section).  Returns ``path``.
    """
    if isinstance(obj, AMRCompressionResult):
        with TACZWriter(path, **kwargs) as w:
            for lr in obj.levels:
                w.add_compressed(lr)
            if frontier is not None:
                w.set_frontier(frontier)
        return path
    if isinstance(obj, AMRDataset):
        if eb is None:
            raise ValueError("writing a raw AMRDataset needs eb=")
        ebs = eb if isinstance(eb, (list, tuple)) else [eb] * obj.n_levels
        if len(ebs) != obj.n_levels:
            raise ValueError("need one error bound per level")
        with TACZWriter(path, **kwargs) as w:
            for lvl, e in zip(obj.levels, ebs):
                w.add_level(lvl.data, lvl.mask, eb=float(e), ratio=lvl.ratio)
            if frontier is not None:
                w.set_frontier(frontier)
        return path
    raise TypeError(f"cannot write {type(obj).__name__} as TACZ")
