"""TACZ blobs for single tensors — the checkpoint-manager integration.

``CheckpointManager`` lossy mode used to write an ad-hoc ``(blob, eb,
dtype, shape)`` dict per tensor; those parameters now travel inside a
self-describing one-level TACZ container instead, so a lossy checkpoint
entry is the same indexed, CRC-framed, versioned format the AMR pipeline
writes — one decoder, one corruption story.

The encoding itself is unchanged ("sz-light", DESIGN.md §6): dual-quant
N-D Lorenzo codes stored *raw* (int16 when they fit, int32 otherwise)
under a zstd/zlib byte pass — no Huffman stage, keeping restore fast.  On
the wire that is a ``STRATEGY_GLOBAL`` level with a ``CODEC_RAW_*``
payload.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.core.compat import HAVE_ZSTD, zstd_compress
from repro.core.sz import lorenzo_nd_codes, prequant

from . import format as fmt
from .reader import TACZReader
from .writer import build_container

__all__ = ["encode_tensor", "decode_tensor"]


def encode_tensor(a: np.ndarray, eb: float) -> bytes:
    """Error-bounded lossy encoding of one tensor → TACZ container bytes.

    :param a: array of any numeric dtype, rank 1..8.
    :param eb: absolute error bound; the reconstruction satisfies
        ``|a - decode_tensor(blob)| ≤ eb`` (+ float32 rounding).
    :returns: a self-describing one-level TACZ container as bytes.
    :raises ValueError: if the tensor rank is outside 1..8.
    """
    a = np.asarray(a)
    if not 1 <= a.ndim <= fmt.MAX_RANK:
        raise ValueError(f"tensor rank {a.ndim} outside 1..{fmt.MAX_RANK}")
    codes = lorenzo_nd_codes(prequant(a, eb))
    if np.abs(codes).max(initial=0) < 2 ** 15:
        raw = codes.astype("<i2").tobytes()
        codec = fmt.CODEC_RAW_I16
    else:
        raw = codes.astype("<i4").tobytes()
        codec = fmt.CODEC_RAW_I32
    if HAVE_ZSTD:
        payload = zstd_compress(raw)
        compressor = fmt.COMPRESSOR_ZSTD
    else:
        payload = zlib.compress(raw, 6)
        compressor = fmt.COMPRESSOR_ZLIB
    shape = tuple(int(s) for s in a.shape)
    entry = fmt.LevelEntry(
        shape=shape, grid_shape=shape, strategy=fmt.STRATEGY_GLOBAL,
        algorithm=fmt.ALGO_LORENZO, unit=1, sz_block=6, ratio=1,
        eb=float(eb), n_values=int(a.size), density=1.0)
    entry.subblocks.append(fmt.SubBlockEntry(
        origin=(0, 0, 0), size=(shape + (1, 1, 1))[:3],
        branch=fmt.BRANCH_LORENZO, codec=codec, compressor=compressor,
        payload_off=0, payload_len=len(payload), nbits=0,
        n_codes=int(codes.size), betas_len=0, crc=zlib.crc32(payload)))
    return build_container([(payload, entry)])


def decode_tensor(blob: bytes) -> np.ndarray:
    """Inverse of :func:`encode_tensor`.

    :param blob: container bytes produced by :func:`encode_tensor`.
    :returns: the float32 reconstruction at the original shape.
    :raises ValueError: if the blob is not a one-level TACZ container.
    :raises IOError: if the payload fails its CRC check.
    """
    with TACZReader(blob) as rd:
        if rd.n_levels != 1:
            raise ValueError("tensor blob must hold exactly one level")
        return rd.read_level(0)
