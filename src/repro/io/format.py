"""TACZ container format: framing, enums, and index (de)serialization.

Layout of a ``.tacz`` file (little-endian throughout)::

    +--------------------------------------------------------------+
    | HEADER (16 B): magic "TACZ", u16 version, u16 flags, u64 rsvd|
    +--------------------------------------------------------------+
    | level 0 sections:  [codebook][mask][payload payload ...]     |
    | level 1 sections:  [codebook][mask][payload payload ...]     |
    | ...              (appended in arrival order — streamable)    |
    +--------------------------------------------------------------+
    | INDEX: per-level entry + per-sub-block entries (see below)   |
    +--------------------------------------------------------------+
    | FOOTER (20 B): u64 index_off, u32 index_len, u32 index_crc,  |
    |                magic "TACZ"                                  |
    +--------------------------------------------------------------+

The index is written *last* so the writer can stream level payloads as
they arrive without back-patching; readers locate it through the footer.
Every sub-block payload carries its own CRC32 so corruption is localized
to one sub-block, and the index itself is CRC'd so a truncated file fails
loudly at open time instead of decoding garbage.

A *sub-block entry* records everything needed to decode that sub-block in
isolation — origin/shape (cells, in padded-grid coordinates), prediction
branch, payload codec, byte offset/length, exact bit count, code count,
and the length of the inline regression-betas prefix.  This per-sub-block
granularity is what makes region-of-interest decode possible: the reader
touches only the payload byte ranges whose cuboids intersect the query.

Version history:

  * **v1** — initial container (PR 2): raw packed-bits Huffman payloads.
  * **v2** — adds an optional lossless byte pass (zstd, or zlib via
    ``repro.core.compat`` fallback) over the shared-Huffman payload
    sections and records the level's configured codec in a new
    ``payload_compressor`` byte in the per-level index head.  The
    per-sub-block ``compressor`` field (present since v1) stays the
    authoritative decode-side switch — a sub-block whose pass did not
    shrink keeps ``COMPRESSOR_NONE``.  v1 files remain readable: the
    index head is parsed by the version the header advertises.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

TACZ_MAGIC = b"TACZ"
TACZ_VERSION = 2

MAX_RANK = 8

# --- enums (u8 on the wire) -------------------------------------------------

# level strategy
STRATEGY_OPST = 0
STRATEGY_AKDTREE = 1
STRATEGY_GSP = 2
STRATEGY_GLOBAL = 3      # single global payload (e.g. checkpoint tensors)
STRATEGY_NAST = 4

STRATEGY_NAMES = {STRATEGY_OPST: "opst", STRATEGY_AKDTREE: "akdtree",
                  STRATEGY_GSP: "gsp", STRATEGY_GLOBAL: "global",
                  STRATEGY_NAST: "nast"}
STRATEGY_CODES = {v: k for k, v in STRATEGY_NAMES.items()}

# level algorithm
ALGO_LOR_REG = 0
ALGO_LORENZO = 1
ALGO_INTERP = 2
ALGO_NAMES = {ALGO_LOR_REG: "lor_reg", ALGO_LORENZO: "lorenzo",
              ALGO_INTERP: "interp"}
ALGO_CODES = {v: k for k, v in ALGO_NAMES.items()}

# per-sub-block prediction branch (what `repro.core.sz.decode_codes` takes)
BRANCH_LORENZO = 0
BRANCH_REG = 1
BRANCH_INTERP = 2
BRANCH_NAMES = {BRANCH_LORENZO: "lorenzo", BRANCH_REG: "reg",
                BRANCH_INTERP: "interp"}

# payload codec: how the code stream is represented on the wire
CODEC_HUFFMAN = 0        # canonical-Huffman packed bits (shared codebook)
CODEC_RAW_I16 = 1        # raw little-endian int16 codes ("sz-light")
CODEC_RAW_I32 = 2        # raw little-endian int32 codes

# byte-level lossless pass over the (non-betas part of the) payload
COMPRESSOR_NONE = 0
COMPRESSOR_ZLIB = 1
COMPRESSOR_ZSTD = 2

# --- framing ----------------------------------------------------------------

_HEADER = struct.Struct("<4sHHQ")                 # magic, version, flags, rsvd
_FOOTER = struct.Struct("<QII4s")                 # off, len, crc, magic
HEADER_SIZE = _HEADER.size                        # 16
FOOTER_SIZE = _FOOTER.size                        # 20

# v1: rank, strategy, algorithm, mask_compressor, sz_block, unit, ratio,
# eb, n_values, density
_LEVEL_HEAD_V1 = struct.Struct("<BBBBBHHdQd")
# v2 inserts payload_compressor after mask_compressor
_LEVEL_HEAD = struct.Struct("<BBBBBBHHdQd")
# codebook off/len/crc, mask off/len/crc, n_subblocks
_LEVEL_SECTIONS = struct.Struct("<QIIQIII")
# origin xyz, size xyz, branch, codec, compressor, payload off/len,
# nbits, n_codes, betas_len, crc
_SUBBLOCK = struct.Struct("<6I3BQIQQII")


def pack_header(flags: int = 0, *, version: int = TACZ_VERSION) -> bytes:
    """The 16-byte file header (magic, version, flags, reserved)."""
    return _HEADER.pack(TACZ_MAGIC, version, flags, 0)


def parse_header(buf: bytes) -> int:
    """Validate the header; returns the format version."""
    if len(buf) < HEADER_SIZE:
        raise ValueError("not a TACZ file: truncated header")
    magic, version, _flags, _rsvd = _HEADER.unpack_from(buf, 0)
    if magic != TACZ_MAGIC:
        raise ValueError("not a TACZ file: bad magic")
    if version > TACZ_VERSION:
        raise ValueError(f"unsupported TACZ version {version}")
    return version


def pack_footer(index_off: int, index_len: int, index_crc: int) -> bytes:
    """The 20-byte trailer locating (and checksumming) the index."""
    return _FOOTER.pack(index_off, index_len, index_crc & 0xFFFFFFFF,
                        TACZ_MAGIC)


def parse_footer(buf: bytes) -> tuple[int, int, int]:
    """(index_off, index_len, index_crc) from the trailing FOOTER_SIZE bytes."""
    if len(buf) < FOOTER_SIZE:
        raise ValueError("truncated TACZ file: missing footer")
    off, length, crc, magic = _FOOTER.unpack_from(buf, len(buf) - FOOTER_SIZE)
    if magic != TACZ_MAGIC:
        raise ValueError("truncated or corrupt TACZ file: bad footer magic")
    return off, length, crc


# --- index entries ----------------------------------------------------------


@dataclass
class SubBlockEntry:
    """Index record for one independently-decodable sub-block payload."""

    origin: tuple[int, int, int]      # cell coords in the padded level grid
    size: tuple[int, int, int]        # cell extent per dim
    branch: int                       # BRANCH_*
    codec: int                        # CODEC_*
    compressor: int                   # COMPRESSOR_* (code bytes only)
    payload_off: int                  # absolute file offset
    payload_len: int                  # stored bytes (betas prefix included)
    nbits: int                        # exact Huffman bit count (codec 0)
    n_codes: int                      # symbols in the code stream
    betas_len: int                    # bytes of float32 betas at payload start
    crc: int                          # CRC32 of the stored payload bytes


@dataclass
class LevelEntry:
    """Index record for one level (or one tensor, strategy=GLOBAL)."""

    shape: tuple[int, ...]            # original level shape (rank dims)
    grid_shape: tuple[int, ...]       # padded block-grid shape
    strategy: int                     # STRATEGY_*
    algorithm: int                    # ALGO_*
    unit: int                         # unit-block edge (cells)
    sz_block: int                     # Lor/Reg regression block edge
    ratio: int                        # coarsening ratio vs the finest grid
    eb: float                         # absolute error bound
    n_values: int                     # stored values at this level
    density: float                    # unit-block density
    codebook_off: int = 0
    codebook_len: int = 0             # 0 → no codebook section
    codebook_crc: int = 0             # CRC32 of the codebook section bytes
    mask_off: int = 0
    mask_len: int = 0                 # 0 → mask is all-True
    mask_crc: int = 0                 # CRC32 of the stored mask bytes
    mask_compressor: int = COMPRESSOR_ZLIB
    # the level's *configured* payload pass (v2); decode always follows the
    # per-sub-block compressor field (COMPRESSOR_NONE when the pass lost)
    payload_compressor: int = COMPRESSOR_NONE
    subblocks: list[SubBlockEntry] = field(default_factory=list)

    @property
    def rank(self) -> int:
        """Number of dimensions of the level."""
        return len(self.shape)

    def shift_offsets(self, base: int) -> None:
        """Turn blob-relative section offsets into absolute file offsets."""
        if self.codebook_len:
            self.codebook_off += base
        if self.mask_len:
            self.mask_off += base
        for sb in self.subblocks:
            sb.payload_off += base


def pack_index(levels: list[LevelEntry], *,
               version: int = TACZ_VERSION) -> bytes:
    """Serialize the index: u32 level count + per-level records.

    :param levels: entries with *absolute* section offsets.
    :param version: index-head layout to emit (v1 drops the
        ``payload_compressor`` byte).
    :raises ValueError: on an unsupported rank or shape-rank mismatch.
    """
    out = bytearray(struct.pack("<I", len(levels)))
    for e in levels:
        rank = e.rank
        if not 1 <= rank <= MAX_RANK:
            raise ValueError(f"unsupported rank {rank}")
        if len(e.grid_shape) != rank:
            raise ValueError("grid_shape rank mismatch")
        if version >= 2:
            out += _LEVEL_HEAD.pack(rank, e.strategy, e.algorithm,
                                    e.mask_compressor, e.payload_compressor,
                                    e.sz_block, e.unit, e.ratio, e.eb,
                                    e.n_values, e.density)
        else:
            out += _LEVEL_HEAD_V1.pack(rank, e.strategy, e.algorithm,
                                       e.mask_compressor, e.sz_block, e.unit,
                                       e.ratio, e.eb, e.n_values, e.density)
        out += struct.pack(f"<{rank}I", *e.shape)
        out += struct.pack(f"<{rank}I", *e.grid_shape)
        out += _LEVEL_SECTIONS.pack(e.codebook_off, e.codebook_len,
                                    e.codebook_crc & 0xFFFFFFFF,
                                    e.mask_off, e.mask_len,
                                    e.mask_crc & 0xFFFFFFFF,
                                    len(e.subblocks))
        for sb in e.subblocks:
            out += _SUBBLOCK.pack(*sb.origin, *sb.size, sb.branch, sb.codec,
                                  sb.compressor, sb.payload_off,
                                  sb.payload_len, sb.nbits, sb.n_codes,
                                  sb.betas_len, sb.crc & 0xFFFFFFFF)
    return bytes(out)


def parse_index(buf: bytes, *, version: int = TACZ_VERSION
                ) -> list[LevelEntry]:
    """Inverse of :func:`pack_index`.

    :param buf: the index bytes (CRC already verified by the caller).
    :param version: the layout the file header advertised.
    :raises ValueError: on truncation or an implausible rank.
    """
    try:
        (n_levels,) = struct.unpack_from("<I", buf, 0)
        pos = 4
        levels: list[LevelEntry] = []
        for _ in range(n_levels):
            if version >= 2:
                (rank, strategy, algorithm, mask_comp, payload_comp,
                 sz_block, unit, ratio, eb, n_values,
                 density) = _LEVEL_HEAD.unpack_from(buf, pos)
                pos += _LEVEL_HEAD.size
            else:
                (rank, strategy, algorithm, mask_comp, sz_block, unit, ratio,
                 eb, n_values, density) = _LEVEL_HEAD_V1.unpack_from(buf, pos)
                payload_comp = COMPRESSOR_NONE
                pos += _LEVEL_HEAD_V1.size
            if not 1 <= rank <= MAX_RANK:
                raise ValueError(f"corrupt index: rank {rank}")
            shape = struct.unpack_from(f"<{rank}I", buf, pos)
            pos += 4 * rank
            grid_shape = struct.unpack_from(f"<{rank}I", buf, pos)
            pos += 4 * rank
            (cb_off, cb_len, cb_crc, mask_off, mask_len, mask_crc,
             n_sb) = _LEVEL_SECTIONS.unpack_from(buf, pos)
            pos += _LEVEL_SECTIONS.size
            entry = LevelEntry(shape=tuple(shape), grid_shape=tuple(grid_shape),
                               strategy=strategy, algorithm=algorithm,
                               unit=unit, sz_block=sz_block, ratio=ratio,
                               eb=eb, n_values=n_values, density=density,
                               codebook_off=cb_off, codebook_len=cb_len,
                               codebook_crc=cb_crc,
                               mask_off=mask_off, mask_len=mask_len,
                               mask_crc=mask_crc, mask_compressor=mask_comp,
                               payload_compressor=payload_comp)
            for _ in range(n_sb):
                vals = _SUBBLOCK.unpack_from(buf, pos)
                pos += _SUBBLOCK.size
                entry.subblocks.append(SubBlockEntry(
                    origin=tuple(vals[0:3]), size=tuple(vals[3:6]),
                    branch=vals[6], codec=vals[7], compressor=vals[8],
                    payload_off=vals[9], payload_len=vals[10],
                    nbits=vals[11], n_codes=vals[12], betas_len=vals[13],
                    crc=vals[14]))
            levels.append(entry)
        return levels
    except struct.error as exc:
        raise ValueError("corrupt TACZ index") from exc


def index_crc(index_bytes: bytes) -> int:
    """CRC32 of the index bytes — the snapshot's content identity."""
    return zlib.crc32(index_bytes) & 0xFFFFFFFF
