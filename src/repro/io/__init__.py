"""``repro.io`` — the TACZ container: random-access storage for TAC+.

TACZ turns the in-memory bit accounting of the compression pipeline into
a real I/O system: a framed, versioned file with a per-level /
per-sub-block index (origin, shape, branch, error bound, byte offset,
CRC), one shared-Huffman codebook section per level, and byte-aligned
sub-block payloads.  The byte-level specification lives in
``docs/tacz_format.md`` (kept in sync with :mod:`repro.io.format` by a
test); an independent reader can be written from it alone.

  * :func:`write` / :class:`TACZWriter` — one-shot or streaming writes
    (background encoder thread, atomic tmp + ``os.replace`` publish).
  * :func:`read` / :func:`read_roi` / :class:`TACZReader` — full or
    region-of-interest decode; ROI touches only the sub-blocks whose
    cuboids intersect the query box.  The reader also exposes the
    serving-layer plumbing: ``subblock_keys`` (the key universe shard
    maps range over), ``level_signature`` (content identity for cache
    carry-over across republishes), and ``read_level_box`` (single-level
    crops in level cells).
  * :mod:`repro.io.tensor` — one-tensor TACZ blobs for lossy checkpoints.
  * format v2 adds an optional lossless byte pass (zstd/zlib) over the
    shared-Huffman payload sections; v1 files remain readable.
  * :class:`ParallelTACZWriter` / :func:`write_multipart` — multi-part
    snapshots: N workers (threads or processes) each stream their own
    rendezvous-hash partition of every level into ``part-XXXX.tacz``,
    bound by an atomic CRC'd ``manifest.json``
    (:mod:`repro.io.manifest`); :func:`open_snapshot` opens either kind
    behind one reader surface (:class:`MultiPartReader` for
    directories).  The placement rule lives in
    :mod:`repro.io.placement` — the same hashing the serving-side shard
    maps use, so shards can align 1:1 with parts.

  * :mod:`repro.io.frontier` / :mod:`repro.io.variants` — rate–
    distortion frontiers (the optional ``TACF`` section / manifest key
    the autotuner records) and multi-variant snapshot sets under one
    ``variants.json`` catalog; distortion-target grammar and selection
    live here too.  See ``docs/tuning.md``.

Serving-side consumers (sub-block cache, batched decode planner, HTTP
region endpoint, consistent-hash sharding) live in :mod:`repro.serving`
— see ``docs/serving.md``.

Quick start::

    from repro import io as tacz
    from repro.core import amr, hybrid

    ds = amr.load_preset("run1_z10")
    res = hybrid.compress_amr(ds, eb=1e-3)
    tacz.write("snap.tacz", res)
    crops = tacz.read_roi("snap.tacz", ((0, 16), (0, 16), (0, 16)))
"""
from .format import TACZ_MAGIC, TACZ_VERSION
from .frontier import (Frontier, FrontierPoint, Target,
                       TargetUnsatisfiable, parse_target)
from .parallel import MultiPartReader, ParallelTACZWriter, write_multipart
from .reader import (ROILevel, TACZReader, WHOLE_LEVEL, open_snapshot,
                     read, read_roi)
from .variants import is_variant_set, load_catalog, select_variant
from .writer import TACZWriter, write

__all__ = ["TACZ_MAGIC", "TACZ_VERSION", "Frontier", "FrontierPoint",
           "MultiPartReader", "ParallelTACZWriter", "ROILevel",
           "TACZReader", "TACZWriter", "Target", "TargetUnsatisfiable",
           "WHOLE_LEVEL", "is_variant_set", "load_catalog",
           "open_snapshot", "parse_target", "read", "read_roi",
           "select_variant", "write", "write_multipart"]
