"""TACZ reader: full decode, region-of-interest decode, corruption checks.

The reader never scans the file: it parses the footer + CRC'd index, then
seeks straight to the byte ranges it needs.  Full decode touches every
payload; :meth:`TACZReader.read_roi` touches only the sub-blocks whose
cuboids intersect the query box — on partition-heavy TAC+ levels that is
the difference between decoding the whole snapshot and decoding a few
bricks (the access pattern AMR visualization/analysis consumers actually
have).  Both paths reproduce the in-memory ``compress_amr`` reconstruction
bit-identically.
"""
from __future__ import annotations

import io as _stdio
import os
import threading
import zlib
from dataclasses import dataclass

import numpy as np

from repro.core import entropy, huffman, sz
from repro.core.blocks import make_block_grid
from repro.core.compat import HAVE_ZSTD, zstd_decompress
from repro.obs import metrics as obsm
from repro.core.gsp import gsp_unpad

from . import format as fmt
from . import frontier as frt

__all__ = ["ROILevel", "TACZReader", "WHOLE_LEVEL", "open_snapshot",
           "probe_index_crc", "read", "read_roi"]

Box = tuple[tuple[int, int], tuple[int, int], tuple[int, int]]

#: Sub-block index standing in for the single payload of a gsp/global
#: level in a ``(level, sub_block)`` key.  SHE levels use real indices
#: (``0..n_subblocks-1``); single-payload levels are addressed as one
#: unit because their reconstruction is not block-local.  The serving
#: layer (cache keys, shard placement) uses the same convention.
WHOLE_LEVEL = -1


@dataclass
class ROILevel:
    """One level's crop of a region-of-interest read."""

    level: int                    # level index in the file
    ratio: int                    # coarsening ratio vs the finest grid
    box: Box                      # the decoded box, in *level* cells
    data: np.ndarray              # recon crop, shape = box extents

    @property
    def shape(self) -> tuple[int, ...]:
        """Extent of the crop per dim (``hi - lo`` of each box range)."""
        return tuple(hi - lo for lo, hi in self.box)


def _decompress(buf: bytes, compressor: int) -> bytes:
    if compressor == fmt.COMPRESSOR_NONE:
        return buf
    if compressor == fmt.COMPRESSOR_ZLIB:
        return zlib.decompress(buf)
    if compressor == fmt.COMPRESSOR_ZSTD:
        if not HAVE_ZSTD:
            raise ModuleNotFoundError(
                "this TACZ file was written with zstd payloads but "
                "zstandard is not installed")
        return zstd_decompress(buf)
    raise ValueError(f"unknown compressor {compressor}")


class TACZReader:
    """Random-access reader over a TACZ container.

    The constructor validates framing eagerly: header magic/version,
    footer, index bounds, and the index CRC — a truncated or corrupt
    file fails at open time, never as silent garbage mid-decode.  One
    reader may serve many threads (the seek+read pair is lock-guarded).

    :param src: file path, raw ``bytes``/``bytearray``, or a seekable
        binary file object (not closed on :meth:`close`).
    :param entropy_engine: :mod:`repro.core.entropy` engine for payload
        decode (``"auto"`` picks the batched path; every engine is
        bit-identical, so this only affects speed).
    :raises ValueError: if the bytes are not a valid TACZ container
        (bad magic, unsupported version, truncation, index CRC mismatch).
    :raises OSError: if a path cannot be opened.
    """

    _SHE_STRATEGIES = (fmt.STRATEGY_OPST, fmt.STRATEGY_AKDTREE,
                       fmt.STRATEGY_NAST)

    def __init__(self, src, *, entropy_engine: str = "auto"):
        entropy.check_engine_name(entropy_engine)
        self._entropy_engine = entropy_engine
        if isinstance(src, (bytes, bytearray)):
            self._f = _stdio.BytesIO(bytes(src))
            self._own = True
        elif hasattr(src, "seek"):
            self._f = src
            self._own = False
        else:
            self._f = open(src, "rb")
            self._own = True
        self._io_lock = threading.Lock()   # seek+read must be atomic
        try:
            self._f.seek(0, 2)
            self._size = self._f.tell()
            self.version = fmt.parse_header(
                self._read_at(0, min(fmt.HEADER_SIZE, self._size)))
            idx_off, idx_len, idx_crc = fmt.parse_footer(
                self._read_at(max(0, self._size - fmt.FOOTER_SIZE),
                              min(fmt.FOOTER_SIZE, self._size)))
            if idx_off + idx_len + fmt.FOOTER_SIZE > self._size:
                raise ValueError("truncated TACZ file: index out of bounds")
            index = self._read_at(idx_off, idx_len)
            if fmt.index_crc(index) != idx_crc:
                raise ValueError("corrupt TACZ file: index CRC mismatch")
            # the index CRC uniquely identifies the snapshot's content —
            # the serving layer's hot-swap check compares it footer-to-footer
            self.index_crc = idx_crc & 0xFFFFFFFF
            self.levels: list[fmt.LevelEntry] = fmt.parse_index(
                index, version=self.version)
            # optional TACF frontier section between index and footer:
            # absent (zero gap) or corrupt → frontier=None, never raise
            # (a pre-frontier file must keep opening, and a damaged
            # section must degrade to default-variant serving)
            self.frontier: frt.Frontier | None = None
            self.frontier_error: str | None = None
            gap = (self._size - fmt.FOOTER_SIZE) - (idx_off + idx_len)
            if gap > 0:
                try:
                    self.frontier = frt.parse_section(
                        self._read_at(idx_off + idx_len, gap))
                except ValueError as exc:
                    self.frontier_error = str(exc)
        except BaseException:
            # validation raises for exactly the files callers probe with
            # (truncated/corrupt/non-TACZ) — don't leak the fd until GC
            self.close()
            raise
        self._codebooks: dict[int, huffman.Codebook] = {}
        self._masks: dict[int, np.ndarray | None] = {}

    # ------------------------------ plumbing -------------------------------

    def close(self) -> None:
        """Close the underlying handle (no-op for caller-owned files)."""
        if self._own:
            self._f.close()

    def __enter__(self) -> "TACZReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def n_levels(self) -> int:
        """Number of levels (or tensors) in the container."""
        return len(self.levels)

    def _read_at(self, off: int, length: int) -> bytes:
        # one reader may serve many threads (RegionServer, ThreadingHTTP):
        # the shared handle's seek+read pair must not interleave
        with self._io_lock:
            self._f.seek(off)
            buf = self._f.read(length)
        if len(buf) != length:
            raise ValueError("truncated TACZ file: unexpected EOF")
        return buf

    def _section(self, off: int, length: int, crc: int, what: str,
                 li: int) -> bytes:
        buf = self._read_at(off, length)
        if (zlib.crc32(buf) & 0xFFFFFFFF) != (crc & 0xFFFFFFFF):
            raise IOError(f"TACZ corruption: {what} section CRC mismatch "
                          f"(level {li})")
        return buf

    def _codebook(self, li: int) -> huffman.Codebook:
        if li not in self._codebooks:
            e = self.levels[li]
            self._codebooks[li] = huffman.deserialize_codebook(
                self._section(e.codebook_off, e.codebook_len,
                              e.codebook_crc, "codebook", li))
        return self._codebooks[li]

    def _mask(self, li: int) -> np.ndarray | None:
        """Level validity mask at its original shape, or None (all-True)."""
        if li not in self._masks:
            e = self.levels[li]
            if e.mask_len == 0:
                self._masks[li] = None
            else:
                raw = _decompress(
                    self._section(e.mask_off, e.mask_len, e.mask_crc,
                                  "mask", li),
                    e.mask_compressor)
                n = int(np.prod(e.shape))
                bits = np.unpackbits(np.frombuffer(raw, dtype=np.uint8),
                                     count=n)
                self._masks[li] = bits.astype(bool).reshape(e.shape)
        return self._masks[li]

    # ------------------------------ decoding -------------------------------

    @staticmethod
    def _prefix_limit(sb: fmt.SubBlockEntry, shape: tuple[int, ...],
                      sz_block: int, hi: tuple[int, int, int]) -> int:
        """Number of leading codes needed to reconstruct every cell with
        brick-local index < ``hi`` (exclusive per dim).

        Lorenzo recon of cell (i,j,k) sums the rectangular code prefix
        [0..i]×[0..j]×[0..k], and every cell of that rectangle has a
        C-order flat index ≤ flat(i,j,k) — so decoding the C-order prefix
        up to the box's high corner is sufficient.  The regression branch
        is block-local with blocks stored in C order, so the same argument
        applies at block granularity.  Entropy decode is bit-serial — this
        prefix stop is what makes partially-overlapped bricks cheap.
        """
        corner = tuple(h - 1 for h in hi)
        if sb.branch == fmt.BRANCH_REG:
            b, bgrid = sz.reg_block_grid(shape, sz_block)
            bc = tuple(c // b for c in corner)
            flat = (bc[0] * bgrid[1] + bc[1]) * bgrid[2] + bc[2]
            return (flat + 1) * b ** 3
        if sb.branch == fmt.BRANCH_LORENZO:
            flat = (corner[0] * shape[1] + corner[1]) * shape[2] + corner[2]
            return flat + 1
        return sb.n_codes   # interp is global — no partial decode

    def _payload_parts(self, li: int, sb: fmt.SubBlockEntry,
                       shape: tuple[int, ...],
                       ) -> tuple[bytes, np.ndarray | None]:
        """Fetch + CRC-check one payload → (decompressed code bytes, betas).

        This is the I/O half of the payload path; entropy decode happens
        in :meth:`_decode_payloads` so many payloads can share one
        batched engine launch.
        """
        e = self.levels[li]
        payload = self._read_at(sb.payload_off, sb.payload_len)
        if (zlib.crc32(payload) & 0xFFFFFFFF) != sb.crc:
            raise IOError(f"TACZ corruption: sub-block payload CRC mismatch "
                          f"(level {li}, offset {sb.payload_off})")
        betas = None
        if sb.betas_len:
            _, bgrid = sz.reg_block_grid(shape, e.sz_block)
            betas = np.frombuffer(payload, dtype="<f4",
                                  count=int(np.prod(bgrid)) * 4,
                                  offset=0).reshape(bgrid + (4,))
        code_bytes = _decompress(payload[sb.betas_len:], sb.compressor)
        return code_bytes, betas

    def _decode_payloads(self, li: int, jobs,
                         ) -> list[tuple[np.ndarray, np.ndarray | None]]:
        """(codes, betas) per ``(sub-block entry, shape, limit)`` job.

        All CODEC_HUFFMAN payloads of the batch go through **one**
        ``EntropyEngine.decode_payloads`` launch (the level's shared
        codebook covers them all); RAW_I16/I32 payloads decode directly.
        Each codes array has ``sb.n_codes`` entries; with a ``limit``
        only the leading ``limit`` are decoded (the rest are zeros and
        unspecified for reconstruction purposes).
        """
        out: list[tuple[np.ndarray, np.ndarray | None] | None] = \
            [None] * len(jobs)
        huff: list[tuple[int, tuple[bytes, int, int]]] = []
        metas: list[tuple[fmt.SubBlockEntry, int, np.ndarray | None]] = []
        for pos, (sb, shape, limit) in enumerate(jobs):
            code_bytes, betas = self._payload_parts(li, sb, shape)
            n_decode = (sb.n_codes if limit is None
                        else min(int(limit), sb.n_codes))
            metas.append((sb, n_decode, betas))
            if sb.codec == fmt.CODEC_HUFFMAN:
                huff.append((pos, (code_bytes, sb.nbits, n_decode)))
            elif sb.codec == fmt.CODEC_RAW_I16:
                out[pos] = (np.frombuffer(code_bytes, dtype="<i2",
                                          count=n_decode).astype(np.int64),
                            betas)
            elif sb.codec == fmt.CODEC_RAW_I32:
                out[pos] = (np.frombuffer(code_bytes, dtype="<i4",
                                          count=n_decode).astype(np.int64),
                            betas)
            else:
                raise ValueError(f"unknown payload codec {sb.codec}")
        if huff:
            with obsm.timed(obsm.ENTROPY_DECODE_SECONDS.labels(),
                            "entropy_decode"):
                decoded = entropy.get_engine(self._entropy_engine). \
                    decode_payloads(self._codebook(li),
                                    [payload for _, payload in huff])
            for (pos, _), codes in zip(huff, decoded):
                out[pos] = (codes, metas[pos][2])
        for pos, (sb, n_decode, _) in enumerate(metas):
            codes, betas = out[pos]
            if n_decode < sb.n_codes:
                full = np.zeros(sb.n_codes, dtype=np.int64)
                full[:n_decode] = codes
                out[pos] = (full, betas)
        return out

    def _subblock_codes(self, li: int, sb: fmt.SubBlockEntry,
                        shape: tuple[int, ...], limit: int | None = None,
                        ) -> tuple[np.ndarray, np.ndarray | None]:
        """Entropy-decode one payload → (codes, betas), no prediction
        replay — the single-payload case of :meth:`_decode_payloads`."""
        return self._decode_payloads(li, [(sb, shape, limit)])[0]

    def decode_subblocks(self, li: int, sbis, limits=None,
                         ) -> list[tuple[np.ndarray, np.ndarray | None]]:
        """(codes, betas) for many sub-blocks of one level — the batched
        form of :meth:`subblock_codes`, and the serving planner's entry
        point: every Huffman payload of the batch decodes in one
        ``EntropyEngine`` launch instead of one serial bit-walk each.

        :param li: level index.
        :param sbis: sub-block indices (any order, duplicates allowed).
        :param limits: optional per-entry prefix limits (None = full).
        :returns: one ``(codes, betas)`` pair per entry of ``sbis``, in
            input order, each identical to ``subblock_codes(li, sbi)``.
        """
        e = self.levels[li]
        jobs = []
        for pos, sbi in enumerate(sbis):
            limit = None if limits is None else limits[pos]
            jobs.append((e.subblocks[sbi], self.subblock_shape(li, sbi),
                         limit))
        return self._decode_payloads(li, jobs)

    def subblock_shape(self, li: int, sbi: int) -> tuple[int, ...]:
        """Decode shape of one sub-block payload (brick shape for SHE
        levels, the padded/original grid for gsp/global single payloads)."""
        e = self.levels[li]
        if e.strategy in self._SHE_STRATEGIES:
            return tuple(int(s) for s in e.subblocks[sbi].size)
        if e.strategy == fmt.STRATEGY_GSP:
            return tuple(int(s) for s in e.grid_shape)
        return tuple(int(s) for s in e.shape)

    def subblock_codes(self, li: int, sbi: int, limit: int | None = None,
                       ) -> tuple[np.ndarray, np.ndarray | None]:
        """(codes, betas) of sub-block ``sbi`` of level ``li`` — the
        planner's entry point for batched reconstruction."""
        e = self.levels[li]
        return self._subblock_codes(li, e.subblocks[sbi],
                                    self.subblock_shape(li, sbi), limit)

    def _decode_subblock(self, li: int, sb: fmt.SubBlockEntry,
                         shape: tuple[int, ...],
                         limit: int | None = None) -> np.ndarray:
        """Decode one payload into its reconstructed brick (bit-identical
        to the encoder-side recon).

        ``limit`` (from :meth:`_prefix_limit`) stops the entropy decode
        after the first ``limit`` codes: cells whose code rectangle lies
        inside the prefix reconstruct bit-identically, later cells are
        unspecified — only the ROI path passes it, and it never reads
        those cells.
        """
        e = self.levels[li]
        codes, betas = self._subblock_codes(li, sb, shape, limit)
        return sz.decode_codes(codes, shape, e.eb,
                               branch=fmt.BRANCH_NAMES[sb.branch],
                               block=e.sz_block, betas=betas)

    def _decode_bricks(self, li: int, jobs) -> list[np.ndarray]:
        """Reconstructed bricks for many ``(sbi, limit)`` jobs of one
        SHE level — the fully batched cold path: one entropy-engine
        launch over every payload, then one ``sz.decode_codes_batched``
        per (shape, branch) group.  Each brick is bit-identical to
        ``_decode_subblock`` on the same (sub-block, limit).
        """
        e = self.levels[li]
        sbis = [sbi for sbi, _ in jobs]
        decoded = self.decode_subblocks(li, sbis,
                                        [lim for _, lim in jobs])
        groups: dict[tuple[tuple[int, ...], int], list[int]] = {}
        for pos, sbi in enumerate(sbis):
            key = (self.subblock_shape(li, sbi), e.subblocks[sbi].branch)
            groups.setdefault(key, []).append(pos)
        out: list[np.ndarray | None] = [None] * len(jobs)
        for (shape, branch), poss in groups.items():
            codes = np.stack([decoded[p][0] for p in poss])
            betas = (np.stack([decoded[p][1] for p in poss])
                     if branch == fmt.BRANCH_REG else None)
            recon = sz.decode_codes_batched(
                codes, shape, e.eb, branch=fmt.BRANCH_NAMES[branch],
                block=e.sz_block, betas=betas)
            for p, brick in zip(poss, recon):
                out[p] = np.ascontiguousarray(brick)
        return out

    def read_level(self, li: int) -> np.ndarray:
        """Full decode of one level.

        :param li: level index (file order).
        :returns: float32 reconstruction at the level's original shape,
            bit-identical to the in-memory ``compress_amr`` recon.
        :raises IndexError: if ``li`` is out of range.
        :raises IOError: if a section or payload fails its CRC check.
        """
        e = self.levels[li]
        mask = self._mask(li)
        if e.strategy in self._SHE_STRATEGIES:
            acc = np.zeros(e.grid_shape, dtype=np.float32)
            bricks = self._decode_bricks(
                li, [(sbi, None) for sbi in range(len(e.subblocks))])
            for sb, brick in zip(e.subblocks, bricks):
                sl = tuple(slice(o, o + s) for o, s in zip(sb.origin, sb.size))
                acc[sl] = brick
            recon = acc[tuple(slice(0, s) for s in e.shape)]
            if mask is not None:
                recon = np.where(mask, recon, 0.0)
            return recon.astype(np.float32)
        if e.strategy == fmt.STRATEGY_GSP:
            padded = self._decode_subblock(li, e.subblocks[0], e.grid_shape)
            m = mask if mask is not None else np.ones(e.shape, dtype=bool)
            grid = make_block_grid(np.zeros(e.shape, dtype=np.float32), m,
                                   unit=e.unit)
            return gsp_unpad(padded, grid)[
                tuple(slice(0, s) for s in e.shape)]
        if e.strategy == fmt.STRATEGY_GLOBAL:
            recon = self._decode_subblock(li, e.subblocks[0], e.shape)
            if mask is not None:
                recon = np.where(mask, recon, 0.0).astype(np.float32)
            return recon
        raise ValueError(f"unknown strategy {e.strategy}")

    def read(self) -> list[np.ndarray]:
        """Full decode of every level.

        :returns: one float32 reconstruction per level, in file order.
        :raises IOError: if a section or payload fails its CRC check.
        """
        return [self.read_level(i) for i in range(self.n_levels)]

    # ----------------------- ROI machinery (shared) ------------------------
    # read_roi and the serving layer (repro.serving.regions) are the same
    # code path: box mapping, sub-block intersection, and crop assembly live
    # here; only *where the decoded brick comes from* differs (prefix-stop
    # entropy decode here, the byte-budgeted sub-block cache there).

    def level_box(self, li: int, box: Box) -> Box:
        """Map a finest-grid box into level ``li`` cells.

        :param li: level index.
        :param box: three half-open ``(lo, hi)`` ranges in finest cells.
        :returns: the box in level cells — lows floored, highs ceiled
            through the coarsening ratio, both clipped to the level
            extent (may be empty, ``hi <= lo``).
        :raises ValueError: if the level is not 3-D.
        """
        e = self.levels[li]
        if e.rank != 3:
            raise ValueError("ROI reads need 3D levels")
        r = max(int(e.ratio), 1)
        return tuple(
            (min(max(lo // r, 0), s), min(-(-hi // r), s))
            for (lo, hi), s in zip(box, e.shape))

    def intersecting_subblocks(self, li: int, lbox: Box,
                               ) -> list[tuple[int, Box]]:
        """Sub-blocks of level ``li`` whose cuboids overlap ``lbox``.

        :param li: level index.
        :param lbox: three half-open ranges in *level* cells.
        :returns: ``(sub_block_index, intersection_box)`` pairs in index
            order; the intersection is again in level cells.
        """
        e = self.levels[li]
        out: list[tuple[int, Box]] = []
        for i, sb in enumerate(e.subblocks):
            isect = tuple(
                (max(lo, o), min(hi, o + s))
                for (lo, hi), o, s in zip(lbox, sb.origin, sb.size))
            if all(hi > lo for lo, hi in isect):
                out.append((i, isect))
        return out

    def subblock_keys(self, levels: list[int] | None = None,
                      ) -> list[tuple[int, int]]:
        """Enumerate every ``(level, sub_block)`` key in the container.

        SHE levels contribute one key per partition sub-block; gsp/global
        levels contribute a single ``(level, WHOLE_LEVEL)`` key (their one
        payload decodes as a unit).  This is the key universe that cache
        entries and consistent-hash shard placement range over — a shard
        filter intersects it with a shard map to learn which payloads it
        owns.

        :param levels: restrict enumeration to these level indices
            (default: every level, in file order).
        :returns: list of ``(level_index, sub_block_index)`` tuples, file
            order; ``sub_block_index`` is :data:`WHOLE_LEVEL` for
            single-payload levels.
        :raises IndexError: if ``levels`` names an out-of-range level.
        """
        lis = range(self.n_levels) if levels is None else levels
        out: list[tuple[int, int]] = []
        for li in lis:
            e = self.levels[li]
            if e.strategy in self._SHE_STRATEGIES:
                out.extend((li, sbi) for sbi in range(len(e.subblocks)))
            else:
                out.append((li, WHOLE_LEVEL))
        return out

    def level_signature(self, li: int) -> tuple:
        """Content signature of one level, independent of byte placement.

        Two snapshots whose signatures match for a level reconstruct that
        level bit-identically: the signature covers the decode-relevant
        index fields (shape, strategy, error bound, per-sub-block
        geometry/branch/codec) plus the CRC32 of every stored section —
        codebook, mask, and each payload — but **not** file offsets, so a
        level whose bytes merely moved (an earlier level changed size on
        republish) still matches.  The serving layer uses this to carry
        decoded-brick cache entries across snapshot hot-swaps.

        :param li: level index.
        :returns: an opaque hashable tuple; compare with ``==`` only.
        :raises IndexError: if ``li`` is out of range.
        """
        e = self.levels[li]
        return (e.shape, e.grid_shape, e.strategy, e.algorithm, e.unit,
                e.sz_block, e.ratio, e.eb, e.n_values,
                e.codebook_crc & 0xFFFFFFFF, e.mask_len,
                e.mask_crc & 0xFFFFFFFF, e.mask_compressor,
                tuple((sb.origin, sb.size, sb.branch, sb.codec,
                       sb.payload_len, sb.nbits, sb.n_codes, sb.betas_len,
                       sb.crc & 0xFFFFFFFF) for sb in e.subblocks))

    def read_level_box(self, li: int, lbox: Box) -> np.ndarray:
        """Decode one level's crop of a box given in *level* cells.

        Unlike :meth:`read_roi` (whose box is in finest-grid cells and is
        mapped through every level's ratio), this takes a single level and
        a box already expressed in that level's own cells — the shape the
        sharded router's local-fallback path works in.  The box is clipped
        to the level extent; only intersecting sub-blocks are decoded,
        with the same prefix-stop entropy decode as ``read_roi``.

        :param li: level index.
        :param lbox: three half-open ``(lo, hi)`` ranges in level cells.
        :returns: float32 crop of shape ``(hi-lo, ...)`` after clipping —
            bit-identical to slicing the full level reconstruction.
        :raises IndexError: if ``li`` is out of range.
        :raises ValueError: if ``lbox`` is not three ranges.
        """
        if len(lbox) != 3:
            raise ValueError("box must be ((x0,x1),(y0,y1),(z0,z1))")
        e = self.levels[li]
        clipped = tuple((min(max(int(lo), 0), s), min(max(int(hi), 0), s))
                        for (lo, hi), s in zip(lbox, e.shape))
        return self.assemble_level_roi(li, clipped,
                                       self._fetch_brick_prefix,
                                       self.read_level,
                                       fetch_bricks=self._fetch_bricks_prefix)

    def assemble_level_roi(self, li: int, lbox: Box, fetch_brick,
                           fetch_level, tasks=None,
                           fetch_bricks=None) -> np.ndarray:
        """Assemble one level's crop from decoded bricks.

        ``fetch_brick(li, sbi, local_hi)`` must return sub-block ``sbi``'s
        reconstruction, valid at least on brick-local cells below
        ``local_hi`` (exclusive); ``fetch_level(li)`` must return the full
        level reconstruction (gsp/global levels — their single payload is
        not block-local).  ``tasks`` may carry a precomputed
        ``intersecting_subblocks(li, lbox)`` result (the serving planner
        already ran the scan).  ``fetch_bricks(li, [(sbi, local_hi)])``,
        when given, replaces the per-brick calls with one batched fetch
        for the whole SHE task list (the cold ROI path routes this at the
        batched entropy engine).  Masking and crop placement are
        identical for every caller, which is what keeps cached serving
        bit-identical to :meth:`read_roi`.
        """
        e = self.levels[li]
        bshape = tuple(max(hi - lo, 0) for lo, hi in lbox)
        if 0 in bshape:
            return np.zeros(bshape, dtype=np.float32)
        if e.strategy in self._SHE_STRATEGIES:
            if tasks is None:
                tasks = self.intersecting_subblocks(li, lbox)
            acc = np.zeros(bshape, dtype=np.float32)
            if not tasks:      # nothing decoded → all zeros; masking is a
                return acc     # no-op, so skip the mask-section read
            jobs = [(sbi, tuple(hi - o for (_, hi), o
                                in zip(isect, e.subblocks[sbi].origin)))
                    for sbi, isect in tasks]
            bricks = (fetch_bricks(li, jobs) if fetch_bricks is not None
                      else [fetch_brick(li, sbi, hi) for sbi, hi in jobs])
            for (sbi, isect), brick in zip(tasks, bricks):
                sb = e.subblocks[sbi]
                src = tuple(slice(lo - o, hi - o) for (lo, hi), o
                            in zip(isect, sb.origin))
                dst = tuple(slice(lo - b0, hi - b0) for (lo, hi), (b0, _)
                            in zip(isect, lbox))
                acc[dst] = brick[src]
            mask = self._mask(li)
            if mask is not None:
                mcrop = mask[tuple(slice(lo, hi) for lo, hi in lbox)]
                acc = np.where(mcrop, acc, 0.0).astype(np.float32)
            return acc
        # gsp/global levels have one global payload — decode fully,
        # then crop (interpolation/padding are not block-local)
        return fetch_level(li)[tuple(slice(lo, hi) for lo, hi in lbox)]

    def _fetch_brick_prefix(self, li: int, sbi: int,
                            local_hi: tuple[int, int, int]) -> np.ndarray:
        """read_roi's brick source: prefix-stop entropy decode up to the
        box's high corner (C-order prefix ⊇ Lorenzo code rectangle)."""
        e = self.levels[li]
        sb = e.subblocks[sbi]
        limit = self._prefix_limit(sb, sb.size, e.sz_block, local_hi)
        return self._decode_subblock(li, sb, sb.size, limit=limit)

    def _fetch_bricks_prefix(self, li: int, jobs) -> list[np.ndarray]:
        """Batched :meth:`_fetch_brick_prefix`: same prefix limits, one
        entropy launch + one batched recon per (shape, branch) group."""
        e = self.levels[li]
        return self._decode_bricks(
            li, [(sbi, self._prefix_limit(e.subblocks[sbi],
                                          e.subblocks[sbi].size,
                                          e.sz_block, local_hi))
                 for sbi, local_hi in jobs])

    def read_roi(self, box: Box) -> list[ROILevel]:
        """Decode only the region of interest.

        ``box`` is three half-open ``(lo, hi)`` ranges in *finest-grid*
        cells.  Per level the box is mapped through the coarsening ratio
        (floor/ceil, then clipped to the level extent) and only sub-blocks
        intersecting it are decoded.  Each returned crop is bit-identical
        to slicing that level's full reconstruction with the same box.
        """
        if len(box) != 3:
            raise ValueError("box must be ((x0,x1),(y0,y1),(z0,z1))")
        out: list[ROILevel] = []
        for li, e in enumerate(self.levels):
            lbox = self.level_box(li, box)
            data = self.assemble_level_roi(
                li, lbox, self._fetch_brick_prefix, self.read_level,
                fetch_bricks=self._fetch_bricks_prefix)
            out.append(ROILevel(level=li, ratio=max(int(e.ratio), 1),
                                box=lbox, data=data))
        return out

    def verify(self) -> bool:
        """Check every section and payload CRC (the index CRC was checked
        at open).

        :returns: True when every stored byte range checks out.
        :raises IOError: at the first corrupt byte range, naming the
            level and section.
        """
        for li, e in enumerate(self.levels):
            if e.codebook_len:
                self._section(e.codebook_off, e.codebook_len,
                              e.codebook_crc, "codebook", li)
            if e.mask_len:
                self._section(e.mask_off, e.mask_len, e.mask_crc, "mask", li)
            for sb in e.subblocks:
                payload = self._read_at(sb.payload_off, sb.payload_len)
                if (zlib.crc32(payload) & 0xFFFFFFFF) != sb.crc:
                    raise IOError(
                        f"TACZ corruption: sub-block payload CRC mismatch "
                        f"(level {li}, offset {sb.payload_off})")
        return True


def probe_index_crc(path) -> int | None:
    """Read a snapshot's identity CRC — nothing else.

    The cheap snapshot-identity probe the serving layer's hot-swap checks
    run per request: the CRC uniquely identifies a published snapshot's
    content, so comparing it against an open reader's ``index_crc`` tells
    whether the file was atomically republished.  For a single-file
    snapshot that is the 20-byte footer's index CRC; for a multi-part
    snapshot directory it is the manifest's own CRC (``manifest.json``
    is the commit point — part files only count once it names them).

    :param path: ``.tacz`` file path or multi-part snapshot directory.
    :returns: the CRC as an unsigned 32-bit int, or None when the file is
        missing, truncated, or not a TACZ container (a half-written state
        is never adopted — the writer publishes atomically).
    """
    from . import manifest as _manifest
    if _manifest.is_multipart(path):
        return _manifest.probe_crc(path)
    try:
        with open(path, "rb") as f:
            f.seek(-fmt.FOOTER_SIZE, os.SEEK_END)
            _, _, crc = fmt.parse_footer(f.read(fmt.FOOTER_SIZE))
    except (OSError, ValueError):
        return None
    return crc & 0xFFFFFFFF


def open_snapshot(src, *, entropy_engine: str = "auto") -> TACZReader:
    """Open a snapshot — single-file or multi-part — behind one surface.

    A multi-part snapshot directory (or its ``manifest.json``) yields a
    :class:`repro.io.parallel.MultiPartReader`; anything else — a
    ``.tacz`` path, raw bytes, or a seekable file object — yields a
    plain :class:`TACZReader`.  Both expose the same read surface
    (``read``/``read_roi``/``subblock_keys``/``level_signature``/...),
    which is what lets the serving stack treat them interchangeably.

    :param src: snapshot path (file or directory), bytes, or file object.
    :param entropy_engine: payload-decode engine, forwarded to the reader
        (see :class:`TACZReader`).
    :returns: an open reader; the caller owns :meth:`TACZReader.close`.
    :raises ValueError: if the snapshot fails validation.
    :raises OSError: if the path cannot be opened.
    """
    from . import manifest as _manifest
    if _manifest.is_multipart(src):
        from .parallel import MultiPartReader
        return MultiPartReader(src, entropy_engine=entropy_engine)
    return TACZReader(src, entropy_engine=entropy_engine)


def read(path) -> list[np.ndarray]:
    """Decode every level of ``path``.

    :param path: file path or container bytes.
    :returns: one float32 reconstruction per level, file order.
    :raises ValueError: if the bytes are not a valid TACZ container.
    :raises IOError: if a section or payload fails its CRC check.
    """
    with TACZReader(path) as rd:
        return rd.read()


def read_roi(path, box: Box) -> list[ROILevel]:
    """ROI decode of ``path`` — see :meth:`TACZReader.read_roi`.

    :param path: file path or container bytes.
    :param box: three half-open ``(lo, hi)`` ranges in finest-grid cells.
    :returns: one :class:`ROILevel` crop per level, finest first.
    :raises ValueError: if the container or box is malformed.
    :raises IOError: if a touched payload fails its CRC check.
    """
    with TACZReader(path) as rd:
        return rd.read_roi(box)
