"""TACZ reader: full decode, region-of-interest decode, corruption checks.

The reader never scans the file: it parses the footer + CRC'd index, then
seeks straight to the byte ranges it needs.  Full decode touches every
payload; :meth:`TACZReader.read_roi` touches only the sub-blocks whose
cuboids intersect the query box — on partition-heavy TAC+ levels that is
the difference between decoding the whole snapshot and decoding a few
bricks (the access pattern AMR visualization/analysis consumers actually
have).  Both paths reproduce the in-memory ``compress_amr`` reconstruction
bit-identically.
"""
from __future__ import annotations

import io as _stdio
import threading
import zlib
from dataclasses import dataclass

import numpy as np

from repro.core import huffman, sz
from repro.core.blocks import make_block_grid
from repro.core.compat import HAVE_ZSTD, zstd_decompress
from repro.core.gsp import gsp_unpad

from . import format as fmt

__all__ = ["ROILevel", "TACZReader", "read", "read_roi"]

Box = tuple[tuple[int, int], tuple[int, int], tuple[int, int]]


@dataclass
class ROILevel:
    """One level's crop of a region-of-interest read."""

    level: int                    # level index in the file
    ratio: int                    # coarsening ratio vs the finest grid
    box: Box                      # the decoded box, in *level* cells
    data: np.ndarray              # recon crop, shape = box extents

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(hi - lo for lo, hi in self.box)


def _decompress(buf: bytes, compressor: int) -> bytes:
    if compressor == fmt.COMPRESSOR_NONE:
        return buf
    if compressor == fmt.COMPRESSOR_ZLIB:
        return zlib.decompress(buf)
    if compressor == fmt.COMPRESSOR_ZSTD:
        if not HAVE_ZSTD:
            raise ModuleNotFoundError(
                "this TACZ file was written with zstd payloads but "
                "zstandard is not installed")
        return zstd_decompress(buf)
    raise ValueError(f"unknown compressor {compressor}")


class TACZReader:
    """Random-access reader over a TACZ container (file path or bytes)."""

    _SHE_STRATEGIES = (fmt.STRATEGY_OPST, fmt.STRATEGY_AKDTREE,
                       fmt.STRATEGY_NAST)

    def __init__(self, src):
        if isinstance(src, (bytes, bytearray)):
            self._f = _stdio.BytesIO(bytes(src))
            self._own = True
        elif hasattr(src, "seek"):
            self._f = src
            self._own = False
        else:
            self._f = open(src, "rb")
            self._own = True
        self._io_lock = threading.Lock()   # seek+read must be atomic
        try:
            self._f.seek(0, 2)
            self._size = self._f.tell()
            self.version = fmt.parse_header(
                self._read_at(0, min(fmt.HEADER_SIZE, self._size)))
            idx_off, idx_len, idx_crc = fmt.parse_footer(
                self._read_at(max(0, self._size - fmt.FOOTER_SIZE),
                              min(fmt.FOOTER_SIZE, self._size)))
            if idx_off + idx_len + fmt.FOOTER_SIZE > self._size:
                raise ValueError("truncated TACZ file: index out of bounds")
            index = self._read_at(idx_off, idx_len)
            if fmt.index_crc(index) != idx_crc:
                raise ValueError("corrupt TACZ file: index CRC mismatch")
            # the index CRC uniquely identifies the snapshot's content —
            # the serving layer's hot-swap check compares it footer-to-footer
            self.index_crc = idx_crc & 0xFFFFFFFF
            self.levels: list[fmt.LevelEntry] = fmt.parse_index(
                index, version=self.version)
        except BaseException:
            # validation raises for exactly the files callers probe with
            # (truncated/corrupt/non-TACZ) — don't leak the fd until GC
            self.close()
            raise
        self._codebooks: dict[int, huffman.Codebook] = {}
        self._masks: dict[int, np.ndarray | None] = {}

    # ------------------------------ plumbing -------------------------------

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self) -> "TACZReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def _read_at(self, off: int, length: int) -> bytes:
        # one reader may serve many threads (RegionServer, ThreadingHTTP):
        # the shared handle's seek+read pair must not interleave
        with self._io_lock:
            self._f.seek(off)
            buf = self._f.read(length)
        if len(buf) != length:
            raise ValueError("truncated TACZ file: unexpected EOF")
        return buf

    def _section(self, off: int, length: int, crc: int, what: str,
                 li: int) -> bytes:
        buf = self._read_at(off, length)
        if (zlib.crc32(buf) & 0xFFFFFFFF) != (crc & 0xFFFFFFFF):
            raise IOError(f"TACZ corruption: {what} section CRC mismatch "
                          f"(level {li})")
        return buf

    def _codebook(self, li: int) -> huffman.Codebook:
        if li not in self._codebooks:
            e = self.levels[li]
            self._codebooks[li] = huffman.deserialize_codebook(
                self._section(e.codebook_off, e.codebook_len,
                              e.codebook_crc, "codebook", li))
        return self._codebooks[li]

    def _mask(self, li: int) -> np.ndarray | None:
        """Level validity mask at its original shape, or None (all-True)."""
        if li not in self._masks:
            e = self.levels[li]
            if e.mask_len == 0:
                self._masks[li] = None
            else:
                raw = _decompress(
                    self._section(e.mask_off, e.mask_len, e.mask_crc,
                                  "mask", li),
                    e.mask_compressor)
                n = int(np.prod(e.shape))
                bits = np.unpackbits(np.frombuffer(raw, dtype=np.uint8),
                                     count=n)
                self._masks[li] = bits.astype(bool).reshape(e.shape)
        return self._masks[li]

    # ------------------------------ decoding -------------------------------

    @staticmethod
    def _prefix_limit(sb: fmt.SubBlockEntry, shape: tuple[int, ...],
                      sz_block: int, hi: tuple[int, int, int]) -> int:
        """Number of leading codes needed to reconstruct every cell with
        brick-local index < ``hi`` (exclusive per dim).

        Lorenzo recon of cell (i,j,k) sums the rectangular code prefix
        [0..i]×[0..j]×[0..k], and every cell of that rectangle has a
        C-order flat index ≤ flat(i,j,k) — so decoding the C-order prefix
        up to the box's high corner is sufficient.  The regression branch
        is block-local with blocks stored in C order, so the same argument
        applies at block granularity.  Entropy decode is bit-serial — this
        prefix stop is what makes partially-overlapped bricks cheap.
        """
        corner = tuple(h - 1 for h in hi)
        if sb.branch == fmt.BRANCH_REG:
            b, bgrid = sz.reg_block_grid(shape, sz_block)
            bc = tuple(c // b for c in corner)
            flat = (bc[0] * bgrid[1] + bc[1]) * bgrid[2] + bc[2]
            return (flat + 1) * b ** 3
        if sb.branch == fmt.BRANCH_LORENZO:
            flat = (corner[0] * shape[1] + corner[1]) * shape[2] + corner[2]
            return flat + 1
        return sb.n_codes   # interp is global — no partial decode

    def _subblock_codes(self, li: int, sb: fmt.SubBlockEntry,
                        shape: tuple[int, ...], limit: int | None = None,
                        ) -> tuple[np.ndarray, np.ndarray | None]:
        """Entropy-decode one payload → (codes, betas), no prediction replay.

        The codes array always has ``sb.n_codes`` entries; with ``limit``
        only the leading ``limit`` are decoded (the rest are zeros and
        unspecified for reconstruction purposes).  This is the shared
        payload path of :meth:`_decode_subblock` (serial recon) and the
        serving-side decode planner (batched recon through
        ``sz.decode_codes_batched``).
        """
        e = self.levels[li]
        payload = self._read_at(sb.payload_off, sb.payload_len)
        if (zlib.crc32(payload) & 0xFFFFFFFF) != sb.crc:
            raise IOError(f"TACZ corruption: sub-block payload CRC mismatch "
                          f"(level {li}, offset {sb.payload_off})")
        betas = None
        if sb.betas_len:
            _, bgrid = sz.reg_block_grid(shape, e.sz_block)
            betas = np.frombuffer(payload, dtype="<f4",
                                  count=int(np.prod(bgrid)) * 4,
                                  offset=0).reshape(bgrid + (4,))
        n_decode = sb.n_codes if limit is None else min(limit, sb.n_codes)
        code_bytes = _decompress(payload[sb.betas_len:], sb.compressor)
        if sb.codec == fmt.CODEC_HUFFMAN:
            codes = huffman.decode(self._codebook(li),
                                   np.frombuffer(code_bytes, dtype=np.uint8),
                                   sb.nbits, n_decode)
        elif sb.codec == fmt.CODEC_RAW_I16:
            codes = np.frombuffer(code_bytes, dtype="<i2",
                                  count=n_decode).astype(np.int64)
        elif sb.codec == fmt.CODEC_RAW_I32:
            codes = np.frombuffer(code_bytes, dtype="<i4",
                                  count=n_decode).astype(np.int64)
        else:
            raise ValueError(f"unknown payload codec {sb.codec}")
        if n_decode < sb.n_codes:
            full = np.zeros(sb.n_codes, dtype=np.int64)
            full[:n_decode] = codes
            codes = full
        return codes, betas

    def subblock_shape(self, li: int, sbi: int) -> tuple[int, ...]:
        """Decode shape of one sub-block payload (brick shape for SHE
        levels, the padded/original grid for gsp/global single payloads)."""
        e = self.levels[li]
        if e.strategy in self._SHE_STRATEGIES:
            return tuple(int(s) for s in e.subblocks[sbi].size)
        if e.strategy == fmt.STRATEGY_GSP:
            return tuple(int(s) for s in e.grid_shape)
        return tuple(int(s) for s in e.shape)

    def subblock_codes(self, li: int, sbi: int, limit: int | None = None,
                       ) -> tuple[np.ndarray, np.ndarray | None]:
        """(codes, betas) of sub-block ``sbi`` of level ``li`` — the
        planner's entry point for batched reconstruction."""
        e = self.levels[li]
        return self._subblock_codes(li, e.subblocks[sbi],
                                    self.subblock_shape(li, sbi), limit)

    def _decode_subblock(self, li: int, sb: fmt.SubBlockEntry,
                         shape: tuple[int, ...],
                         limit: int | None = None) -> np.ndarray:
        """Decode one payload into its reconstructed brick (bit-identical
        to the encoder-side recon).

        ``limit`` (from :meth:`_prefix_limit`) stops the entropy decode
        after the first ``limit`` codes: cells whose code rectangle lies
        inside the prefix reconstruct bit-identically, later cells are
        unspecified — only the ROI path passes it, and it never reads
        those cells.
        """
        e = self.levels[li]
        codes, betas = self._subblock_codes(li, sb, shape, limit)
        return sz.decode_codes(codes, shape, e.eb,
                               branch=fmt.BRANCH_NAMES[sb.branch],
                               block=e.sz_block, betas=betas)

    def read_level(self, li: int) -> np.ndarray:
        """Full decode of one level → recon at its original shape."""
        e = self.levels[li]
        mask = self._mask(li)
        if e.strategy in self._SHE_STRATEGIES:
            acc = np.zeros(e.grid_shape, dtype=np.float32)
            for sb in e.subblocks:
                brick = self._decode_subblock(li, sb, sb.size)
                sl = tuple(slice(o, o + s) for o, s in zip(sb.origin, sb.size))
                acc[sl] = brick
            recon = acc[tuple(slice(0, s) for s in e.shape)]
            if mask is not None:
                recon = np.where(mask, recon, 0.0)
            return recon.astype(np.float32)
        if e.strategy == fmt.STRATEGY_GSP:
            padded = self._decode_subblock(li, e.subblocks[0], e.grid_shape)
            m = mask if mask is not None else np.ones(e.shape, dtype=bool)
            grid = make_block_grid(np.zeros(e.shape, dtype=np.float32), m,
                                   unit=e.unit)
            return gsp_unpad(padded, grid)[
                tuple(slice(0, s) for s in e.shape)]
        if e.strategy == fmt.STRATEGY_GLOBAL:
            recon = self._decode_subblock(li, e.subblocks[0], e.shape)
            if mask is not None:
                recon = np.where(mask, recon, 0.0).astype(np.float32)
            return recon
        raise ValueError(f"unknown strategy {e.strategy}")

    def read(self) -> list[np.ndarray]:
        """Full decode of every level, in file order."""
        return [self.read_level(i) for i in range(self.n_levels)]

    # ----------------------- ROI machinery (shared) ------------------------
    # read_roi and the serving layer (repro.serving.regions) are the same
    # code path: box mapping, sub-block intersection, and crop assembly live
    # here; only *where the decoded brick comes from* differs (prefix-stop
    # entropy decode here, the byte-budgeted sub-block cache there).

    def level_box(self, li: int, box: Box) -> Box:
        """Map a finest-grid box into level ``li`` cells (floor/ceil through
        the coarsening ratio, clipped to the level extent)."""
        e = self.levels[li]
        if e.rank != 3:
            raise ValueError("ROI reads need 3D levels")
        r = max(int(e.ratio), 1)
        return tuple(
            (min(max(lo // r, 0), s), min(-(-hi // r), s))
            for (lo, hi), s in zip(box, e.shape))

    def intersecting_subblocks(self, li: int, lbox: Box,
                               ) -> list[tuple[int, Box]]:
        """(sub-block index, intersection box in level cells) for every
        sub-block of level ``li`` whose cuboid overlaps ``lbox``."""
        e = self.levels[li]
        out: list[tuple[int, Box]] = []
        for i, sb in enumerate(e.subblocks):
            isect = tuple(
                (max(lo, o), min(hi, o + s))
                for (lo, hi), o, s in zip(lbox, sb.origin, sb.size))
            if all(hi > lo for lo, hi in isect):
                out.append((i, isect))
        return out

    def assemble_level_roi(self, li: int, lbox: Box, fetch_brick,
                           fetch_level, tasks=None) -> np.ndarray:
        """Assemble one level's crop from decoded bricks.

        ``fetch_brick(li, sbi, local_hi)`` must return sub-block ``sbi``'s
        reconstruction, valid at least on brick-local cells below
        ``local_hi`` (exclusive); ``fetch_level(li)`` must return the full
        level reconstruction (gsp/global levels — their single payload is
        not block-local).  ``tasks`` may carry a precomputed
        ``intersecting_subblocks(li, lbox)`` result (the serving planner
        already ran the scan).  Masking and crop placement are identical
        for every caller, which is what keeps cached serving bit-identical
        to :meth:`read_roi`.
        """
        e = self.levels[li]
        bshape = tuple(max(hi - lo, 0) for lo, hi in lbox)
        if 0 in bshape:
            return np.zeros(bshape, dtype=np.float32)
        if e.strategy in self._SHE_STRATEGIES:
            if tasks is None:
                tasks = self.intersecting_subblocks(li, lbox)
            acc = np.zeros(bshape, dtype=np.float32)
            for sbi, isect in tasks:
                sb = e.subblocks[sbi]
                local_hi = tuple(hi - o for (_, hi), o
                                 in zip(isect, sb.origin))
                brick = fetch_brick(li, sbi, local_hi)
                src = tuple(slice(lo - o, hi - o) for (lo, hi), o
                            in zip(isect, sb.origin))
                dst = tuple(slice(lo - b0, hi - b0) for (lo, hi), (b0, _)
                            in zip(isect, lbox))
                acc[dst] = brick[src]
            mask = self._mask(li)
            if mask is not None:
                mcrop = mask[tuple(slice(lo, hi) for lo, hi in lbox)]
                acc = np.where(mcrop, acc, 0.0).astype(np.float32)
            return acc
        # gsp/global levels have one global payload — decode fully,
        # then crop (interpolation/padding are not block-local)
        return fetch_level(li)[tuple(slice(lo, hi) for lo, hi in lbox)]

    def _fetch_brick_prefix(self, li: int, sbi: int,
                            local_hi: tuple[int, int, int]) -> np.ndarray:
        """read_roi's brick source: prefix-stop entropy decode up to the
        box's high corner (C-order prefix ⊇ Lorenzo code rectangle)."""
        e = self.levels[li]
        sb = e.subblocks[sbi]
        limit = self._prefix_limit(sb, sb.size, e.sz_block, local_hi)
        return self._decode_subblock(li, sb, sb.size, limit=limit)

    def read_roi(self, box: Box) -> list[ROILevel]:
        """Decode only the region of interest.

        ``box`` is three half-open ``(lo, hi)`` ranges in *finest-grid*
        cells.  Per level the box is mapped through the coarsening ratio
        (floor/ceil, then clipped to the level extent) and only sub-blocks
        intersecting it are decoded.  Each returned crop is bit-identical
        to slicing that level's full reconstruction with the same box.
        """
        if len(box) != 3:
            raise ValueError("box must be ((x0,x1),(y0,y1),(z0,z1))")
        out: list[ROILevel] = []
        for li, e in enumerate(self.levels):
            lbox = self.level_box(li, box)
            data = self.assemble_level_roi(li, lbox,
                                           self._fetch_brick_prefix,
                                           self.read_level)
            out.append(ROILevel(level=li, ratio=max(int(e.ratio), 1),
                                box=lbox, data=data))
        return out

    def verify(self) -> bool:
        """Check every section and payload CRC (the index CRC was checked
        at open).  Raises ``IOError`` at the first corrupt byte range;
        True otherwise.
        """
        for li, e in enumerate(self.levels):
            if e.codebook_len:
                self._section(e.codebook_off, e.codebook_len,
                              e.codebook_crc, "codebook", li)
            if e.mask_len:
                self._section(e.mask_off, e.mask_len, e.mask_crc, "mask", li)
            for sb in e.subblocks:
                payload = self._read_at(sb.payload_off, sb.payload_len)
                if (zlib.crc32(payload) & 0xFFFFFFFF) != sb.crc:
                    raise IOError(
                        f"TACZ corruption: sub-block payload CRC mismatch "
                        f"(level {li}, offset {sb.payload_off})")
        return True


def read(path) -> list[np.ndarray]:
    """Decode every level of ``path`` (file path or bytes)."""
    with TACZReader(path) as rd:
        return rd.read()


def read_roi(path, box: Box) -> list[ROILevel]:
    """ROI decode of ``path`` — see :meth:`TACZReader.read_roi`."""
    with TACZReader(path) as rd:
        return rd.read_roi(box)
