"""Multi-part TACZ snapshot manifest: framing, (de)serialization, probing.

A multi-part snapshot is a directory::

    snap.taczd/
      manifest.json        (published last, atomically — the commit point)
      part-0000.tacz       (each a complete, valid TACZ container)
      part-0001.tacz
      ...

Each part holds one rendezvous-hash partition of the snapshot's
``(level, sub_block)`` key universe (``repro.io.placement``); the
manifest binds the parts into one logical snapshot.  It records, per
part, the file name, size, footer ``index_crc``, and — per level — the
*global* sub-block indices the part's payloads correspond to (in the
part's local file order).  The manifest body carries its own CRC32 so a
torn or hand-edited file fails loudly, and the recorded per-part
``index_crc`` values bind the exact part bytes: a part republished
without its manifest (or vice versa) is detected at open time.

Publishing is two-phase: every part is *finalized* at ``<name>.tmp``
(index, footer, fsync) first; only when all of them succeeded are they
renamed into place, and the manifest is written last.  A crash or
worker failure at any point before the rename loop leaves
``part-*.tacz.tmp`` litter and the *old* snapshot — manifest and part
files — fully intact; a manifest never names parts that do not check
out.  ``stale_parts`` enumerates the litter; a re-run of the writer
cleans it up and converges to a valid snapshot.

Byte-level spec: ``docs/tacz_format.md`` §9 (cross-checked by
``tests/test_docs.py``).
"""
from __future__ import annotations

import json
import os
import re
import zlib

__all__ = ["MANIFEST_MAGIC", "MANIFEST_NAME", "MANIFEST_VERSION",
           "is_multipart", "load", "manifest_crc", "part_name",
           "probe_crc", "stale_parts", "write_atomic"]

MANIFEST_NAME = "manifest.json"
MANIFEST_MAGIC = "TACZM"
MANIFEST_VERSION = 1

#: Part files are named ``part-NNNN.tacz`` (zero-padded decimal index).
_PART_RE = re.compile(r"^part-(\d{4,})\.tacz$")
_TMP_RE = re.compile(r"^part-(\d{4,})\.tacz\.tmp$")


def part_name(i: int) -> str:
    """Canonical file name of part ``i`` (``part-0000.tacz`` for 0)."""
    if i < 0:
        raise ValueError("part index must be non-negative")
    return f"part-{i:04d}.tacz"


def part_stem(i: int) -> str:
    """Part name without the ``.tacz`` suffix — the id the partition's
    rendezvous hashing scores (and a part-aligned shard would use)."""
    return part_name(i)[:-len(".tacz")]


def canonical_bytes(body: dict) -> bytes:
    """The byte form the manifest CRC covers: JSON with sorted keys and
    ``(",", ":")`` separators, UTF-8 — byte-stable across writers."""
    return json.dumps(body, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def manifest_crc(body: dict) -> int:
    """CRC32 of :func:`canonical_bytes` over ``body`` (sans ``crc32``)."""
    body = {k: v for k, v in body.items() if k != "crc32"}
    return zlib.crc32(canonical_bytes(body)) & 0xFFFFFFFF


def _manifest_path(path: str) -> str:
    """Resolve a snapshot directory or direct manifest path."""
    if os.path.basename(path) == MANIFEST_NAME:
        return path
    return os.path.join(path, MANIFEST_NAME)


def write_atomic(snapshot_dir: str, body: dict) -> str:
    """Stamp ``crc32`` into ``body`` and publish it atomically.

    Written to ``manifest.json.tmp``, fsynced, then moved into place via
    ``os.replace`` — the manifest is the snapshot's commit point, so a
    crash before the replace leaves the previous snapshot (or nothing)
    fully intact.

    :param snapshot_dir: the snapshot directory.
    :param body: manifest body (``crc32`` is overwritten).
    :returns: the manifest path.
    """
    body = dict(body)
    body["crc32"] = manifest_crc(body)
    path = _manifest_path(snapshot_dir)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(body, f, sort_keys=True, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load(path: str) -> dict:
    """Read and validate a manifest (magic, version, CRC).

    :param path: snapshot directory or manifest file path.
    :returns: the manifest dict (``crc32`` verified).
    :raises ValueError: on bad magic, an unsupported version, a CRC
        mismatch, or malformed JSON.
    :raises OSError: if the file cannot be read.
    """
    mpath = _manifest_path(path)
    with open(mpath, encoding="utf-8") as f:
        try:
            body = json.load(f)
        except json.JSONDecodeError as exc:
            raise ValueError(f"corrupt multi-part manifest {mpath}: "
                             f"{exc}") from exc
    if not isinstance(body, dict) or body.get("magic") != MANIFEST_MAGIC:
        raise ValueError(f"not a TACZ multi-part manifest: {mpath}")
    if int(body.get("version", 0)) > MANIFEST_VERSION:
        raise ValueError(
            f"unsupported manifest version {body.get('version')}")
    if int(body.get("crc32", -1)) != manifest_crc(body):
        raise ValueError(f"corrupt multi-part manifest {mpath}: "
                         f"CRC mismatch")
    return body


def is_multipart(path) -> bool:
    """True when ``path`` is a multi-part snapshot directory (or its
    manifest file) — the dispatch test ``open_snapshot`` uses."""
    if not isinstance(path, (str, os.PathLike)):
        return False
    path = os.fspath(path)
    if os.path.isdir(path):
        return os.path.exists(os.path.join(path, MANIFEST_NAME))
    return os.path.basename(path) == MANIFEST_NAME and os.path.exists(path)


def probe_crc(path) -> int | None:
    """The manifest CRC of a multi-part snapshot, or None.

    The multi-part analogue of :func:`repro.io.reader.probe_index_crc`
    — one small JSON read, used by the serving layer's per-request
    hot-swap check.  Returns None when the manifest is missing, torn,
    or fails validation (a half-published state is never adopted).
    """
    try:
        return int(load(os.fspath(path))["crc32"])
    except (OSError, ValueError, KeyError, TypeError):
        return None


def stale_parts(snapshot_dir: str) -> list[str]:
    """Leftover ``part-*.tacz.tmp`` files from a crashed/killed writer.

    A published snapshot never references them (the manifest is written
    last); the parallel writer truncates and replaces them on a re-run.

    :param snapshot_dir: the snapshot directory.
    :returns: sorted tmp file names (not paths); empty when clean.
    """
    try:
        names = os.listdir(snapshot_dir)
    except OSError:
        return []
    return sorted(n for n in names if _TMP_RE.match(n))


def referenced_parts(body: dict) -> list[str]:
    """Part file names a manifest binds, in part order."""
    return [str(p["name"]) for p in body.get("parts", [])]
