"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

GShard-style dense dispatch/combine einsums: TPU-friendly (all-to-all falls
out of the sharding of the ``experts`` axis under SPMD), deterministic
shapes, capacity factor bounds the per-expert buffer.  Router in fp32 with
an auxiliary load-balancing loss (Switch §2.2).

Sharding: experts → the ``model`` mesh axis (expert parallelism).  Tokens
are dispatched with one-hot einsums; under EP the dispatch einsum lowers to
an all-to-all on the expert axis — exactly the collective the roofline
's collective term tracks for the MoE cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec, linear, rmsnorm, shard

__all__ = ["moe_specs", "moe_apply", "mlp_specs", "mlp_apply"]


def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "ln": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), cfg.dtype),
    }
    if cfg.act == "swiglu":
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"), cfg.dtype)
    return specs


def mlp_apply(params, x, cfg):
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    up = linear(xn, params["w_up"])
    if cfg.act == "swiglu":
        up = jax.nn.silu(linear(xn, params["w_gate"])) * up
    else:
        up = jax.nn.gelu(up)
    up = shard(up, "batch", None, "mlp")
    return linear(up, params["w_down"])


def moe_specs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "ln": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        "router": ParamSpec((d, e), ("embed", None), "float32"),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp"), cfg.dtype),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"), cfg.dtype),
        "w_down": ParamSpec((e, f, d), ("experts", "mlp", "embed"), cfg.dtype),
    }


def _moe_group(params, tokens, cfg):
    """Route + dispatch + expert-compute + combine for one token group.

    Scatter-based dispatch (O(g·k) index work, no O(g·e·c) one-hot einsum)
    into a per-group capacity buffer — GShard's group semantics: capacity
    is provisioned per group, so routing hot spots drop locally.
    """
    g_tokens, d = tokens.shape
    e, k = cfg.n_experts, cfg.experts_per_token

    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (g, e)
    gate_vals, sel = jax.lax.top_k(probs, k)                      # (g, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): e · Σ_e fraction_tokens · router_prob
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)            # (g, k, e)
    frac = onehot.sum(axis=(0, 1)) / (g_tokens * k)
    aux = e * jnp.sum(frac * probs.mean(0))

    capacity = max(int(cfg.capacity_factor * g_tokens * k / e), 4)
    flat_sel = sel.reshape(-1)                                    # (g·k,)
    # slot ranking via stable sort (O(n log n)) — the (n·k, e) one-hot
    # cumsum variant is counted quadratically by HloCostAnalysis and is
    # the expensive path on real hardware too (production MoEs sort)
    nk = flat_sel.shape[0]
    order = jnp.argsort(flat_sel, stable=True)
    expert_sorted = flat_sel[order]
    starts = jnp.searchsorted(expert_sorted, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) \
        - starts[expert_sorted].astype(jnp.int32)
    pos_in_expert = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos_in_expert < capacity
    gate_keep = (gate_vals.reshape(-1) * keep).astype(tokens.dtype)
    dest = jnp.where(keep, flat_sel * capacity + pos_in_expert, e * capacity)

    tok_rep = jnp.repeat(tokens, k, axis=0)                       # (g·k, d)
    buf = jnp.zeros((e * capacity + 1, d), tokens.dtype)
    buf = buf.at[dest].add(tok_rep * keep[:, None].astype(tokens.dtype))
    expert_in = buf[:-1].reshape(e, capacity, d)
    expert_in = shard(expert_in, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", expert_in,
                   params["w_up"].astype(expert_in.dtype))
    gt = jnp.einsum("ecd,edf->ecf", expert_in,
                    params["w_gate"].astype(expert_in.dtype))
    h = jax.nn.silu(gt) * h
    expert_out = jnp.einsum("ecf,efd->ecd", h,
                            params["w_down"].astype(h.dtype))
    expert_out = shard(expert_out, "experts", None, None)

    out_flat = jnp.concatenate(
        [expert_out.reshape(e * capacity, d),
         jnp.zeros((1, d), expert_out.dtype)])[dest]
    out = (out_flat * gate_keep[:, None]).reshape(g_tokens, k, d).sum(axis=1)
    return out, aux


def moe_apply(params, x, cfg, *, group_size: int = 4096,
              unroll: bool = False):
    """Returns (out, aux_loss).  x: (B, S, d).

    Tokens are processed in groups of ≤``group_size`` under a ``lax.scan``:
    the dispatch scatter's working set (capacity buffer + index tensors) is
    bounded per group instead of scaling with the full 0.5M-token batch —
    without this, SPMD replicates a multi-GB scatter across the mesh.
    """
    B, S, d = x.shape
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    tokens = xn.reshape(B * S, d)
    n = tokens.shape[0]
    # flops-variant lowering (unroll=True) uses a single group: FLOPs are
    # group-size invariant (total capacity slots are fixed at n·k·cf), and
    # unrolling hundreds of group bodies would explode compile time
    gs = n if unroll else min(group_size, n)
    while n % gs:
        gs //= 2
    n_groups = n // gs
    if n_groups == 1:
        out, aux = _moe_group(params, tokens, cfg)
        return out.reshape(B, S, d), aux

    groups = tokens.reshape(n_groups, gs, d)

    def body(aux_acc, grp):
        out, aux = _moe_group(params, grp, cfg)
        return aux_acc + aux, out

    # remat: the backward otherwise saves every group's dispatch buffers
    # and expert activations (n_groups × (e, c, d_ff) tensors)
    aux_sum, outs = jax.lax.scan(jax.checkpoint(body),
                                 jnp.zeros((), jnp.float32), groups,
                                 unroll=unroll)
    return outs.reshape(B, S, d), aux_sum / n_groups
