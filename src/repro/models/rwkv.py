"""RWKV6 "Finch" block: data-dependent per-channel decay (arXiv:2404.05892).

Time-mix with LoRA-produced dynamic decay ``w_t`` and token-shift mixing,
WKV6 linear recurrence over (head, d_head × d_head) matrix states, and the
squared-ReLU channel-mix.  Training uses a chunked form (chunk 64, fp32
decay algebra as in flash-linear-attention); decode is the exact O(1)
recurrence — the ``long_500k`` cell for this arch runs entirely on the
matrix state, no KV cache.

Applicability note (DESIGN.md §Arch-applicability): the paper's *spatial*
partitioning (OpST/AKDTree) has no analogue on these dense 2D states; the
framework-plane TAC+ integration for this arch is checkpoint/gradient
compression only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec, linear, rmsnorm, shard

__all__ = ["rwkv6_specs", "rwkv6_apply", "init_rwkv_state"]

_LORA_R = 64


def rwkv6_specs(cfg) -> dict:
    d = cfg.d_model
    nh = d // cfg.rwkv_head
    f = cfg.d_ff
    return {
        "ln_t": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        "mu_r": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "mu_k": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "mu_v": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "mu_w": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "mu_g": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "wr": ParamSpec((d, d), ("embed", "heads"), cfg.dtype),
        "wk": ParamSpec((d, d), ("embed", "heads"), cfg.dtype),
        "wv": ParamSpec((d, d), ("embed", "heads"), cfg.dtype),
        "wg": ParamSpec((d, d), ("embed", "heads"), cfg.dtype),
        "wo": ParamSpec((d, d), ("heads", "embed"), cfg.dtype),
        # dynamic decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": ParamSpec((d,), ("heads",), "float32", init="zeros"),
        "wA": ParamSpec((d, _LORA_R), ("embed", None), cfg.dtype),
        "wB": ParamSpec((_LORA_R, d), (None, "heads"), cfg.dtype),
        "u_bonus": ParamSpec((d,), ("heads",), "float32", init="zeros"),
        "gn": ParamSpec((d,), ("heads",), cfg.dtype, init="ones"),
        # channel mix
        "ln_c": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        "mu_c": ParamSpec((d,), (None,), cfg.dtype, init="zeros"),
        "ck": ParamSpec((d, f), ("embed", "mlp"), cfg.dtype),
        "cv": ParamSpec((f, d), ("mlp", "embed"), cfg.dtype),
        "cr": ParamSpec((d, d), ("embed", None), cfg.dtype),
    }


def init_rwkv_state(cfg, batch: int):
    d = cfg.d_model
    nh, hd = d // cfg.rwkv_head, cfg.rwkv_head
    # fp32 shift streams: the block computes in fp32 (see rwkv6_apply), and
    # a bf16 handoff would make the decode step see a rounded x_{t-1} the
    # train path never saw.
    return {
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, d), jnp.float32),
        "shift_c": jnp.zeros((batch, d), jnp.float32),
    }


def _token_shift(x, prev):
    """x_{t-1} stream: shift right by one, carry ``prev`` in at t=0."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def rwkv6_apply(params, x, cfg, *, mode: str, state=None,
                chunk: int = 32, unroll: bool = False):
    """Full RWKV6 block (time-mix + channel-mix).  Returns (out, state).

    The whole block runs in fp32 with a single rounding back to the model
    dtype at the residual output.  Intermediate bf16 roundings are not
    shape-stable under XLA (conversion folding elides them differently per
    fused program), so a bf16 block makes decode logits drift from train
    logits by bf16 ulps even though the recurrence is exact — fp32 ops
    round identically in every program shape, which is what the
    decode==train serve-consistency gate needs at its 1e-4 tolerance.
    """
    B, S, d = x.shape
    nh, hd = d // cfg.rwkv_head, cfg.rwkv_head
    st = state or init_rwkv_state(cfg, B)
    out_dtype = x.dtype
    f32 = jnp.float32
    x = x.astype(f32)
    params = jax.tree.map(
        lambda a: a.astype(f32) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, params)

    # ---------------- time mix ----------------
    xn = rmsnorm(x, params["ln_t"], cfg.norm_eps)
    xprev = _token_shift(xn, st["shift_t"].astype(xn.dtype))
    r = linear(_mix(xn, xprev, params["mu_r"]), params["wr"])
    k = linear(_mix(xn, xprev, params["mu_k"]), params["wk"])
    v = linear(_mix(xn, xprev, params["mu_v"]), params["wv"])
    g = linear(_mix(xn, xprev, params["mu_g"]), params["wg"])
    xw = _mix(xn, xprev, params["mu_w"])
    logw = params["w0"] + linear(
        jnp.tanh(linear(xw, params["wA"])), params["wB"]).astype(jnp.float32)
    # -log w_t, clipped to [1e-4, 2.5] so the fp32 chunked form (chunk=32,
    # exp(±Σ) factors as in flash-linear-attention) cannot overflow
    neg_decay = jnp.clip(jnp.exp(logw), 1e-4, 2.5)
    # per-head views, fp32 recurrence
    rh = r.reshape(B, S, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, S, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, S, nh, hd).astype(jnp.float32)
    lw = -neg_decay.reshape(B, S, nh, hd)                 # log w_t ≤ 0
    u = params["u_bonus"].reshape(nh, hd)

    if mode == "decode":
        Swkv = st["wkv"]
        # decode step: y = r·(S + u ⊙ k ⊗ v); S' = diag(w) S + k ⊗ v
        kv = jnp.einsum("bhi,bhj->bhij", kh[:, 0], vh[:, 0])
        y = jnp.einsum("bhi,bhij->bhj", rh[:, 0],
                       Swkv + u[None, :, :, None] * kv)
        Snew = jnp.exp(lw[:, 0])[..., None] * Swkv + kv
        y = y[:, None]                                    # (B,1,nh,hd)
        new_wkv = Snew
    else:
        pad = (-S) % chunk
        def padt(a):
            return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        rp, kp, vp, lwp = map(padt, (rh, kh, vh, lw))
        nck = (S + pad) // chunk
        def tochunks(a):
            return a.reshape(B, nck, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
        rc, kc, vc, lc = map(tochunks, (rp, kp, vp, lwp))

        def chunk_step(Sc, inp):
            rk, kk, vk, lk = inp                          # (B,c,nh,hd)
            cum = jnp.cumsum(lk, axis=1)                  # ≤ 0, decreasing
            total = cum[:, -1]                            # (B,nh,hd)
            # inter-chunk: r_i decayed to chunk start
            rdec = rk * jnp.exp(cum - lk)                 # decay *before* t
            y_inter = jnp.einsum("bihk,bhkv->bihv", rdec, Sc)
            # intra-chunk: scores_ij = Σ_k r_i w^(i-1..j) k_j  (j < i)
            a_i = rk * jnp.exp(cum - lk)
            b_j = kk * jnp.exp(-cum)
            scores = jnp.einsum("bihk,bjhk->bhij", a_i, b_j)
            mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
            scores = scores * mask[None, None]
            y_intra = jnp.einsum("bhij,bjhv->bihv", scores, vk)
            # same-step bonus term: (Σ_k r·u·k) v
            y_diag = (rk * u[None, None] * kk).sum(-1, keepdims=True) * vk
            # state to chunk end
            kdec = kk * jnp.exp(total[:, None] - cum)
            S_new = (jnp.exp(total)[..., None] * Sc
                     + jnp.einsum("bjhk,bjhv->bhkv", kdec, vk))
            return S_new, y_inter + y_intra + y_diag

        S0 = st["wkv"]
        S_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0,
                                   (rc, kc, vc, lc), unroll=unroll)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nck * chunk, nh, hd)[:, :S]
        new_wkv = S_final

    y = y.reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(y, params["gn"], cfg.norm_eps)            # group-norm stand-in
    y = y * jax.nn.silu(g)
    y = shard(y, "batch", None, "heads")
    tm_out = linear(y, params["wo"])
    x = x + tm_out
    new_shift_t = xn[:, -1]

    # ---------------- channel mix ----------------
    xc = rmsnorm(x, params["ln_c"], cfg.norm_eps)
    xcprev = _token_shift(xc, st["shift_c"].astype(xc.dtype))
    xm = _mix(xc, xcprev, params["mu_c"])
    kk = jnp.square(jax.nn.relu(linear(xm, params["ck"])))
    kk = shard(kk, "batch", None, "mlp")
    cm = linear(kk, params["cv"]) * jax.nn.sigmoid(linear(xm, params["cr"]))
    out = (x + cm).astype(out_dtype)
    new_state = {"wkv": new_wkv, "shift_t": new_shift_t.astype(jnp.float32),
                 "shift_c": xc[:, -1].astype(jnp.float32)}
    return out, new_state
