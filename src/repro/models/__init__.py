"""Model zoo: layers, attention, MoE, SSM (Mamba2), RWKV6, decoder stacks."""
