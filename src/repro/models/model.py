"""Decoder-stack assembly for all assigned architecture families.

One code path per family, all under ``lax.scan`` over stacked layer params
(compile-time O(1) in depth — a 126-layer 405B model lowers as one layer
body):

  * dense / moe / vlm / audio — pre-norm attention + (MLP | MoE) blocks.
  * ssm (rwkv6)               — RWKV6 time-mix + channel-mix blocks.
  * hybrid (zamba2)           — groups of ``shared_attn_every`` Mamba2
    layers followed by one application of a *shared* attention block
    (one param set, per-application KV caches), scanned over groups.

``mode``: train | prefill | decode.  vlm/audio archs take pre-computed
frontend embeddings (``input_mode='embeddings'``) per the assignment brief;
everything else takes token ids.

The returned ``aux`` carries new caches/states (prefill/decode) and the
MoE load-balance loss (train).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, attn_specs, init_kv_cache
from .layers import ParamSpec, shard, rmsnorm
from .moe import mlp_apply, mlp_specs, moe_apply, moe_specs
from .rwkv import init_rwkv_state, rwkv6_apply, rwkv6_specs
from .ssm import init_mamba_state, mamba2_apply, mamba2_specs

__all__ = ["model_specs", "forward", "init_decode_state", "param_counts"]


def _stack_specs(specs: dict, n: int) -> dict:
    """Add a leading stacked-layers axis to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                            s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _layer_specs(cfg) -> dict:
    if cfg.family == "ssm":
        return rwkv6_specs(cfg)
    if cfg.family == "hybrid":
        return mamba2_specs(cfg)
    specs = {"attn": attn_specs(cfg)}
    specs["mlp"] = moe_specs(cfg) if cfg.n_experts else mlp_specs(cfg)
    return specs


def model_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs: dict = {
        "final_ln": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        "lm_head": ParamSpec((d, v), ("embed", "vocab"), cfg.dtype),
    }
    if cfg.input_mode == "tokens":
        specs["embed"] = ParamSpec((v, d), ("vocab", "embed"), cfg.dtype)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        groups = cfg.n_layers // cfg.shared_attn_every
        per_group = _stack_specs(_layer_specs(cfg), cfg.shared_attn_every)
        specs["layers"] = _stack_specs(per_group, groups)
        specs["shared_attn"] = attn_specs(cfg)
        specs["shared_mlp"] = mlp_specs(cfg)
    else:
        specs["layers"] = _stack_specs(_layer_specs(cfg), cfg.n_layers)
    return specs


def param_counts(cfg) -> tuple[int, int]:
    """(total params, active-per-token params) from the spec tree."""
    import numpy as np

    specs = model_specs(cfg)
    leaves = {k: v for k, v in _flatten("", specs).items()}
    total = sum(int(np.prod(s.shape)) for s in leaves.values())
    active = 0
    for k, s in leaves.items():
        n = int(np.prod(s.shape))
        if cfg.n_experts and ("/w_up" in k or "/w_gate" in k or "/w_down" in k) \
                and "shared" not in k:
            n = n * cfg.experts_per_token // cfg.n_experts
        active += n
    return total, active


def _flatten(prefix, tree):
    out = {}
    if isinstance(tree, ParamSpec):
        out[prefix] = tree
        return out
    for k, v in tree.items():
        out.update(_flatten(f"{prefix}/{k}", v))
    return out


# ---------------------------------------------------------------------------
# decode-state construction
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch: int, capacity: int, quantized: bool = False):
    """Per-layer stacked serve-time state for the given cache capacity."""
    if cfg.family == "ssm":
        one = init_rwkv_state(cfg, batch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        m = init_mamba_state(cfg, batch)
        mam = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (groups, cfg.shared_attn_every) + a.shape), m)
        kv = init_kv_cache(cfg, batch, capacity, quantized=quantized)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (groups,) + a.shape), kv)
        return {"mamba": mam, "kv": kv}
    kv = init_kv_cache(cfg, batch, capacity, quantized=quantized)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), kv)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(params, cfg, *, tokens=None, embeds=None, mode: str = "train",
            state=None, cache_len=None, q_chunk: int = 512,
            kv_chunk: int = 1024, ssm_chunk: int = 256,
            unroll_scans: bool = False, remat: bool = False):
    """Returns (logits, aux).  aux = {"state": ..., "moe_aux": scalar}.

    ``remat=True`` checkpoints each scanned layer body (activation
    rematerialization): backward recomputes the layer instead of saving
    its internals — the standard memory/compute trade for deep stacks.
    """
    maybe_remat = (jax.checkpoint if remat else (lambda f: f))
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", "seq", None)
    B, S = x.shape[:2]
    if mode == "decode":
        if state is None:
            raise ValueError("decode needs a serve-time state")
        positions = cache_len + jnp.arange(S, dtype=jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)

    moe_aux = jnp.zeros((), jnp.float32)
    needs_state = mode in ("prefill", "decode")

    if cfg.family == "ssm":
        # rwkv states are O(d·head) — cheap enough to thread in every mode
        layer_state = state if state is not None else init_decode_state(
            cfg, B, 0)

        def body(carry, xs):
            h, aux = carry
            lp, lstate = xs
            h, new_st = rwkv6_apply(lp, h, cfg, mode=mode, state=lstate,
                                    chunk=32, unroll=unroll_scans)
            h = shard(h, "batch", "seq", None)
            return (h, aux), new_st

        (x, moe_aux), new_state = jax.lax.scan(
            maybe_remat(body), (x, moe_aux), (params["layers"], layer_state),
            unroll=unroll_scans)

    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every

        def one_mamba(lp, h, lst):
            delta, ns = mamba2_apply(lp, h, cfg, mode=mode, state=lst,
                                     chunk=ssm_chunk, unroll=unroll_scans)
            return h + delta, ns

        # nested remat: without it the group body's backward holds all k
        # mamba layers' internals at once
        one_mamba_r = maybe_remat(one_mamba)

        def group(h, aux, gp, g_mamba, g_kv):
            new_mamba = []
            for i in range(k):
                lp = jax.tree.map(lambda a: a[i], gp)
                lst = (None if g_mamba is None
                       else jax.tree.map(lambda a: a[i], g_mamba))
                h, ns = one_mamba_r(lp, h, lst)
                h = shard(h, "batch", "seq", None)
                new_mamba.append(ns)
            new_mamba = jax.tree.map(lambda *a: jnp.stack(a), *new_mamba)
            a_out, new_kv = attention(
                params["shared_attn"], h, cfg, mode=mode, positions=positions,
                cache=g_kv if mode == "decode" else None, cache_len=cache_len,
                q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll_scans)
            h = h + a_out
            h = h + mlp_apply(params["shared_mlp"], h, cfg)
            h = shard(h, "batch", "seq", None)
            return h, aux, new_mamba, new_kv

        if mode == "train":
            def body(carry, gp):
                h, aux = carry
                h, aux, _, _ = group(h, aux, gp, None, None)
                return (h, aux), None

            (x, moe_aux), _ = jax.lax.scan(
                maybe_remat(body), (x, moe_aux), params["layers"], unroll=unroll_scans)
            new_state = None
        else:
            if state is None:
                state = init_decode_state(cfg, B, S)  # prefill target

            # the cache stack is loop-CARRIED and updated in place at the
            # group index: threading it as scan xs/ys makes XLA double-
            # buffer the whole multi-GB cache (input stack + ys stack)
            def body(carry, gp_i):
                h, aux, st = carry
                gp, i = gp_i
                g_mamba = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, 0, keepdims=False), st["mamba"])
                g_kv = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, 0, keepdims=False), st["kv"])
                h, aux, new_mamba, new_kv = group(h, aux, gp, g_mamba, g_kv)
                if new_kv is None:
                    new_kv = g_kv
                st = {
                    "mamba": jax.tree.map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(
                            a, n.astype(a.dtype), i, 0), st["mamba"],
                        new_mamba),
                    "kv": jax.tree.map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(
                            a, n.astype(a.dtype), i, 0), st["kv"], new_kv),
                }
                return (h, aux, st), None

            groups = cfg.n_layers // k
            (x, moe_aux, new_state), _ = jax.lax.scan(
                maybe_remat(body), (x, moe_aux, state),
                (params["layers"], jnp.arange(groups)),
                unroll=unroll_scans)

    else:
        # dense/moe/vlm/audio transformer: no state threaded in train mode
        def block(h, aux, lp, l_kv):
            a_out, new_kv = attention(
                lp["attn"], h, cfg, mode=mode, positions=positions,
                cache=l_kv if mode == "decode" else None, cache_len=cache_len,
                q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll_scans)
            h = h + a_out
            h = shard(h, "batch", "seq", None)
            if cfg.n_experts:
                m_out, m_aux = moe_apply(lp["mlp"], h, cfg,
                                         unroll=unroll_scans)
                aux = aux + m_aux
            else:
                m_out = mlp_apply(lp["mlp"], h, cfg)
            h = h + m_out
            h = shard(h, "batch", "seq", None)
            return h, aux, new_kv

        if mode == "train":
            def body(carry, lp):
                h, aux = carry
                h, aux, _ = block(h, aux, lp, None)
                return (h, aux), None

            (x, moe_aux), _ = jax.lax.scan(
                maybe_remat(body), (x, moe_aux), params["layers"], unroll=unroll_scans)
            new_state = None
        else:
            if state is None:
                state = init_decode_state(cfg, B, S)  # prefill target

            # loop-carried cache stack, in-place update at the layer index
            # (scan xs/ys would double-buffer the entire cache)
            def body(carry, lp_i):
                h, aux, st = carry
                lp, i = lp_i
                l_kv = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, 0, keepdims=False), st)
                h, aux, new_kv = block(h, aux, lp, l_kv)
                if new_kv is not None:
                    st = jax.tree.map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(
                            a, n.astype(a.dtype), i, 0), st, new_kv)
                return (h, aux, st), None

            (x, moe_aux, new_state), _ = jax.lax.scan(
                maybe_remat(body), (x, moe_aux, state),
                (params["layers"], jnp.arange(cfg.n_layers)),
                unroll=unroll_scans)

    if mode == "prefill":
        # serving only needs the last position's logits; the full (B, 32k,
        # vocab) tensor would dominate prefill memory for 100k+ vocabs
        x = x[:, -1:]
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype))
    logits = shard(logits, "batch", None, "vocab")
    aux = {"moe_aux": moe_aux / max(cfg.n_layers, 1),
           "state": new_state if needs_state else None}
    return logits, aux
