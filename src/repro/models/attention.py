"""GQA attention with RoPE, KV caches, and a flash-style blocked softmax.

The blocked attention (``flash_attention``) is the TPU adaptation layer:
an online-softmax scan over (q-chunk × kv-chunk) tiles in pure
``jax.lax`` — the exact semantics of a fused flash kernel, with
O(q_chunk · kv_chunk) live scores instead of O(S²).  On a real TPU
deployment the inner block would be a Pallas kernel; the scan structure,
numerics (f32 accumulators, bf16 matmuls) and memory behaviour are what
the dry-run must prove out, and XLA fuses the inner block well.

Modes:
  * train/prefill — full causal self-attention, optionally returning the
    KV cache (prefill).
  * decode        — one new token against a length-``cache_len`` cache
    (the assigned ``decode_32k`` / ``long_500k`` cells).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec, apply_rope, linear, rope_freqs, shard

__all__ = ["attn_specs", "flash_attention", "attention", "init_kv_cache"]

# §Perf hillclimb switch: triangular (causal-skip) flash schedule vs the
# full (qi × ki) grid.  True = deployed default.
TRIANGULAR = True


def attn_specs(cfg) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads"), cfg.dtype),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), cfg.dtype),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), cfg.dtype),
        "wo": ParamSpec((h * hd, d), ("heads", "embed"), cfg.dtype),
        "ln": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h * hd,), ("heads",), cfg.dtype, init="zeros")
        specs["bk"] = ParamSpec((hkv * hd,), ("kv_heads",), cfg.dtype, init="zeros")
        specs["bv"] = ParamSpec((hkv * hd,), ("kv_heads",), cfg.dtype, init="zeros")
    return specs


def init_kv_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16,
                  quantized: bool = False):
    """KV cache; ``quantized=True`` stores int8 codes + per-(token, head)
    fp32 scales — the compressed-KV option (DESIGN.md Plane B: the paper's
    quantization stage with unit-block = one head-token vector)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    if quantized:
        return {
            "k": jnp.zeros((batch, capacity, hkv, hd), jnp.int8),
            "v": jnp.zeros((batch, capacity, hkv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, capacity, hkv), jnp.float32),
            "v_scale": jnp.zeros((batch, capacity, hkv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, capacity, hkv, hd), dtype),
        "v": jnp.zeros((batch, capacity, hkv, hd), dtype),
    }


def _quantize_heads(x):
    """Per-(token, head) symmetric int8: returns (codes, scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_heads(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def flash_attention(q, k, v, *, causal: bool, q_offset=0, kv_valid_len=None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    k_scale=None, v_scale=None, unroll: bool = False):
    """Blocked online-softmax attention (GQA-aware).

    q: (B, Sq, H, D);  k/v: (B, Sk, Hkv, D);  H % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: cache_len).
    ``kv_valid_len``: number of valid cache entries (None = all).
    ``k_scale``/``v_scale``: per-(token, head) fp32 scales for an int8
    cache — dequantization happens *per kv-chunk inside the loop*, so the
    bf16 cache is never materialized in full (decode-32k memory term).

    K/V chunks are taken with ``dynamic_slice`` per step rather than a
    pre-reshaped scan input: pre-blocking a 32k-token cache would copy
    (and transpose) the entire cache on every decode step.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples (masked out below)
    pq = (-Sq) % q_chunk
    pk = (-Sk) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pk), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pk), (0, 0)))
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    qb = q.reshape(B, nq, q_chunk, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    valid = Sk if kv_valid_len is None else kv_valid_len
    compute_dt = q.dtype

    def _kv_chunk_at(ki):
        kc = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
        if k_scale is not None:
            ks = jax.lax.dynamic_slice_in_dim(k_scale, ki * kv_chunk,
                                              kv_chunk, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v_scale, ki * kv_chunk,
                                              kv_chunk, axis=1)
            kc = _dequantize_heads(kc, ks, compute_dt)
            vc = _dequantize_heads(vc, vs, compute_dt)
        return kc, vc

    def q_step(_, qi_qc):
        qi, qc, nk_i = qi_qc                # qc: (B, q_chunk, Hkv, G, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc = _kv_chunk_at(ki)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bchd->bhgqc", qc, kc.astype(qc.dtype),
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < valid
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (exp(-inf - -inf))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        # remat: backward recomputes the score block instead of saving it —
        # the memory behaviour of a fused flash kernel
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            jnp.arange(nk_i), unroll=unroll)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Hkv, G, q_chunk, D) -> (B, q_chunk, Hkv, G, D)
        return out.transpose(0, 3, 1, 2, 4)

    if causal and q_offset == 0 and nq > 1 and TRIANGULAR:
        # triangular schedule: q-chunk qi only attends kv chunks
        # [0, ceil((qi+1)·q_chunk / kv_chunk)) — fully-masked blocks are
        # statically skipped, halving attention FLOPs vs the full grid
        # (§Perf hillclimb #1).  Per-qi trip counts are static, so both
        # the deployed scan and the unrolled flops variants benefit.
        outs = []
        for qi in range(nq):
            hi = min(nk, -(-((qi + 1) * q_chunk) // kv_chunk))
            f = jax.checkpoint(
                lambda qc, _qi=qi, _hi=hi: q_step(
                    None, (jnp.int32(_qi), qc, _hi)))
            outs.append(f(qb[qi]))
        out = jnp.stack(outs)
    else:
        def q_body(_, qi_qc):
            qi, qc = qi_qc
            return None, q_step(None, (qi, qc, nk))

        _, out = jax.lax.scan(jax.checkpoint(q_body), None,
                              (jnp.arange(nq), qb), unroll=unroll)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k, v, *, valid_len, k_scale=None, v_scale=None):
    """Single-einsum attention for tiny Sq (decode): one masked softmax
    over the full cache.

    Scores are only (B, H, Sq, Sk) for Sq=1, so no chunking is needed —
    and *must not* be used: dynamic-slicing a sequence-sharded cache makes
    the SPMD partitioner reshard the entire cache per loop step.  A plain
    einsum over the sharded seq dim partitions cleanly (partial softmax +
    all-reduce).  Int8 caches are dequantized at the einsum operand, which
    XLA fuses into the dot.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k.astype(qg.dtype),
                   preferred_element_type=jnp.float32) * D ** -0.5
    if k_scale is not None:
        # per-(token, head) int8 scales are constant over the contracted
        # head_dim, so they factor out of the dot exactly: scale the scores
        # (B·H·Sk floats) instead of dequantizing the B·Sk·H·D cache
        s = s * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    kpos = jnp.arange(Sk)
    s = jnp.where((kpos < valid_len)[None, None, None, None, :], s, -jnp.inf)
    # Normalize *after* the pv contraction with unnormalized exp weights
    # rounded to the cache dtype — the exact operation order of the flash
    # path, so decode logits track train logits to the last rounding step
    # (train/serve consistency; the MoE router is sensitive to sub-ulp
    # drift in the attention output).
    m = s.max(axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    l = p.sum(axis=-1)
    if v_scale is not None:
        pv = jnp.einsum("bhgqs,bshd->bhgqd",
                        (p * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
                         ).astype(jnp.float32),
                        v.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    else:
        pv = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v.dtype), v,
                        preferred_element_type=jnp.float32)
    out = pv / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention(params, x, cfg, *, mode: str, positions, cache=None,
              cache_len=None, q_chunk: int = 512, kv_chunk: int = 1024,
              unroll: bool = False):
    """Pre-norm attention block body (residual added by the caller).

    Returns (out, new_cache).  ``positions``: (Sq,) absolute positions of
    the query tokens.  decode mode writes this step's K/V at ``positions``
    and attends over ``cache_len + Sq`` entries.
    """
    from .layers import rmsnorm

    B, Sq, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    q = linear(xn, params["wq"], params.get("bq")).reshape(B, Sq, h, hd)
    k = linear(xn, params["wk"], params.get("bk")).reshape(B, Sq, hkv, hd)
    v = linear(xn, params["wv"], params.get("bv")).reshape(B, Sq, hkv, hd)
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and cache_len is not None
        quantized = "k_scale" in cache
        if quantized:
            kq, ks = _quantize_heads(k)
            vq, vs = _quantize_heads(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], kq, cache_len, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], vq, cache_len, axis=1),
                "k_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ks.astype(jnp.float32), cache_len,
                    axis=1),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vs.astype(jnp.float32), cache_len,
                    axis=1),
            }
            out = decode_attention(
                q, new_cache["k"], new_cache["v"],
                valid_len=cache_len + Sq,
                k_scale=new_cache["k_scale"], v_scale=new_cache["v_scale"])
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
            new_cache = {"k": ck, "v": cv}
            out = decode_attention(q, ck, cv, valid_len=cache_len + Sq)
    else:
        out = flash_attention(q, k, v, causal=True,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              unroll=unroll)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    out = out.reshape(B, Sq, h * hd)
    return linear(out, params["wo"]), new_cache
