"""Mamba2 (SSD) block — the state-space half of the Zamba2 hybrid.

Training uses the chunked SSD form (Mamba2 paper §6): within a chunk the
scalar-decay linear recurrence is evaluated as a masked quadratic
("attention-like") term; across chunks a short ``lax.scan`` carries the
(heads, d_head, d_state) state.  All decay algebra runs in fp32 log-space.

Decode is the exact O(1) recurrence — this is what makes the ``long_500k``
cell runnable for the hybrid/ssm archs (state size is independent of
context length).

Sharding: heads → ``model`` axis; state tensors follow their head axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec, linear, rmsnorm, shard

__all__ = ["mamba2_specs", "mamba2_apply", "init_mamba_state"]

_CONV_K = 4


def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_head
    ns = cfg.ssm_state
    return {
        "ln": ParamSpec((d,), (None,), cfg.dtype, init="ones"),
        # fused input projection: [x_in, z(gate), B, C, dt]
        "w_in": ParamSpec((d, 2 * din + 2 * ns + nh), ("embed", "heads"), cfg.dtype),
        "conv_w": ParamSpec((_CONV_K, din + 2 * ns), (None, "heads"), cfg.dtype,
                            scale=0.5),
        "a_log": ParamSpec((nh,), ("heads",), "float32", init="zeros"),
        "dt_bias": ParamSpec((nh,), ("heads",), "float32", init="zeros"),
        "d_skip": ParamSpec((nh,), ("heads",), "float32", init="ones"),
        "w_out": ParamSpec((din, d), ("heads", "embed"), cfg.dtype),
        "out_ln": ParamSpec((din,), ("heads",), cfg.dtype, init="ones"),
    }


def init_mamba_state(cfg, batch: int):
    din = cfg.ssm_expand * cfg.d_model
    nh = din // cfg.ssm_head
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, din + 2 * cfg.ssm_state),
                          jnp.bfloat16),
    }


def _split_proj(proj, din, ns, nh):
    xin = proj[..., :din]
    z = proj[..., din:2 * din]
    B = proj[..., 2 * din:2 * din + ns]
    C = proj[..., 2 * din + ns:2 * din + 2 * ns]
    dt = proj[..., 2 * din + 2 * ns:]
    return xin, z, B, C, dt


def _causal_conv(u, w, state=None):
    """Depthwise causal conv, kernel _CONV_K.  u: (B,S,C); w: (K,C)."""
    if state is None:
        pad = jnp.zeros((u.shape[0], _CONV_K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)
    out = sum(ext[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
              for i in range(_CONV_K))
    new_state = ext[:, -(_CONV_K - 1):] if _CONV_K > 1 else None
    return jax.nn.silu(out), new_state


def mamba2_apply(params, x, cfg, *, mode: str, state=None,
                 chunk: int = 256, unroll: bool = False):
    """Returns (out, new_state).  x: (B,S,d)."""
    B_, S, d = x.shape
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_head
    hd = cfg.ssm_head
    ns = cfg.ssm_state

    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    proj = linear(xn, params["w_in"])
    xin, z, Bm, Cm, dt = _split_proj(proj, din, ns, nh)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, params["conv_w"],
        None if state is None else state["conv"])
    xin, Bm, Cm = (conv_out[..., :din], conv_out[..., din:din + ns],
                   conv_out[..., din + ns:])
    xh = xin.reshape(B_, S, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])            # (B,S,nh) > 0
    a = -jnp.exp(params["a_log"].astype(jnp.float32))    # (nh,) < 0
    la = dt * a[None, None, :]                           # log-decay ≤ 0
    xdt = xh.astype(jnp.float32) * dt[..., None]         # dt-weighted input
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    if mode == "decode":
        assert state is not None
        h = state["ssm"]
        dec = jnp.exp(la)                                # (B,S=1,nh)
        h = (h * dec[:, 0, :, None, None]
             + jnp.einsum("bhp,bn->bhpn", xdt[:, 0], Bf[:, 0]))
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, 0])[:, None]
        new_state = {"ssm": h, "conv": conv_state}
    else:
        pad = (-S) % chunk
        if pad:
            xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
            la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
            Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
            Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
        nck = (S + pad) // chunk
        xc = xdt.reshape(B_, nck, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
        lac = la.reshape(B_, nck, chunk, nh).transpose(1, 0, 2, 3)
        Bc = Bf.reshape(B_, nck, chunk, ns).transpose(1, 0, 2, 3)
        Cc = Cf.reshape(B_, nck, chunk, ns).transpose(1, 0, 2, 3)

        def chunk_step(h, inp):
            xk, lak, Bk, Ck = inp
            cum = jnp.cumsum(lak, axis=1)                 # (B,c,nh)
            total = cum[:, -1]                            # (B,nh)
            # intra-chunk quadratic term (masked decay kernel)
            decay_ij = jnp.exp(jnp.clip(
                cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0))
            mask = jnp.tril(jnp.ones((chunk, chunk), bool))
            scores = (jnp.einsum("bin,bjn->bij", Ck, Bk)[:, :, :, None]
                      * decay_ij * mask[None, :, :, None])
            y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xk)
            # inter-chunk: contribution of the carried state
            y_inter = jnp.einsum("bhpn,bin,bih->bihp",
                                 h, Ck, jnp.exp(cum))
            # state update to chunk end
            wj = jnp.exp(jnp.clip(total[:, None] - cum, -60.0, 0.0))
            h_new = (h * jnp.exp(total)[..., None, None]
                     + jnp.einsum("bjhp,bjn,bjh->bhpn", xk, Bk, wj))
            return h_new, y_intra + y_inter

        h0 = (jnp.zeros((B_, nh, hd, ns), jnp.float32)
              if state is None else state["ssm"])
        h_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                                   (xc, lac, Bc, Cc), unroll=unroll)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, nck * chunk, nh, hd)
        y = y[:, :S]
        new_state = {"ssm": h_final, "conv": conv_state}

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, din)
    y = rmsnorm(y.astype(x.dtype), params["out_ln"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    y = shard(y, "batch", None, "heads")
    return linear(y, params["w_out"]), new_state
