"""Parameter plumbing + basic layers (pure JAX, no framework deps).

Parameters are plain pytrees (nested dicts of arrays).  Alongside every
param tree we build a *spec tree* of :class:`ParamSpec` with logical
sharding axes — the dry-run lowers from specs (ShapeDtypeStruct, zero
allocation) and the launcher resolves logical axes → mesh axes through
:mod:`repro.launch.sharding` rules.

Activation sharding constraints go through :func:`shard` which consults a
context-local (mesh, rules) pair set by the launcher; without a mesh it is
the identity, so smoke tests run untouched on one CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_from_specs", "abstract_from_specs", "shard",
           "activation_shardings", "rmsnorm", "linear", "rope_freqs",
           "apply_rope", "param_count", "mesh_context", "current_mesh_rules"]


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    dtype: str = "bfloat16"
    init: str = "normal"                  # normal | zeros | ones
    scale: float = 1.0

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
    std = spec.scale / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def init_from_specs(specs, key):
    """Materialize a param tree from a spec tree (one PRNG split per leaf)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_from_specs(specs):
    """ShapeDtypeStruct tree — the dry-run's zero-allocation params."""
    return jax.tree.map(lambda s: s.sds(), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(int(np.prod(s.shape)) for s in leaves))


# ---------------------------------------------------------------------------
# mesh/rules context for activation sharding constraints
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def mesh_context(mesh, rules):
    """Launcher-installed context: activation ``shard()`` constraints apply."""
    prev = getattr(_CTX, "value", None)
    _CTX.value = (mesh, rules)
    try:
        yield
    finally:
        _CTX.value = prev


def current_mesh_rules():
    return getattr(_CTX, "value", None)


def shard(x, *axes):
    """Constrain activation sharding by logical axis names.

    Unresolved dims (no rule, or indivisible) are left UNCONSTRAINED so XLA
    keeps its propagated sharding.  No-op outside a mesh context
    (single-device smoke tests)."""
    ctx = current_mesh_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.partition_spec(axes, shape=x.shape, mesh=mesh,
                                unconstrained_fallback=True)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def activation_shardings(axes_tree, shapes_tree=None):
    """Resolve a tree of logical-axis tuples to NamedShardings (launcher)."""
    ctx = current_mesh_rules()
    if ctx is None:
        raise RuntimeError("activation_shardings needs a mesh_context")
    mesh, rules = ctx
    def _one(axes):
        return jax.sharding.NamedSharding(
            mesh, rules.partition_spec(axes, shape=None, mesh=mesh))
    return jax.tree.map(_one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# basic layers
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rope_freqs(positions, head_dim: int, theta: float = 10_000.0):
    """(…, head_dim/2) cos/sin tables for the given absolute positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
