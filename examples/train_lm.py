"""End-to-end training driver: data pipeline → sharded train step →
checkpoints → resilience, on any ``--arch`` (reduced or full config).

Default: a ~110M-param llama-style model on the synthetic LM stream.

    PYTHONPATH=src python examples/train_lm.py --steps 60          # demo
    PYTHONPATH=src python examples/train_lm.py --steps 300 \
        --preset 100m                                              # brief's
    PYTHONPATH=src python examples/train_lm.py --arch deepseek_7b \
        --smoke --steps 40                                         # any arch

Resilience demo: Ctrl-C (SIGTERM) checkpoints and exits; re-running
resumes from the last checkpoint.  ``--amr-data`` trains on quantization
codes of a synthetic AMR field (Plane A ↔ Plane B bridge).
"""
import argparse
import time

import jax

from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import amr_token_batches, embedding_batches, lm_batches
from repro.launch.mesh import make_smoke_mesh
from repro.launch.train import train_loop
from repro.models.model import param_counts
from repro.optim.adamw import AdamWConfig

PRESETS = {
    # ~110M params: the brief's "train ~100M model" driver
    "100m": ModelConfig(name="demo-100m", family="dense", n_layers=10,
                        d_model=640, n_heads=10, n_kv_heads=10, d_ff=2560,
                        vocab_size=32768),
    "20m": ModelConfig(name="demo-20m", family="dense", n_layers=6,
                       d_model=320, n_heads=8, n_kv_heads=8, d_ff=1280,
                       vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--amr-data", action="store_true")
    args = ap.parse_args()

    if args.arch:
        cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    else:
        cfg = PRESETS[args.preset]
    total, active = param_counts(cfg)
    print(f"model: {cfg.name}  params={total / 1e6:.1f}M "
          f"(active {active / 1e6:.1f}M)")

    run = RunConfig(microbatches=args.microbatches, remat="layer")
    mesh = make_smoke_mesh()
    shape = ShapeConfig("train", "train", seq_len=args.seq,
                        global_batch=args.batch)
    if cfg.input_mode != "tokens":
        stream = embedding_batches(cfg, shape, seed=0)
    elif args.amr_data:
        stream = amr_token_batches(cfg, shape, seed=0)
    else:
        stream = lm_batches(cfg, shape, seed=0)

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    t0 = time.time()
    params, opt_state, hist = train_loop(
        cfg, run, mesh, stream, steps=args.steps, opt_cfg=opt,
        checkpoint_dir=args.ckpt, checkpoint_every=max(args.steps // 4, 10),
        log_every=max(args.steps // 15, 1))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\nstep, loss")
    for s, l in hist:
        print(f"{s:5d}, {l:.4f}")
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({toks / dt:.0f} tok/s on {jax.device_count()} device(s)); "
          f"loss {hist[0][1]:.3f} → {hist[-1][1]:.3f}")


if __name__ == "__main__":
    main()
