"""Batched serving demo: prefill + iterative decode with the (optionally
int8-compressed) KV cache.

    PYTHONPATH=src python examples/serve_lm.py --kv-quant
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, smoke_config
from repro.models.layers import init_from_specs
from repro.models.model import model_specs, param_counts
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    total, _ = param_counts(cfg)
    print(f"serving {cfg.name} ({total / 1e6:.1f}M params), "
          f"kv_quant={args.kv_quant}")
    params = init_from_specs(model_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, RunConfig(kv_quant=args.kv_quant))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = eng.generate(params, prompts, new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"generated {args.batch}×{args.new_tokens} tokens in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", np.asarray(out[0])[:16], "...")
    # cache footprint comparison
    hkv, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    cap = args.prompt_len + args.new_tokens
    bf16 = L * args.batch * cap * hkv * hd * 2 * 2
    int8 = L * args.batch * cap * hkv * (hd + 4) * 2
    print(f"KV cache: bf16={bf16 / 1e6:.2f}MB  int8+scales={int8 / 1e6:.2f}MB "
          f"({bf16 / int8:.2f}x smaller)")


if __name__ == "__main__":
    main()
