"""Write → serve → client fetch: the TACZ region-serving path (ISSUE 3).

  1. stream a multi-level AMR snapshot into a ``.tacz`` file;
  2. stand up the HTTP region endpoint (stdlib ``http.server`` over a
     :class:`RegionServer` with a byte-budgeted sub-block cache);
  3. fetch overlapping regions through :class:`RegionClient`, verify them
     against a local ``read_roi``, and watch the cache absorb the repeat
     traffic;
  4. republish the snapshot and see the server hot-swap via footer CRC.

    PYTHONPATH=src python examples/serve_regions.py
"""
import os
import tempfile
import threading
import time

import numpy as np

from repro import io as tacz
from repro.core import amr
from repro.serving import RegionClient, RegionServer, serve


def main():
    ds = amr.load_preset("run1_z10")
    eb = 1e-3 * float(ds.levels[0].data.max() - ds.levels[0].data.min())

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snapshot.tacz")
        with tacz.TACZWriter(path, eb=eb) as w:
            for lvl in ds.levels:
                w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
        print(f"wrote {os.path.getsize(path) / 1e3:.1f} kB "
              f"({ds.total_values() * 4 / 1e3:.1f} kB raw)")

        # --- serve: budget the cache at ~25% of the decoded level bytes --
        budget = sum(lvl.data.nbytes for lvl in ds.levels) // 4
        srv = RegionServer(path, cache_bytes=budget, auto_reload=True)
        httpd = serve(srv, port=0)
        threading.Thread(target=httpd.serve_forever, daemon=True).start()
        url = f"http://127.0.0.1:{httpd.server_address[1]}"
        client = RegionClient(url)

        meta = client.meta()
        print(f"serving {url}  snapshot crc={meta['snapshot_crc']:#010x}  "
              f"levels={[lv['shape'] for lv in meta['levels']]}")

        # --- overlapping region reads (the canonical analysis workload) --
        n = ds.finest_shape[0]
        s = n // 3
        boxes = [((o, o + s), (o, o + s), (0, s)) for o in (0, s // 2, s)]
        with tacz.TACZReader(path) as rd:
            refs = [rd.read_roi(b) for b in boxes]

        t0 = time.perf_counter()
        cold = client.regions(boxes)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = client.regions(boxes)
        t_warm = time.perf_counter() - t0
        for got, ref in zip(cold, refs):
            for g, r in zip(got, ref):
                assert np.array_equal(g.data, r.data)
        for got, ref in zip(warm, refs):
            for g, r in zip(got, ref):
                assert np.array_equal(g.data, r.data)
        stats = client.stats()
        print(f"{len(boxes)} overlapping boxes == read_roi  ✓   "
              f"cold {t_cold * 1e3:.0f} ms → warm {t_warm * 1e3:.0f} ms "
              f"({t_cold / max(t_warm, 1e-9):.1f}x; "
              f"hits={stats['hits']} misses={stats['misses']})")

        # --- hot swap: republish (atomic os.replace) under the server ----
        ds2 = amr.synthetic_amr(ds.finest_shape, densities=[0.4, 0.6],
                                refine_block=4, seed=11)
        with tacz.TACZWriter(path, eb=eb) as w:
            for lvl in ds2.levels:
                w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
        roi = client.region(0, boxes[0])     # auto_reload picks up the swap
        with tacz.TACZReader(path) as rd:
            assert np.array_equal(roi.data, rd.read_roi(boxes[0])[0].data)
        print(f"republished snapshot hot-swapped "
              f"(crc {client.meta()['snapshot_crc']:#010x})  ✓")

        httpd.shutdown()
        srv.close()


if __name__ == "__main__":
    main()
