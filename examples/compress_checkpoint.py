"""TAC+ as checkpoint compression (DESIGN.md Plane B): save a model
checkpoint losslessly and with the error-bounded lossy pipeline, compare
sizes and verify the per-tensor bound — the direct analogue of the paper's
per-AMR-level adaptive error bounds, applied per layer.

Lossy tensors land as TACZ container blobs (`repro.io.tensor`): framed,
versioned, CRC-indexed — the same on-disk format the AMR pipeline writes,
so this example also sanity-checks each stored blob's TACZ magic.

    PYTHONPATH=src python examples/compress_checkpoint.py
"""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.io import TACZ_MAGIC
from repro.configs import smoke_config
from repro.models.layers import init_from_specs
from repro.models.model import model_specs


def main():
    cfg = smoke_config("deepseek_7b")
    params = init_from_specs(model_specs(cfg), jax.random.PRNGKey(0))

    # give the big matrices trained-weight-like low-rank structure
    def structure(p):
        if p.ndim >= 2 and p.size > 4096:
            r = jnp.arange(p.shape[-2], dtype=jnp.float32)
            c = jnp.arange(p.shape[-1], dtype=jnp.float32)
            smooth = jnp.sin(r[:, None] / 11.0) * jnp.cos(c[None, :] / 5.0)
            return (smooth * 0.02 + 0.002 * p.astype(jnp.float32)
                    ).astype(p.dtype)
        return p

    params = jax.tree.map(structure, params)
    opt = {"step": jnp.zeros((), jnp.int32)}

    with tempfile.TemporaryDirectory() as d:
        sizes = {}
        for name, eb in [("lossless", 0.0), ("lossy@1e-3", 1e-3),
                         ("lossy@1e-2", 1e-2)]:
            mgr = CheckpointManager(os.path.join(d, name), lossy_eb_rel=eb)
            mgr.save(1, params, opt, blocking=True)
            f = os.path.join(d, name, "step_00000001.npz")
            sizes[name] = os.path.getsize(f)
            if eb > 0:
                # every lossy entry is a self-describing TACZ container
                with open(os.path.join(d, name, "step_00000001.json")) as mf:
                    manifest = json.load(mf)
                with np.load(f) as z:
                    n_tacz = sum(
                        bytes(z[k][:4]) == TACZ_MAGIC
                        for k in manifest["lossy"])
                print(f"  {n_tacz} lossy tensors stored as TACZ blobs")
            rp, _, _ = mgr.restore(1)
            worst = 0.0
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rp)):
                a = np.asarray(a, np.float32)
                b = np.asarray(b, np.float32)
                rng = float(np.abs(a).max())
                if rng > 0:
                    worst = max(worst, float(np.abs(a - b).max()) / rng)
            print(f"{name:12s} {sizes[name] / 1e6:7.2f} MB   "
                  f"worst rel err = {worst:.2e}"
                  + ("" if eb == 0 else f"  (bound {eb:.0e})"))
        print(f"\nlossy@1e-3 is {sizes['lossless'] / sizes['lossy@1e-3']:.2f}x"
              f" smaller than lossless")


if __name__ == "__main__":
    main()
