"""Quickstart: the paper's pipeline end to end on a synthetic AMR dataset.

    PYTHONPATH=src python examples/quickstart.py

Generates a Nyx-like 2-level AMR dataset, compresses it with TAC+ (and the
baselines), verifies the error bound, and prints the rate-distortion
summary — the 60-second tour of Plane A.
"""
import numpy as np

from repro.core import amr, baselines, hybrid, metrics
from repro.core.adaptive_eb import level_error_bounds


def main():
    ds = amr.synthetic_amr((64, 64, 64), densities=[0.23, 0.77],
                           refine_block=8, seed=10, name="z10-like")
    print(f"dataset: {ds.name}  levels={ds.n_levels} "
          f"densities={[f'{d:.0%}' for d in ds.densities()]} "
          f"values={ds.total_values():,}")

    rng = max(float(l.data.max()) for l in ds.levels)
    eb = 1e-3 * rng

    print(f"\nerror bound {eb:.4f} (1e-3 of the value range)\n")
    print(f"{'method':14s} {'CR':>8s} {'bits/val':>9s} {'PSNR dB':>8s} "
          f"{'max err':>9s}")
    for name, res in [
        ("TAC+", hybrid.compress_amr(ds, eb=eb, unit=8)),
        ("TAC/interp", hybrid.compress_amr(ds, eb=eb, unit=8,
                                           algorithm="interp", she=False)),
        ("1D-naive", baselines.compress_1d_naive(ds, eb)),
        ("zMesh", baselines.compress_zmesh(ds, eb)),
        ("3D-baseline", baselines.compress_3d_baseline(ds, eb)),
    ]:
        err = max(float(np.abs(r.recon[l.mask] - l.data[l.mask]).max())
                  for l, r in zip(ds.levels, res.levels))
        assert err <= eb * (1 + 1e-4) + rng * 2 ** -22
        print(f"{name:14s} {res.compression_ratio():8.2f} "
              f"{res.bit_rate():9.3f} {metrics.amr_psnr(ds, res):8.2f} "
              f"{err:9.5f}")

    # the paper's §IV-F move: per-level adaptive bounds
    ebs = level_error_bounds(eb * 1.5, ds.n_levels, metric="power_spectrum")
    res = hybrid.compress_amr(ds, eb=ebs, unit=8)
    print(f"\nTAC+ adaptive eb (fine:coarse = "
          f"{ebs[0] / ebs[1]:.1f}:1): CR={res.compression_ratio():.2f} "
          f"PSNR={metrics.amr_psnr(ds, res):.2f} dB")


if __name__ == "__main__":
    main()
