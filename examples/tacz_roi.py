"""Region-of-interest serving from a TACZ container.

The serving-side story of the container (AMRIC's in-situ I/O argument,
arXiv:2307.09609, plus the AMReX visualization finding that consumers
read *regions*, not snapshots, arXiv:2309.16980):

  1. *stream* a multi-level AMR snapshot into a ``.tacz`` file as the
     levels "arrive" (double-buffered background encoder, atomic publish);
  2. answer ROI queries by decoding only the sub-blocks whose cuboids
     intersect the requested box, and compare against full decode.

    PYTHONPATH=src python examples/tacz_roi.py
"""
import os
import tempfile
import time

import numpy as np

from repro import io as tacz
from repro.core import amr


def main():
    ds = amr.load_preset("run1_z10")
    eb = 1e-3 * float(ds.levels[0].data.max() - ds.levels[0].data.min())

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snapshot.tacz")

        # --- streaming write: one level at a time, as a simulation would --
        t0 = time.perf_counter()
        with tacz.TACZWriter(path, eb=eb) as w:
            for lvl in ds.levels:
                w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
        t_write = time.perf_counter() - t0
        print(f"wrote {os.path.getsize(path) / 1e3:.1f} kB "
              f"({ds.total_values() * 4 / 1e3:.1f} kB raw) "
              f"in {t_write * 1e3:.0f} ms")

        with tacz.TACZReader(path) as rd:
            rd.verify()
            t0 = time.perf_counter()
            full = rd.read()
            t_full = time.perf_counter() - t0

            n = ds.finest_shape[0]
            s = n // 4                      # a (1/4)^3 ≈ 1.6% volume box
            box = ((n // 2, n // 2 + s),) * 3
            t0 = time.perf_counter()
            rois = rd.read_roi(box)
            t_roi = time.perf_counter() - t0

        for roi, rec in zip(rois, full):
            crop = rec[tuple(slice(lo, hi) for lo, hi in roi.box)]
            assert np.array_equal(crop, roi.data)
            print(f"level {roi.level} (ratio {roi.ratio}): ROI "
                  f"{roi.shape} == full-decode crop  ✓")
        print(f"full decode {t_full * 1e3:.0f} ms, ROI decode "
              f"{t_roi * 1e3:.0f} ms  ({t_full / max(t_roi, 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
