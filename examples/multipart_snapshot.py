"""Multi-part snapshots end to end: parallel write → merged read →
part-aligned sharded serving.

Runs in a temp directory and verifies every step:

  1. compress a synthetic AMR dataset and publish it twice — once as a
     single ``.tacz`` file, once as a 3-part ``.taczd`` snapshot written
     by :class:`repro.io.parallel.ParallelTACZWriter`;
  2. read the multi-part snapshot back (bit-identical to the single
     file);
  3. launch one shard endpoint per part with a ``ShardMap`` built from
     the manifest's own partition config, scatter-gather through the
     router, and show that each shard only ever opened its own part.

Usage::

    PYTHONPATH=src python examples/multipart_snapshot.py
"""
import os
import tempfile
import threading

import numpy as np

from repro import io as tacz
from repro.core import amr, hybrid
from repro.io.parallel import ParallelTACZWriter
from repro.serving import RegionServer, ShardMap, ShardedRegionRouter, serve


def main() -> None:
    ds = amr.synthetic_amr((64, 64, 64), densities=[0.3, 0.7],
                           refine_block=4, seed=11)
    eb = 1e-3 * float(ds.levels[0].data.max() - ds.levels[0].data.min())

    with tempfile.TemporaryDirectory() as d:
        # -- write: one single-file snapshot, one 3-part parallel one ----
        single = os.path.join(d, "snap.tacz")
        res = hybrid.compress_amr(ds, eb=eb)
        tacz.write(single, res)

        multi = os.path.join(d, "snap.taczd")
        with ParallelTACZWriter(multi, parts=3, eb=eb) as w:
            for lvl in ds.levels:           # each worker compresses and
                w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)
            # streams its own (level, sub_block) partition
        parts = sorted(n for n in os.listdir(multi) if n.endswith(".tacz"))
        print(f"published {multi}: {parts} + manifest.json")

        # -- read: the merged view is bit-identical to the single file ---
        with tacz.open_snapshot(multi) as mrd:
            for a, b in zip(tacz.read(single), mrd.read()):
                np.testing.assert_array_equal(a, b)
            partition = mrd.partition
        print("multi-part read: bit-identical to the single-file snapshot")

        # -- serve: shards aligned 1:1 with parts ------------------------
        shard_map = ShardMap.from_dict(partition)
        servers, urls = {}, {}
        try:
            for sid in shard_map.shards:
                httpd = serve(multi, port=0, cache_bytes=8 << 20,
                              shard_map=shard_map, shard_id=sid)
                threading.Thread(target=httpd.serve_forever,
                                 daemon=True).start()
                servers[sid] = httpd
                urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"

            boxes = [((0, 16), (0, 16), (0, 16)),
                     ((20, 52), (8, 40), (16, 48))]
            with RegionServer(single) as baseline, \
                    ShardedRegionRouter(multi, shard_map, urls) as router:
                ref = baseline.get_regions(boxes)
                got = router.get_regions(boxes)
                for per_got, per_ref in zip(got, ref):
                    for g, r in zip(per_got, per_ref):
                        np.testing.assert_array_equal(g.data, r.data)
            print("sharded router: crops bit-identical to one full server")
            for pi, sid in enumerate(sorted(shard_map.shards)):
                opened = servers[sid].region_server.reader.open_parts
                print(f"  shard {sid}: opened parts {opened} "
                      f"(its own slice only)")
        finally:
            for httpd in servers.values():
                httpd.shutdown()
                httpd.server_close()
                httpd.region_server.close()


if __name__ == "__main__":
    main()
