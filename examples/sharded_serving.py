"""Sharded region serving: shard map → shard fleet → scatter-gather
router (ISSUE 4).

  1. write a multi-level AMR snapshot into a ``.tacz`` file;
  2. build a consistent-hash :class:`ShardMap` and stand up one
     shard-filtered HTTP region endpoint per shard (each caches only the
     sub-blocks it owns — aggregate cache capacity scales with N);
  3. fetch region batches through :class:`ShardedRegionRouter` and verify
     them bit-identically against both a single unsharded
     :class:`RegionServer` and a local ``read_roi``;
  4. kill one shard and watch the router absorb it (replica retry /
     direct local decode) with identical results;
  5. grow the map by one shard and count how few keys move.

    PYTHONPATH=src python examples/sharded_serving.py
"""
import os
import tempfile
import threading
import time

import numpy as np

from repro import io as tacz
from repro.core import amr
from repro.serving import (RegionServer, ShardedRegionRouter, ShardMap,
                           serve)


def main():
    ds = amr.load_preset("run1_z10")
    eb = 1e-3 * float(ds.levels[0].data.max() - ds.levels[0].data.min())

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snapshot.tacz")
        with tacz.TACZWriter(path, eb=eb) as w:
            for lvl in ds.levels:
                w.add_level(lvl.data, lvl.mask, ratio=lvl.ratio)

        # --- the shard map both sides agree on (ship it as JSON) ---------
        shard_map = ShardMap(["alpha", "beta", "gamma"], seed=0)
        with tacz.TACZReader(path) as rd:
            keys = rd.subblock_keys()
        sizes = {sid: len(ks) for sid, ks in
                 shard_map.partition(keys).items()}
        print(f"{len(keys)} sub-block keys over {len(shard_map)} shards: "
              f"{sizes}")

        # --- one shard-filtered endpoint per shard -----------------------
        servers, urls = {}, {}
        for sid in shard_map.shards:
            httpd = serve(path, port=0, cache_bytes=32 << 20,
                          shard_map=shard_map, shard_id=sid)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers[sid] = httpd
            urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
            print(f"  shard {sid!r} serving {urls[sid]}")

        n = ds.finest_shape[0]
        s = n // 3
        boxes = [((o, o + s), (o, o + s), (0, s)) for o in (0, s // 2, s)]

        with tacz.TACZReader(path) as rd:
            refs = [rd.read_roi(b) for b in boxes]
        router = ShardedRegionRouter(path, shard_map, urls)
        single = RegionServer(path)

        t0 = time.perf_counter()
        got = router.get_regions(boxes)
        t_router = time.perf_counter() - t0
        ref_single = single.get_regions(boxes)
        for per_router, per_single, per_file in zip(got, ref_single, refs):
            for a, b, c in zip(per_router, per_single, per_file):
                assert np.array_equal(a.data, b.data)
                assert np.array_equal(a.data, c.data)
        st = router.stats()
        print(f"{len(boxes)} boxes scatter-gathered in {t_router * 1e3:.0f} "
              f"ms == single server == read_roi  ✓   "
              f"({st['shard_requests']} shard requests, "
              f"{st['local_fallbacks']} fallbacks)")

        # --- kill one shard: correctness survives, throughput degrades ---
        down = shard_map.shards[0]
        servers[down].shutdown()
        servers[down].server_close()
        servers[down].region_server.close()
        got = router.get_regions(boxes)
        for per_router, per_file in zip(got, refs):
            for a, c in zip(per_router, per_file):
                assert np.array_equal(a.data, c.data)
        print(f"shard {down!r} down → served bit-identically via local "
              f"fallback ({router.stats()['local_fallbacks']} group(s))  ✓")

        # --- resizing: rendezvous moves only what it must ----------------
        grown = shard_map.with_shard("delta")
        moved = [k for k in keys if shard_map.owner(k) != grown.owner(k)]
        assert all(grown.owner(k) == "delta" for k in moved)
        print(f"adding a 4th shard moves {len(moved)}/{len(keys)} keys "
              f"(~1/4 expected), all onto the new shard  ✓")

        router.close()
        single.close()
        for sid in shard_map.shards[1:]:
            servers[sid].shutdown()
            servers[sid].server_close()
            servers[sid].region_server.close()


if __name__ == "__main__":
    main()
