"""Async serving core (ISSUE 10): bounded decode admission control,
429/503 + ``Retry-After`` backpressure semantics, per-level decode-unit
splitting, busy-aware client/router retry (busy is not down), the
CRC-checked cache-handoff protocol, and live fleet resharding."""
import contextlib
import json
import struct
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as obsm
from repro.serving import (AsyncServingCore, RegionClient, RegionServer,
                           ServerBusy, ShardMap, ShardedRegionRouter,
                           serve)
from repro.serving.client import RegionAPIError
from repro.serving.loadgen import LoadGenerator, ZipfWorkload

BOXES = [((0, 8), (0, 8), (0, 8)),
         ((5, 23), (11, 30), (2, 9)),
         ((24, 32), (16, 32), (0, 32))]
FULL = ((0, 32), (0, 32), (0, 32))


@pytest.fixture(scope="module")
def snapshot(make_amr_snapshot):
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5, name="async")
    return snap.path, snap.res


@pytest.fixture()
def metrics_enabled():
    """Leave the process-wide registry the way we found it."""
    was = obs.is_enabled()
    obs.set_enabled(True)
    yield
    obs.set_enabled(was)


@contextlib.contextmanager
def _serve(path, **kw):
    httpd = serve(path, port=0, **kw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        yield httpd, f"http://127.0.0.1:{httpd.server_address[1]}"
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


# ------------------------------ core unit ------------------------------


class _FakeServer:
    """Levels-aware stand-in recording which unit calls the core makes."""

    n_levels = 3

    def __init__(self, crcs=(7,)):
        self.calls = []
        self._crcs = list(crcs)
        self._lock = threading.Lock()

    def get_regions_with_crc(self, boxes, levels=None):
        with self._lock:
            self.calls.append(tuple(levels))
            crc = self._crcs[0] if len(self._crcs) == 1 \
                else self._crcs.pop(0)
        return crc, [[f"L{li}" for li in levels] for _ in boxes]


def test_core_splits_per_level_and_merges_in_request_order():
    core = AsyncServingCore(_FakeServer(), decode_workers=2)
    try:
        crc, vname, results = core.execute([0, 1], levels=[2, 0, 1])
        assert (crc, vname) == (7, None)
        # one unit per level, re-merged in the caller's level order
        assert results == [["L2", "L0", "L1"], ["L2", "L0", "L1"]]
        assert sorted(core.server.calls) == [(0,), (1,), (2,)]
    finally:
        core.close()


def test_core_levels_none_expands_to_all_levels():
    core = AsyncServingCore(_FakeServer(), decode_workers=2)
    try:
        _, _, results = core.execute([0])
        assert results == [["L0", "L1", "L2"]]
    finally:
        core.close()


def test_core_crc_race_retries_once_then_raises():
    # units disagree on the serving CRC once (hot swap between units):
    # the whole batch retries and succeeds on the new generation
    core = AsyncServingCore(_FakeServer(crcs=[1, 2, 2]),
                            decode_workers=1)
    try:
        crc, _, _ = core.execute([0], levels=[0, 1])
        assert crc == 2
    finally:
        core.close()
    # pathological churn: both attempts race -> IOError, not bad data
    core = AsyncServingCore(_FakeServer(crcs=[1, 2, 3, 4, 5]),
                            decode_workers=1)
    try:
        with pytest.raises(IOError, match="hot-swap"):
            core.execute([0], levels=[0, 1])
    finally:
        core.close()


def test_core_queue_full_rejects_with_429_semantics(metrics_enabled):
    release = threading.Event()
    entered = threading.Event()

    class _Blocking(_FakeServer):
        def get_regions_with_crc(self, boxes, levels=None):
            entered.set()
            release.wait(5)
            return super().get_regions_with_crc(boxes, levels=levels)

    core = AsyncServingCore(_Blocking(), decode_workers=1, queue_depth=0,
                            retry_after_s=0.2)
    t = threading.Thread(target=core.execute, args=([0],),
                         kwargs={"levels": [0]}, daemon=True)
    t.start()
    assert entered.wait(5)
    before = obsm.SERVER_BACKPRESSURE.labels("queue_full").value
    with pytest.raises(ServerBusy) as exc_info:
        core.execute([0], levels=[1])
    exc = exc_info.value
    assert exc.status == 429
    assert exc.reason == "queue_full"
    assert exc.retry_after >= 1          # sub-second hints round up
    assert obsm.SERVER_BACKPRESSURE.labels("queue_full").value \
        == before + 1
    release.set()
    t.join(timeout=5)
    assert core.pending == 0
    core.close()


def test_core_draining_rejects_with_503_semantics():
    core = AsyncServingCore(_FakeServer(), decode_workers=1)
    core.close()
    with pytest.raises(ServerBusy) as exc_info:
        core.execute([0], levels=[0])
    assert exc_info.value.status == 503
    assert exc_info.value.reason == "draining"


# ------------------------------ HTTP layer -----------------------------


def test_http_backpressure_429_retry_after_header(snapshot,
                                                  metrics_enabled):
    """Saturating a 1-worker endpoint yields immediate 429s carrying
    ``Retry-After``, counted in tacz_server_backpressure_total."""
    path, _ = snapshot
    with _serve(path, decode_workers=1, queue_depth=0,
                retry_after_s=0.25) as (httpd, url):
        httpd.region_server.fault_hook = lambda: time.sleep(0.4)
        cli = RegionClient(url, busy_retries=0)   # surface the 429s
        before = obsm.SERVER_BACKPRESSURE.labels("queue_full").value
        results, failures = [], []
        barrier = threading.Barrier(4)

        def request():
            barrier.wait()
            try:
                results.append(cli.regions(BOXES[:1], levels=[0]))
            except RegionAPIError as exc:
                failures.append(exc)

        threads = [threading.Thread(target=request) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert results, "someone must get through"
        assert failures, "a saturated endpoint must shed load"
        for exc in failures:
            assert exc.code == 429
            assert int(exc.headers["Retry-After"]) >= 1
            body = json.loads(exc.body_excerpt)
            assert body["reason"] == "queue_full"
        assert obsm.SERVER_BACKPRESSURE.labels("queue_full").value \
            >= before + len(failures)


def test_client_busy_retry_waits_out_saturation(snapshot):
    """With a retry budget, every request of a saturating burst lands —
    the client sleeps out the Retry-After hints instead of failing."""
    path, _ = snapshot
    with _serve(path, decode_workers=1, queue_depth=0,
                retry_after_s=0.1) as (httpd, url):
        httpd.region_server.fault_hook = lambda: time.sleep(0.05)
        cli = RegionClient(url, busy_retries=20, busy_backoff_cap=0.1)
        results, failures = [], []
        barrier = threading.Barrier(4)

        def request():
            barrier.wait()
            try:
                results.append(cli.regions(BOXES[:1], levels=[0]))
            except Exception as exc:  # noqa: BLE001 — any failure fails
                failures.append(exc)

        threads = [threading.Thread(target=request) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not failures, failures
        assert len(results) == 4


def test_oversized_batch_split_is_bit_identical(snapshot,
                                                metrics_enabled):
    """A multi-level batch splits into per-level decode units and still
    returns exactly what an unsplit server serves."""
    path, _ = snapshot
    with _serve(path) as (_httpd, url), RegionServer(path) as direct:
        before = obsm.SERVER_DECODE_UNITS.labels().value
        got = RegionClient(url).regions(BOXES)        # levels=None: all
        want = direct.get_regions(BOXES)
        assert obsm.SERVER_DECODE_UNITS.labels().value \
            == before + direct.n_levels
        for per_got, per_want in zip(got, want):
            assert len(per_got) == len(per_want) == direct.n_levels
            for g, w in zip(per_got, per_want):
                assert g.level == w.level and g.box == w.box
                assert np.array_equal(np.asarray(g.data),
                                      np.asarray(w.data))


def test_router_treats_busy_as_busy_not_down(snapshot):
    """A 429 from a shard makes the router wait and retry the same
    endpoint — never demote it, count an endpoint failure, or fall back
    locally."""
    path, _ = snapshot
    m = ShardMap(["s0"], seed=7)
    release = threading.Event()
    occupied = threading.Event()
    first = []
    lock = threading.Lock()

    def hook():
        with lock:
            mine = not first
            first.append(1)
        if mine:            # only the occupying request blocks
            occupied.set()
            release.wait(5)

    with _serve(path, shard_map=m, shard_id="s0", decode_workers=1,
                queue_depth=0, retry_after_s=0.2) as (httpd, url):
        httpd.region_server.fault_hook = hook
        occupier = threading.Thread(
            target=RegionClient(url, busy_retries=0).regions,
            args=(BOXES[:1],), kwargs={"levels": [0]}, daemon=True)
        occupier.start()
        assert occupied.wait(5)
        threading.Timer(0.3, release.set).start()
        with ShardedRegionRouter(path, m, {"s0": url}, busy_retries=10,
                                 busy_backoff_cap=0.25) as router, \
                RegionServer(path) as direct:
            out = router.get_regions(BOXES[:1], levels=[0])
            assert router.counters["retries"] >= 1
            assert router.counters["endpoint_failures"] == 0
            assert router.counters["demotions"] == 0
            assert router.counters["local_fallbacks"] == 0
            want = direct.get_regions(BOXES[:1], levels=[0])
            assert np.array_equal(np.asarray(out[0][0].data),
                                  np.asarray(want[0][0].data))
        occupier.join(timeout=5)


# ----------------------------- cache handoff ---------------------------


def test_shard_map_grow_moves_only_to_new_shard(snapshot):
    path, _ = snapshot
    with RegionServer(path) as srv:
        keys = list(srv.reader.subblock_keys())
    m = ShardMap(["s0", "s1"], seed=7)
    new_map, moved = m.grow("s2", keys)
    assert new_map.shards == ("s0", "s1", "s2")
    assert moved, "growing must move some keys"
    assert len(moved) < len(keys), "growing must not move everything"
    for k in moved:
        # rendezvous minimality: every moved key lands on the NEW shard
        assert new_map.owner(k) == "s2"
    for k in keys:
        if k not in moved:
            assert new_map.owner(k) == m.owner(k)


def test_cache_export_import_roundtrip(snapshot, metrics_enabled):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    with RegionServer(path, shard_map=m, shard_id="s0") as old, \
            RegionServer(path) as whole:
        old.get_regions([FULL])         # warm every owned sub-block
        new_map, moved = m.grow("s2", old.reader.subblock_keys())
        blob = old.cache_export(moved)
        with RegionServer(path, shard_map=new_map,
                          shard_id="s2") as new:
            summary = new.cache_import(blob)
            assert summary["imported"] > 0
            assert summary["skipped_foreign"] == 0
            assert summary["skipped_stale"] == 0
            assert summary["bytes"] > 0
            assert summary["snapshot_crc"] == new.snapshot_crc
            # imported bricks are really in the cache, bit-identical to
            # a fresh decode of the same sub-block
            gen = new.snapshot_crc
            hits = 0
            for li, sbi in moved:
                got = new.cache.peek((gen, li, sbi))
                if got is None:
                    continue            # moved from s1, not in the blob
                hits += 1
                ref = whole.cache.peek((whole.snapshot_crc, li, sbi))
                if ref is None:
                    whole.get_regions([FULL])
                    ref = whole.cache.peek((whole.snapshot_crc, li, sbi))
                assert np.array_equal(got, ref)
            assert hits == summary["imported"]


def test_cache_import_rejects_corruption_and_stale(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    with RegionServer(path, shard_map=m, shard_id="s0") as old:
        old.get_regions([FULL])
        new_map, moved = m.grow("s2", old.reader.subblock_keys())
        blob = old.cache_export(moved)
        with RegionServer(path, shard_map=new_map,
                          shard_id="s2") as new:
            # flip one payload byte: CRC gate must refuse, not ingest
            bad = bytearray(blob)
            bad[-1] ^= 0xFF
            with pytest.raises(ValueError, match="CRC mismatch"):
                new.cache_import(bytes(bad))
            assert new.cache.stats()["entries"] == 0
            # rewrite the generation: every entry skipped as stale
            hlen = struct.unpack_from("<I", blob)[0]
            head = json.loads(blob[4:4 + hlen])
            head["snapshot_crc"] = head["snapshot_crc"] + 1
            hdr = json.dumps(head, sort_keys=True).encode()
            stale = struct.pack("<I", len(hdr)) + hdr + blob[4 + hlen:]
            summary = new.cache_import(stale)
            assert summary["imported"] == 0
            # the blob holds every moved brick s0 owned (all were cached)
            assert summary["skipped_stale"] \
                == sum(1 for k in moved if m.owner(k) == "s0")


def test_reshard_drops_only_foreign_keys(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    with RegionServer(path, shard_map=m, shard_id="s0") as srv:
        srv.get_regions([FULL])
        entries_before = srv.cache.stats()["entries"]
        new_map, moved = m.grow("s2", srv.reader.subblock_keys())
        moved_from_s0 = [k for k in moved if m.owner(k) == "s0"]
        dropped = srv.reshard(new_map)
        # the full-domain warm-up cached every owned brick, so exactly
        # the bricks that changed owner get dropped
        assert dropped == len(moved_from_s0)
        assert srv.cache.stats()["entries"] == entries_before - dropped
        # what's left is exactly what the new map says s0 owns
        gen = srv.snapshot_crc
        for li, sbi in srv.reader.subblock_keys():
            cached = srv.cache.peek((gen, li, sbi)) is not None
            if cached:
                assert new_map.owner((li, sbi)) == "s0"


def test_http_cache_handoff_between_endpoints(snapshot):
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    new_map = m.with_shard("s2")
    with _serve(path, shard_map=m, shard_id="s0") as (_h0, url0), \
            _serve(path, shard_map=new_map, shard_id="s2") as (h2, url2):
        cli0, cli2 = RegionClient(url0), RegionClient(url2)
        cli0.regions([FULL])                    # warm every level of s0
        with RegionServer(path) as srv:
            _, moved = m.grow("s2", srv.reader.subblock_keys())
        blob = cli0.cache_export(moved)
        summary = cli2.cache_import(blob)
        assert summary["imported"] > 0
        assert h2.region_server.cache.stats()["entries"] \
            == summary["imported"]
        bad = bytearray(blob)
        bad[-1] ^= 0xFF
        with pytest.raises(RegionAPIError) as exc_info:
            cli2.cache_import(bytes(bad))
        assert exc_info.value.code == 400


def test_live_reshard_grow_fleet_serves_warm_and_correct(snapshot):
    """The full grow choreography: export/import moved bricks, router
    adopts the map, old owners reshard last — bit-identical before,
    during, and after, with zero endpoint failures or fallbacks."""
    path, _ = snapshot
    m = ShardMap(["s0", "s1"], seed=7)
    with RegionServer(path) as direct:
        want = direct.get_regions(BOXES)
        keys = list(direct.reader.subblock_keys())
    new_map, moved = m.grow("s2", keys)

    def check(router):
        got = router.get_regions(BOXES)
        for per_got, per_want in zip(got, want):
            for g, w in zip(per_got, per_want):
                assert np.array_equal(np.asarray(g.data),
                                      np.asarray(w.data))

    with _serve(path, shard_map=m, shard_id="s0") as (h0, url0), \
            _serve(path, shard_map=m, shard_id="s1") as (h1, url1):
        urls = {"s0": url0, "s1": url1}
        with ShardedRegionRouter(path, m, dict(urls)) as router:
            check(router)                        # warm the old fleet
            # (1) new shard comes up already on the new map
            with _serve(path, shard_map=new_map,
                        shard_id="s2") as (h2, url2):
                # (2) moved bricks hand off old -> new
                imported = 0
                for url in urls.values():
                    blob = RegionClient(url).cache_export(moved)
                    imported += RegionClient(url2).cache_import(
                        blob)["imported"]
                assert imported > 0, "handoff must move warm bricks"
                assert h2.region_server.cache.stats()["entries"] \
                    == imported
                # (3) router swaps to the grown fleet
                router.apply_shard_map(new_map,
                                       {**urls, "s2": url2})
                check(router)
                # (4) old owners drop moved keys only after the swap
                for h in (h0, h1):
                    h.region_server.reshard(new_map)
                check(router)
                assert router.counters["endpoint_failures"] == 0
                assert router.counters["local_fallbacks"] == 0


# ------------------------------- loadgen -------------------------------


def test_loadgen_actions_hook_runs_once_and_reports_errors():
    wl = ZipfWorkload(shape=(8, 8, 8), population=4, seed=1)
    gen = LoadGenerator(lambda q: [], wl, rate=500.0, concurrency=2)
    ran = []
    report = gen.run(10, actions={3: lambda: ran.append(1)})
    assert ran == [1]
    assert report.errors == 0

    def boom():
        raise RuntimeError("control-plane exploded")

    report = gen.run(10, actions={0: boom})
    assert report.errors == 1
    assert any(e.startswith("action@0") for e in report.error_messages)
    assert report.requests == 10          # requests still all ran
