"""Observability layer (ISSUE 7): metrics registry semantics, trace
spans, the Prometheus scrape surface, request-ID propagation through the
sharded fleet, and the no-behavior-change guarantee (served regions are
bit-identical with metrics enabled vs disabled).
"""
import logging
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as obsm
from repro.obs.registry import MetricsRegistry
from repro.serving import (RegionClient, RegionServer, ShardMap,
                           ShardedRegionRouter, serve)
from repro.serving.client import RegionAPIError

BOXES = [((0, 8), (0, 8), (0, 8)),
         ((5, 23), (11, 30), (2, 9)),
         ((24, 32), (16, 32), (0, 32))]


@pytest.fixture(scope="module")
def snapshot(make_amr_snapshot):
    snap = make_amr_snapshot(densities=[0.35, 0.65], seed=5, name="obs")
    return snap.path, snap.res


@pytest.fixture()
def registry():
    return MetricsRegistry()


@pytest.fixture()
def metrics_enabled():
    """Leave the process-wide registry the way we found it."""
    was = obs.is_enabled()
    obs.set_enabled(True)
    yield
    obs.set_enabled(was)


# ------------------------------ registry -------------------------------


def test_counter_concurrent_increments_exact(registry):
    """8 threads x 10k increments == exactly 80k — the registry's locking
    contract, not a statistical one."""
    c = registry.counter("t_total", "t").labels()
    n_threads, per_thread = 8, 10_000

    def worker():
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per_thread


def test_histogram_concurrent_observe_exact(registry):
    h = registry.histogram("t_seconds", "t", buckets=(1.0, 2.0)).labels()

    def worker():
        for _ in range(5_000):
            h.observe(0.5)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == 40_000
    assert h.sum == pytest.approx(20_000.0)


def test_histogram_bucket_boundaries(registry):
    """Prometheus `le` semantics: a sample equal to an upper bound counts
    in that bucket; above every bound goes to +Inf only."""
    h = registry.histogram("b_seconds", "t", buckets=(0.1, 1.0)).labels()
    for v in (0.05, 0.1, 0.5, 1.0, 2.0):
        h.observe(v)
    counts, total, n = h.snapshot()
    assert counts == [2, 2, 1]          # le=0.1, le=1.0, +Inf
    assert n == 5 and total == pytest.approx(3.65)


def test_histogram_quantiles(registry):
    h = registry.histogram("q_seconds", "t",
                           buckets=(0.001, 0.01, 0.1)).labels()
    assert h.quantile(0.5) is None      # no samples yet
    for _ in range(90):
        h.observe(0.005)
    for _ in range(10):
        h.observe(0.05)
    # p50 interpolates inside (0.001, 0.01]; p99 inside (0.01, 0.1]
    assert 0.001 < h.quantile(0.5) <= 0.01
    assert 0.01 < h.quantile(0.99) <= 0.1
    # the overflow bucket clamps to the largest finite bound
    h2 = registry.histogram("q2_seconds", "t", buckets=(0.1,)).labels()
    h2.observe(5.0)
    assert h2.quantile(0.99) == 0.1
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_prometheus_exposition_golden(registry):
    """Full text exposition, byte for byte — the scrape format is a wire
    contract (text/plain; version=0.0.4)."""
    registry.counter("req_total", "Requests.", labels=("route",)) \
        .labels("/v1/meta").inc(3)
    registry.gauge("occupancy_bytes", "Cache bytes.").set(1.5)
    h = registry.histogram("lat_seconds", "Latency.", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(2.0)
    assert registry.render() == (
        '# HELP req_total Requests.\n'
        '# TYPE req_total counter\n'
        'req_total{route="/v1/meta"} 3\n'
        '# HELP occupancy_bytes Cache bytes.\n'
        '# TYPE occupancy_bytes gauge\n'
        'occupancy_bytes 1.5\n'
        '# HELP lat_seconds Latency.\n'
        '# TYPE lat_seconds histogram\n'
        'lat_seconds_bucket{le="0.1"} 1\n'
        'lat_seconds_bucket{le="1"} 2\n'
        'lat_seconds_bucket{le="+Inf"} 3\n'
        'lat_seconds_sum 2.55\n'
        'lat_seconds_count 3\n')


def test_exposition_escapes_label_values(registry):
    registry.counter("esc_total", "t", labels=("p",)) \
        .labels('a"b\\c\nd').inc()
    assert 'esc_total{p="a\\"b\\\\c\\nd"} 1' in registry.render()


def test_family_get_or_create_and_mismatch(registry):
    a = registry.counter("same_total", "t", labels=("x",))
    assert registry.counter("same_total", "t", labels=("x",)) is a
    with pytest.raises(ValueError):
        registry.gauge("same_total", "t", labels=("x",))
    with pytest.raises(ValueError):
        registry.counter("same_total", "t", labels=("y",))
    with pytest.raises(ValueError):
        registry.counter("bad name", "t")
    with pytest.raises(ValueError):
        registry.histogram("bad_buckets", "t", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        a.labels()              # family declares one label
    with pytest.raises(ValueError):
        a.labels("x").inc(-1)   # counters only go up


def test_disabled_registry_mutations_are_noops(registry):
    c = registry.counter("off_total", "t").labels()
    g = registry.gauge("off_bytes", "t").labels()
    h = registry.histogram("off_seconds", "t").labels()
    registry.enabled = False
    c.inc()
    g.set(5)
    h.observe(1.0)
    assert (c.value, g.value, h.count) == (0.0, 0.0, 0)
    registry.enabled = True
    c.inc()
    assert c.value == 1.0


def test_registry_snapshot_shape(registry):
    registry.counter("s_total", "t", labels=("k",)).labels("a").inc(2)
    registry.histogram("s_seconds", "t", buckets=(1.0,)).observe(0.5)
    snap = registry.snapshot()
    assert snap["s_total"]["series"]["k=a"] == 2.0
    hs = snap["s_seconds"]["series"]["_"]
    assert hs["count"] == 1 and hs["buckets"] == [1, 0]


# ------------------------------- tracing -------------------------------


def test_trace_noop_outside_root():
    """Without an active root span, trace() hands back the shared no-op —
    instrumented code never pays for tree building."""
    s1, s2 = obs.trace("a"), obs.trace("b")
    assert s1 is s2
    with s1:
        pass                    # context manager still works


def test_root_span_collects_nested_stages():
    with obs.root_span("batch") as root:
        with obs.trace("plan"):
            pass
        with obs.trace("fetch"):
            with obs.trace("decode"):
                pass
    summary = root.summary()
    assert summary["name"] == "batch" and summary["ms"] >= 0
    names = [s["name"] for s in summary["stages"]]
    assert names == ["plan", "fetch"]
    assert summary["stages"][1]["stages"][0]["name"] == "decode"
    # the root is torn down: tracing is a no-op again
    assert obs.current_span() is None


def test_new_request_id_format():
    rid = obs.new_request_id()
    assert len(rid) == 16 and int(rid, 16) >= 0
    assert rid != obs.new_request_id()


# --------------------- no behavior change under metrics ----------------


def test_served_regions_bit_identical_enabled_vs_disabled(snapshot):
    """The whole point of obs being observe-only: byte-for-byte equal
    crops whether the registry records or not."""
    path, _ = snapshot
    was = obs.is_enabled()
    try:
        obs.set_enabled(True)
        with RegionServer(path, cache_bytes=4 << 20) as rs:
            ref = rs.get_regions(BOXES)
        obs.set_enabled(False)
        with RegionServer(path, cache_bytes=4 << 20) as rs:
            got = rs.get_regions(BOXES)
    finally:
        obs.set_enabled(was)
    for per_ref, per_got in zip(ref, got):
        for r, g in zip(per_ref, per_got):
            assert (r.level, r.ratio, r.box) == (g.level, g.ratio, g.box)
            np.testing.assert_array_equal(r.data, g.data)


# -------------------- scrape surface: single server --------------------


def test_single_server_scrape_and_stats(snapshot, metrics_enabled):
    path, _ = snapshot
    httpd = serve(path, port=0, cache_bytes=4 << 20)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        client = RegionClient(url)
        client.regions(BOXES)
        client.regions(BOXES)       # warm pass exercises the cache
        text = client.metrics_text()
        # the required coverage: cache, planner, server latency
        for needle in ("tacz_cache_hits", "tacz_cache_misses",
                       "tacz_cache_bytes", "tacz_cache_budget_bytes",
                       "tacz_planner_subblocks_total",
                       'outcome="cached"', 'outcome="decoded"',
                       "tacz_server_request_seconds_bucket",
                       "tacz_server_request_seconds_count",
                       "tacz_server_regions_total",
                       "tacz_http_requests_total",
                       'route="/v1/regions"'):
            assert needle in text, f"scrape missing {needle}"
        # exposition well-formedness: every non-comment line is
        # "name{labels} value"
        for line in text.splitlines():
            if line.startswith("#") or not line:
                continue
            name_part, _, value = line.rpartition(" ")
            assert name_part and value
            float(value.replace("+Inf", "inf"))
        # /v1/stats carries bucket-estimated latency quantiles
        stats = client.stats()
        lat = stats["latency"]
        assert lat["count"] >= 2
        assert 0 <= lat["p50_ms"] <= lat["p90_ms"] <= lat["p99_ms"]
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_request_id_echoed_and_minted(snapshot, metrics_enabled):
    path, _ = snapshot
    httpd = serve(path, port=0, cache_bytes=4 << 20)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        client = RegionClient(url)
        hdr, _ = client.regions_ex(BOXES[:1], request_id="deadbeef01234567")
        assert hdr["request_id"] == "deadbeef01234567"
        assert hdr["trace"]["name"] == "regions"
        assert [s["name"] for s in hdr["trace"]["stages"]] \
            == ["get_regions"]
        hdr2, _ = client.regions_ex(BOXES[:1])   # server mints one
        assert len(hdr2["request_id"]) == 16
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


def test_client_error_carries_status_body_and_request_id(snapshot):
    path, _ = snapshot
    httpd = serve(path, port=0, cache_bytes=4 << 20)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        client = RegionClient(url)
        with pytest.raises(RegionAPIError) as ei:
            client.regions([((0, 8), (0, 8))])      # 2D box -> 400
        err = ei.value
        assert err.code == 400
        assert "each box needs three" in err.body_excerpt
        assert len(err.request_id) == 16
        assert "request_id=" in str(err) and "HTTP 400" in str(err)
        # GET errors go through the same path
        with pytest.raises(RegionAPIError) as ei:
            client.region(99, BOXES[0])
        assert ei.value.code == 400
    finally:
        httpd.shutdown()
        httpd.server_close()
        httpd.region_server.close()


# --------------------- scrape surface: 2-shard fleet -------------------


def test_two_shard_fleet_metrics_and_request_id_in_access_logs(
        snapshot, metrics_enabled):
    """The acceptance scenario: a 2-shard fleet where the router's
    per-batch request ID shows up in every shard's structured access log,
    and the scrape covers the router fan-out series."""
    path, _ = snapshot
    records: list[logging.LogRecord] = []

    class _Capture(logging.Handler):
        def emit(self, record):
            records.append(record)

    handler = _Capture(level=logging.DEBUG)
    logger = logging.getLogger("repro.serving.http")
    old_level = logger.level
    logger.addHandler(handler)
    logger.setLevel(logging.DEBUG)

    m = ShardMap(["s0", "s1"], seed=7)
    servers, urls = {}, {}
    try:
        for sid in m.shards:
            httpd = serve(path, port=0, cache_bytes=4 << 20,
                          shard_map=m, shard_id=sid)
            threading.Thread(target=httpd.serve_forever,
                             daemon=True).start()
            servers[sid] = httpd
            urls[sid] = f"http://127.0.0.1:{httpd.server_address[1]}"
        with ShardedRegionRouter(path, m,
                                 {k: [v] for k, v in urls.items()}) \
                as router:
            out, meta = router.get_regions_meta(BOXES)
            rid = meta["request_id"]
            assert len(rid) == 16 and meta["ms"] > 0
            # every fan-out group reports shard, endpoint, timing, and
            # the shard's own span summary
            shards_hit = {info["shard"] for info in meta["shards"]}
            assert shards_hit == {"s0", "s1"}
            for info in meta["shards"]:
                assert info["endpoint"].startswith("http://")
                assert info["ms"] >= 0
                assert info["trace"]["name"] == "regions"
            # the handler logs after the response is read back — poll
            deadline = time.monotonic() + 5.0
            want = len(meta["shards"])
            while time.monotonic() < deadline:
                got = [r.getMessage() for r in records
                       if f"rid={rid}" in r.getMessage()]
                if len(got) >= want:
                    break
                time.sleep(0.01)
            assert len(got) >= want, got
            assert all("POST /v1/regions 200" in msg for msg in got)
            # scrape (via a shard endpoint — one process, one registry)
            # covers the router fan-out series
            text = RegionClient(urls["s0"]).metrics_text()
            for needle in ("tacz_router_batches_total",
                           "tacz_router_shard_requests_total",
                           'tacz_router_shard_seconds_count{shard="s0"}',
                           'tacz_router_shard_seconds_count{shard="s1"}',
                           "tacz_server_request_seconds_bucket",
                           "tacz_planner_subblocks_total",
                           "tacz_cache_hits"):
                assert needle in text, f"scrape missing {needle}"
            # plain get_regions keeps its signature
            plain = router.get_regions(BOXES[:1], levels=[0])
            np.testing.assert_array_equal(plain[0][0].data,
                                          out[0][0].data)
            stats = router.stats()
            for key in ("retries", "demotions"):
                assert key in stats
    finally:
        logger.removeHandler(handler)
        logger.setLevel(old_level)
        for httpd in servers.values():
            httpd.shutdown()
            httpd.server_close()
            httpd.region_server.close()


# ------------------------- pipeline coverage ---------------------------


def test_compress_and_writer_series_populate(snapshot, metrics_enabled,
                                             tmp_path):
    """The compress->write leg records stage timings and byte counters
    into the process registry."""
    from repro.core import amr
    from repro.io.writer import TACZWriter

    before = obsm.WRITER_BYTES.value
    ds = amr.synthetic_amr((16, 16, 16), densities=[1.0], seed=1)
    path = str(tmp_path / "obs.tacz")
    with TACZWriter(path, eb=1e-2) as w:
        for lv in ds.levels:
            w.add_level(lv.data, lv.mask, ratio=max(int(lv.ratio), 1))
    summary = w.obs_summary()
    assert summary["levels"] == len(ds.levels)
    assert summary["bytes"] > 0
    assert summary["encode_seconds"] >= 0
    assert obsm.WRITER_BYTES.value > before
    text = obs.REGISTRY.render()
    for needle in ('tacz_compress_stage_seconds_count{stage="prequant"}',
                   'tacz_compress_stage_seconds_count{stage="entropy"}',
                   "tacz_compress_level_seconds_count",
                   'tacz_writer_level_seconds_count{stage="encode"}',
                   "tacz_writer_bytes_total"):
        assert needle in text, f"missing {needle}"


def test_label_budget_routes_overflow_to_other(registry):
    """A family with ``max_series`` caps its cardinality: once the cap
    is hit, novel label values collapse into one ``__other__`` series
    instead of growing the scrape without bound."""
    fam = registry.counter("t_cap_total", "capped", labels=("variant",),
                           max_series=3)
    for i in range(10):
        fam.labels(f"v{i}").inc()
    # the first three names got real series; the other seven pooled
    for name in ("v0", "v1", "v2"):
        assert fam.labels(name).value == 1
    assert fam.labels("__other__").value == 7
    text = registry.render()
    assert 'variant="__other__"' in text
    assert text.count("t_cap_total{") == 4          # 3 real + overflow
    # existing series keep counting normally after the cap is hit
    fam.labels("v1").inc()
    assert fam.labels("v1").value == 2


def test_variant_requests_family_is_cardinality_bounded():
    """The process-wide variant counter carries the budget, so a client
    spraying distinct ``variant`` names cannot blow up the scrape."""
    assert obsm.VARIANT_REQUESTS.max_series == obsm.VARIANT_LABEL_BUDGET
