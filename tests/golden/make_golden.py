"""Regenerate the golden conformance fixtures.

Run from the repo root::

    PYTHONPATH=src python tests/golden/make_golden.py

The fixtures freeze the container formats on disk so a future encoder
or entropy-coder change that silently alters decoded bytes (or breaks
old files) fails ``tests/test_golden.py`` instead of shipping:

  * ``v1.tacz``            — version-1 container (pre-payload-codec era)
  * ``v2_zlib.tacz``       — version-2, zlib payload pass, TACF frontier
  * ``multipart.taczd/``   — two-part snapshot with a manifest frontier
  * ``truncated_tacf.tacz``— v2 file whose TACF body length field lies
    (the corrupt-frontier fault fixture: must open, decode bit-identical
    to ``v2_zlib.tacz``, and report ``frontier_error``)
  * ``expected.npz``       — the decoded per-level arrays all of the
    above must reproduce bit for bit

Everything is derived from one seeded synthetic dataset; regenerating
on the same numpy stack is byte-stable.  Do NOT regenerate casually —
the whole point is that these bytes never change.
"""
import os
import sys

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(HERE, "..", "..", "src"))

from repro import io as tacz                              # noqa: E402
from repro.core import amr, hybrid                        # noqa: E402
from repro.io import frontier as frt                      # noqa: E402
from repro.io import writer as tacz_writer                # noqa: E402

SEED = 1234
EB = 1e-3


def dataset():
    return amr.synthetic_amr((16, 16, 16), densities=[0.4, 0.6],
                             refine_block=4, seed=SEED)


def frontier(res):
    """A small frozen frontier whose default point is the written eb."""
    dp = frt.FrontierPoint(
        ebs=tuple(lr.eb for lr in res.levels), bits=res.total_bits,
        metrics={"psnr": 72.0, "max_abs_error": EB})
    loose = frt.FrontierPoint(
        ebs=tuple(4 * lr.eb for lr in res.levels),
        bits=max(1, res.total_bits // 2),
        metrics={"psnr": 58.0, "max_abs_error": 4 * EB})
    return frt.Frontier(metric="psnr", points=[loose, dp], default=1)


def main():
    ds = dataset()
    res = hybrid.compress_amr(ds, eb=EB)
    fr = frontier(res)

    # v1: no payload-codec pass existed yet
    packed = [tacz_writer.pack_level(lr, payload_codec="none")
              for lr in res.levels]
    with open(os.path.join(HERE, "v1.tacz"), "wb") as f:
        f.write(tacz_writer.build_container(packed, version=1))

    v2 = os.path.join(HERE, "v2_zlib.tacz")
    tacz.write(v2, res, payload_codec="zlib", frontier=fr)

    tacz.write_multipart(os.path.join(HERE, "multipart.taczd"), res,
                         parts=2, payload_codec="zlib", frontier=fr)

    # corrupt-TACF fault fixture: copy v2 and overstate the body length
    trunc = os.path.join(HERE, "truncated_tacf.tacz")
    with open(v2, "rb") as f:
        blob = bytearray(f.read())
    import struct
    from repro.io import format as fmt
    idx_off, idx_len, _ = fmt.parse_footer(bytes(blob[-fmt.FOOTER_SIZE:]))
    sec = idx_off + idx_len
    assert bytes(blob[sec:sec + 4]) == frt.FRONTIER_MAGIC
    blob[sec + 8:sec + 12] = struct.pack("<I", 0x7FFFFFFF)
    with open(trunc, "wb") as f:
        f.write(bytes(blob))

    recons = tacz.read(v2)
    np.savez_compressed(
        os.path.join(HERE, "expected.npz"),
        **{f"level{li}": r for li, r in enumerate(recons)},
        **{f"mask{li}": l.mask for li, l in enumerate(ds.levels)},
        **{f"orig{li}": l.data for li, l in enumerate(ds.levels)})
    print("golden fixtures written to", HERE)
    for name in sorted(os.listdir(HERE)):
        p = os.path.join(HERE, name)
        if os.path.isfile(p):
            print(f"  {name:24s} {os.path.getsize(p):7d} B")


if __name__ == "__main__":
    main()
