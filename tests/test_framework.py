"""Framework integration: training loop, checkpoint/restore (lossless +
lossy + elastic), resilience (preemption, failure injection, watchdog),
gradient compression, serving engine, sharding rules."""
import os
import tempfile
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import amr_token_batches, lm_batches
from repro.launch.mesh import make_smoke_mesh
from repro.launch.sharding import rules_for
from repro.launch.train import init_train_state, make_train_step, train_loop
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import (compress_pod_reduce,
                                       init_error_feedback)

CFG = smoke_config("deepseek_7b")
SHAPE = ShapeConfig("t", "train", seq_len=32, global_batch=4)
KEY = jax.random.PRNGKey(0)


def _loop(steps, ckpt_dir=None, **kw):
    run = RunConfig(microbatches=1)
    mesh = make_smoke_mesh()
    return train_loop(CFG, run, mesh, lm_batches(CFG, SHAPE, seed=0),
                      steps=steps, opt_cfg=AdamWConfig(lr=1e-3),
                      checkpoint_dir=ckpt_dir, checkpoint_every=5,
                      log_every=2, **kw)


@pytest.mark.slow
def test_loss_decreases():
    _, _, hist = _loop(20)
    assert hist[-1][1] < hist[0][1]


@pytest.mark.slow
def test_microbatch_equivalence():
    """mb=1 and mb=2 produce (nearly) the same update for the same batch."""
    mesh = make_smoke_mesh()
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = next(lm_batches(CFG, SHAPE, seed=0))
    outs = []
    for mb in (1, 2):
        run = RunConfig(microbatches=mb)
        step, _, _ = make_train_step(CFG, run, mesh, opt_cfg)
        params, opt_state = init_train_state(CFG, run, mesh, KEY, opt_cfg)
        p2, _, m = jax.jit(step)(params, opt_state, batch)
        outs.append((np.asarray(jax.tree.leaves(p2)[0], np.float32),
                     float(m["loss"])))
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-3)
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=2e-2, atol=2e-4)


@pytest.mark.slow
def test_checkpoint_roundtrip_and_resume():
    with tempfile.TemporaryDirectory() as d:
        p1, o1, h1 = _loop(6, ckpt_dir=d)
        # fresh loop resumes from step 5 checkpoint
        p2, o2, h2 = _loop(8, ckpt_dir=d)
        assert h2[0][0] >= 5


def test_checkpoint_lossy_mode_bounds_error():
    from repro.checkpoint.manager import CheckpointManager
    from repro.models.model import model_specs
    from repro.models.layers import init_from_specs

    params = init_from_specs(model_specs(CFG), KEY)
    # trained weights have structure; random init doesn't compress.  Give
    # every big tensor a smooth low-rank component so the size comparison
    # reflects the real use case.
    def smooth(p):
        if p.ndim >= 2 and p.size > 4096:
            r = jnp.arange(p.shape[-2], dtype=jnp.float32)
            c = jnp.arange(p.shape[-1], dtype=jnp.float32)
            field = jnp.sin(r[:, None] / 9.0) * jnp.cos(c[None, :] / 7.0)
            return (field * 0.02 + 0.001 * p.astype(jnp.float32)
                    ).astype(p.dtype)
        return p

    params = jax.tree.map(smooth, params)
    opt = {"step": jnp.zeros((), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, lossy_eb_rel=1e-3)
        mgr.save(1, params, opt, blocking=True)
        size = os.path.getsize(os.path.join(d, "step_00000001.npz"))
        rp, ro, step = mgr.restore(1)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rp)):
            dt = a.dtype
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32)
            rng = np.abs(a).max()
            if a.size > 4096 and a.ndim >= 2 and rng > 0:
                # bound + half-ulp of the output dtype (bf16: 2^-9 rel)
                ulp = 2.0 ** -9 if str(dt) == "bfloat16" else 2.0 ** -24
                assert np.abs(a - b).max() <= (1e-3 + ulp) * rng * (1 + 1e-3)
            else:
                np.testing.assert_array_equal(a, b)
        # lossless copy for size comparison
        mgr2 = CheckpointManager(d + "_ll", lossy_eb_rel=0.0)
        os.makedirs(d + "_ll", exist_ok=True)
        mgr2.save(1, params, opt, blocking=True)
        size_ll = os.path.getsize(os.path.join(d + "_ll",
                                               "step_00000001.npz"))
        assert size < size_ll  # lossy is actually smaller


def test_checkpoint_corruption_detected():
    from repro.checkpoint.manager import CheckpointManager

    params = {"w": jnp.ones((8, 8), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, params, {"step": jnp.zeros((), jnp.int32)},
                 blocking=True)
        # flip bytes in the npz payload
        f = os.path.join(d, "step_00000001.npz")
        data = bytearray(open(f, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(f, "wb").write(bytes(data))
        with pytest.raises(Exception):
            mgr.restore(1)


def test_preemption_checkpoint_and_stop():
    from repro.runtime.resilience import PreemptionGuard

    g = PreemptionGuard(signals=())
    assert not g.should_stop
    g.trigger()
    assert g.should_stop


@pytest.mark.slow
def test_failure_injection_and_restart_recovery():
    from repro.runtime.resilience import FailureInjector, SimulatedFailure

    inj = FailureInjector(fail_at_step=3)
    with tempfile.TemporaryDirectory() as d:
        mesh = make_smoke_mesh()
        run = RunConfig()
        opt_cfg = AdamWConfig(lr=1e-3)
        step_fn, _, _ = make_train_step(CFG, run, mesh, opt_cfg)
        jit_step = jax.jit(step_fn)
        params, opt_state = init_train_state(CFG, run, mesh, KEY, opt_cfg)
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(d)
        data = lm_batches(CFG, SHAPE, seed=0)
        try:
            for s in range(6):
                inj.check(s)
                params, opt_state, m = jit_step(params, opt_state,
                                                next(data))
                mgr.save(s + 1, params, opt_state, blocking=True)
        except SimulatedFailure:
            pass
        # recovery: restart from latest checkpoint (step 3)
        restored = mgr.restore_latest()
        assert restored is not None and restored[2] == 3


def test_watchdog_flags_stragglers():
    import time
    from repro.runtime.resilience import StepWatchdog

    wd = StepWatchdog(straggler_factor=5.0)
    for s in range(8):
        with wd.step(s):
            time.sleep(0.06 if s == 7 else 0.002)
    assert any(i == 7 for i, _, _ in wd.stragglers)


def test_grad_compress_error_bound_and_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64, 64)).astype(np.float32))}
    ef = init_error_feedback(g)
    out, ef2 = compress_pod_reduce(g, ef, pod_axis=None, n_pods=1)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
    # quantization with error feedback: residual is exactly what was lost
    from repro.optim.grad_compress import _dequant_leaf, _quant_leaf
    q, s = _quant_leaf(g["w"])
    deq = _dequant_leaf(q, s, g["w"].shape)
    resid = np.asarray(g["w"]) - np.asarray(deq)
    scale_per_el = np.repeat(np.asarray(s), 256)[:64 * 64].reshape(64, 64)
    assert (np.abs(resid) <= scale_per_el * 0.5 + 1e-7).all()


def test_serving_engine_generates():
    from repro.serving.engine import ServingEngine

    from repro.models.layers import init_from_specs
    from repro.models.model import model_specs

    cfg = smoke_config("deepseek_7b")
    params = init_from_specs(model_specs(cfg), KEY)
    eng = ServingEngine(cfg, RunConfig())
    prompts = jnp.asarray(np.arange(12).reshape(2, 6) % cfg.vocab_size,
                          jnp.int32)
    out = eng.generate(params, prompts, new_tokens=4)
    assert out.shape == (2, 4)
    # greedy generation is deterministic
    out2 = eng.generate(params, prompts, new_tokens=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_kv_quant_decode_close_to_exact():
    from repro.serving.engine import ServingEngine
    from repro.models.layers import init_from_specs
    from repro.models.model import model_specs

    cfg = smoke_config("deepseek_7b")
    params = init_from_specs(model_specs(cfg), KEY)
    prompts = jnp.asarray(np.arange(16).reshape(2, 8) % cfg.vocab_size,
                          jnp.int32)
    exact = ServingEngine(cfg, RunConfig()).generate(
        params, prompts, new_tokens=6)
    quant = ServingEngine(cfg, RunConfig(kv_quant=True)).generate(
        params, prompts, new_tokens=6)
    # int8 KV with random-init weights: most greedy tokens agree
    agree = (np.asarray(exact) == np.asarray(quant)).mean()
    assert agree >= 0.5, agree


class _StubMesh:
    """Duck-typed 16×16 production mesh for divisibility-rule tests."""

    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_sharding_rules_fallbacks():
    from jax.sharding import PartitionSpec as P

    run = RunConfig(fsdp=True)
    rules = rules_for(_StubMesh(), run)
    mesh = _StubMesh()
    # divisible → sharded
    spec = rules.partition_spec(("embed", "heads"), shape=(32, 32), mesh=mesh)
    assert spec == P("data", "model")
    # indivisible (e.g. 24 heads on a 16-wide axis) → replicated for params
    spec = rules.partition_spec(("embed", "heads"), shape=(32, 24), mesh=mesh)
    assert spec == P("data")
    # activations fall back to UNCONSTRAINED instead
    spec = rules.partition_spec(("batch", "heads"), shape=(7, 24), mesh=mesh,
                                unconstrained_fallback=True)
    assert spec[0] is P.UNCONSTRAINED and spec[1] is P.UNCONSTRAINED
    # batch divisible → (pod,)data
    spec = rules.partition_spec(("batch", None), shape=(32, 4), mesh=mesh,
                                unconstrained_fallback=True)
    assert spec[0] == "data"


def test_amr_token_pipeline_bridges_planes():
    cfg = smoke_config("deepseek_7b")
    b = next(amr_token_batches(cfg, SHAPE))
    assert b["tokens"].shape == (4, 32)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab_size).all()


def test_data_pipeline_deterministic_and_elastic():
    b1 = next(lm_batches(CFG, SHAPE, seed=3))
    b2 = next(lm_batches(CFG, SHAPE, seed=3))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the batch
    half = next(lm_batches(CFG, SHAPE, seed=3, host_id=0, n_hosts=2))
    assert half["tokens"].shape[0] == SHAPE.global_batch // 2
