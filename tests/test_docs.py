"""Documentation stays honest (ISSUE 4 acceptance): the byte-level
format spec's field tables must match the constants in ``io/format.py``,
both docs must exist, and the README must link them."""
import os
import re

import pytest

from repro.io import format as fmt
from repro.io import manifest as mfst
from repro.io import placement

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(rel: str) -> str:
    path = os.path.join(REPO, rel)
    assert os.path.exists(path), f"missing {rel}"
    with open(path, encoding="utf-8") as f:
        return f.read()


@pytest.fixture(scope="module")
def format_doc() -> str:
    return _read("docs/tacz_format.md")


@pytest.fixture(scope="module")
def serving_doc() -> str:
    return _read("docs/serving.md")


@pytest.fixture(scope="module")
def obs_doc() -> str:
    return _read("docs/observability.md")


@pytest.fixture(scope="module")
def tuning_doc() -> str:
    return _read("docs/tuning.md")


def test_readme_links_both_docs():
    readme = _read("README.md")
    assert "docs/tacz_format.md" in readme
    assert "docs/serving.md" in readme
    assert "docs/observability.md" in readme
    assert "docs/tuning.md" in readme


def test_format_doc_enum_tables_match_constants(format_doc):
    """Every enum row in the spec is `| CONSTANT | value | ...` — each
    must agree with the live constant, and no constant may be missing."""
    enums = ["STRATEGY_OPST", "STRATEGY_AKDTREE", "STRATEGY_GSP",
             "STRATEGY_GLOBAL", "STRATEGY_NAST",
             "ALGO_LOR_REG", "ALGO_LORENZO", "ALGO_INTERP",
             "BRANCH_LORENZO", "BRANCH_REG", "BRANCH_INTERP",
             "CODEC_HUFFMAN", "CODEC_RAW_I16", "CODEC_RAW_I32",
             "COMPRESSOR_NONE", "COMPRESSOR_ZLIB", "COMPRESSOR_ZSTD"]
    for name in enums:
        value = getattr(fmt, name)
        assert f"| `{name}` | {value} |" in format_doc, \
            f"doc table row for {name} missing or stale (expect {value})"
    # and the doc names no enum value the module does not have
    for name, value in re.findall(r"^\| `([A-Z_0-9]+)` \| (\d+) \|",
                                  format_doc, flags=re.MULTILINE):
        assert int(value) == getattr(fmt, name), \
            f"doc claims {name}={value}, module says {getattr(fmt, name)}"


def test_format_doc_enum_names_match_wire_maps(format_doc):
    for names in (fmt.STRATEGY_NAMES, fmt.ALGO_NAMES, fmt.BRANCH_NAMES):
        for code, name in names.items():
            pat = re.compile(r"\| `[A-Z_0-9]+` \| %d \| `%s` \|"
                             % (code, re.escape(name)))
            assert pat.search(format_doc), \
                f"doc missing name row for code {code} -> {name!r}"


def test_format_doc_struct_strings_match(format_doc):
    """The spec quotes every wire struct verbatim; a format change in the
    module must force a doc update."""
    for struct_obj in (fmt._HEADER, fmt._FOOTER, fmt._LEVEL_HEAD,
                       fmt._LEVEL_HEAD_V1, fmt._LEVEL_SECTIONS,
                       fmt._SUBBLOCK):
        assert f"`{struct_obj.format}`" in format_doc, \
            f"struct string {struct_obj.format!r} not documented"


def test_format_doc_framing_constants(format_doc):
    assert f"HEADER ({fmt.HEADER_SIZE} B)" in format_doc
    assert f"FOOTER ({fmt.FOOTER_SIZE} B)" in format_doc
    assert f'`"{fmt.TACZ_MAGIC.decode()}"`' in format_doc
    assert f"Current version: **{fmt.TACZ_VERSION}**" in format_doc
    assert f"rank ≤ {fmt.MAX_RANK}" in format_doc


def test_format_doc_multipart_manifest_spec(format_doc):
    """§9 (multi-part snapshots) must stay in sync with the manifest and
    placement modules: names, magic, version, algorithm, field table."""
    assert f'`"{mfst.MANIFEST_MAGIC}"`' in format_doc
    assert f"currently **{mfst.MANIFEST_VERSION}**" in format_doc
    assert f"`{placement.ALGORITHM}`" in format_doc
    assert f"`{mfst.MANIFEST_NAME}`" in format_doc
    assert f"`{mfst.part_name(0)}`" in format_doc
    for field in ["magic", "version", "n_levels", "subblocks",
                  "partition", "parts", "crc32"]:
        assert f"| `{field}` |" in format_doc, \
            f"manifest field {field} missing from the §9 table"
    # the CRC rule (canonical serialization) must be spelled out
    assert "sorted keys" in format_doc


def test_serving_doc_covers_required_topics(serving_doc):
    """The architecture guide must keep covering what ISSUEs 4 + 5
    scoped."""
    for needle in ["SubBlockCache", "DecodePlanner", "RegionServer",
                   "POST /v1/regions", "GET /v1/meta", "X-TACZ-",
                   "cache_bytes", "maybe_reload", "ShardMap",
                   "ShardedRegionRouter", "rendezvous", "index_crc",
                   "tacz_format.md", "load_balance", "manifest.json",
                   "open_snapshot", "ParallelTACZWriter", "open_parts",
                   "entropy_engine", "EntropyEngine", "decode_subblocks",
                   "repro.core.entropy"]:
        assert needle in serving_doc, f"serving.md lost coverage: {needle}"


def test_format_doc_entropy_framing_note(format_doc):
    """§4's engine-independence note: the batched entropy engines must
    never be allowed to change the wire format."""
    assert "repro.core.entropy" in format_doc
    assert "engine-independent" in format_doc
    assert "byte-identical payloads" in format_doc


def test_tuning_doc_spec_matches_constants(tuning_doc):
    """The TACF section spec and the variant-catalog spec in tuning.md
    must agree with the live constants — a wire change forces a doc
    change."""
    from repro.io import frontier as frt
    from repro.io import variants as vrt
    assert f'`"{frt.FRONTIER_MAGIC.decode()}"`' in tuning_doc
    assert f"section version: **{frt.FRONTIER_VERSION}**" in tuning_doc
    assert f"`{frt._SECTION_HEAD.format}`" in tuning_doc, \
        "TACF struct string not documented verbatim"
    assert f"SECTION_HEAD ({frt.SECTION_HEAD_SIZE} B)" in tuning_doc
    for metric in frt.HIGHER_IS_BETTER:
        assert f"`{metric}`" in tuning_doc, f"metric {metric} undocumented"
    for op in (">=", "<=", ">", "<"):
        assert op in tuning_doc
    assert f'`"{vrt.VARIANTS_MAGIC}"`' in tuning_doc
    assert f"currently **{vrt.VARIANTS_VERSION}**" in tuning_doc
    assert f"`{vrt.VARIANTS_NAME}`" in tuning_doc
    for fld in ["magic", "version", "default", "variants", "crc32",
                "name", "file", "target", "ebs", "bits", "metrics"]:
        assert f"| `{fld}` |" in tuning_doc, \
            f"catalog field {fld} missing from the tables"
    # the canonical-JSON CRC rule must be spelled out
    assert "sorted keys" in tuning_doc


def test_tuning_doc_covers_required_topics(tuning_doc):
    for needle in ["AutoTuner", "write_variant_set", "measure_metrics",
                   "TuneResult", "coordinate descent", "Pareto",
                   "Frontier", "FrontierPoint", "TargetUnsatisfiable",
                   "parse_target", "HIGHER_IS_BETTER", "set_frontier",
                   "frontier_error", "variants.json", "VariantServer",
                   "get_regions_ex", "X-TACZ-Variant", "HTTP 400",
                   "tacz_variant_requests_total",
                   "tacz_variant_fallbacks_total",
                   "tacz_variant_unsatisfied_total",
                   "load_catalog", "select_variant", "is_variant_set",
                   "bench_autotune", "serving.md", "tacz_format.md",
                   "observability.md", "psnr>=60"]:
        assert needle in tuning_doc, f"tuning.md lost coverage: {needle}"


def test_tuning_doc_references_live_apis():
    import inspect

    from repro import io as repro_io
    from repro import serving, tuning

    for attr in ("AutoTuner", "TuneResult", "measure_metrics",
                 "write_variant_set"):
        assert hasattr(tuning, attr)
    for attr in ("Frontier", "FrontierPoint", "Target",
                 "TargetUnsatisfiable", "parse_target", "is_variant_set",
                 "load_catalog", "select_variant"):
        assert hasattr(repro_io, attr)
    assert hasattr(serving, "VariantServer")
    for cls in (serving.RegionServer, serving.VariantServer,
                serving.ShardedRegionRouter):
        params = inspect.signature(cls.get_regions_ex).parameters
        assert "target" in params and "variant" in params, cls
    for meth in (serving.RegionClient.regions_ex,
                 serving.RegionClient.region):
        params = inspect.signature(meth).parameters
        assert "target" in params and "variant" in params, meth
    from repro.io.parallel import ParallelTACZWriter
    from repro.io.writer import TACZWriter
    assert hasattr(TACZWriter, "set_frontier")
    assert hasattr(ParallelTACZWriter, "set_frontier")


def test_serving_doc_covers_distortion_targets(serving_doc):
    for needle in ["VariantServer", "tuning.md", "target", "variant",
                   "X-TACZ-Variant", "variants.json", "400"]:
        assert needle in serving_doc, f"serving.md lost coverage: {needle}"


def test_serving_doc_covers_admission_and_resharding(serving_doc):
    """ISSUE 10: the async core, backpressure semantics, and the live
    resharding choreography must stay documented."""
    for needle in ["AsyncServingCore", "decode_workers", "queue_depth",
                   "429", "Retry-After", "503", "draining",
                   "tacz_server_backpressure_total", "decode unit",
                   "POST /v1/cache/export", "POST /v1/cache/import",
                   "ShardMap.grow", "apply_shard_map", "reshard",
                   "skipped_stale", "skipped_foreign", "all-or-nothing",
                   "Busy is not down", "Ordering matters", "memoryview",
                   "bench_loadgen"]:
        assert needle in serving_doc, f"serving.md lost coverage: {needle}"


def test_obs_doc_covers_backpressure_and_handoff(obs_doc):
    for needle in ["tacz_server_backpressure_total",
                   "tacz_server_decode_units_total",
                   "tacz_server_queue_depth",
                   "tacz_cache_handoff_keys_total",
                   "tacz_cache_handoff_bytes_total",
                   "VARIANT_LABEL_BUDGET", "__other__"]:
        assert needle in obs_doc, f"observability.md lost coverage: {needle}"


def test_obs_doc_metric_catalog_matches_registry(obs_doc):
    """The catalog table must name every family in the default registry
    with its exact type, and name nothing the registry does not have."""
    from repro.obs import metrics as obsm
    families = {f.name: f for f in obsm.REGISTRY.families()}
    rows = dict(re.findall(r"^\| `(tacz_[a-z_]+)` \| (\w+) \|",
                           obs_doc, flags=re.MULTILINE))
    for name, fam in families.items():
        assert rows.get(name) == fam.kind, \
            f"catalog row for {name} missing or stale (kind={fam.kind})"
    for name in rows:
        assert name in families, f"doc names unknown metric {name}"


def test_obs_doc_covers_required_topics(obs_doc):
    for needle in ["GET /v1/metrics", "text/plain; version=0.0.4",
                   "X-Repro-Request-Id", "root_span", "set_enabled",
                   "repro.serving.http", "RegionAPIError", "regions_ex",
                   "obs_summary", "0.95", "p50_ms", "quantile",
                   "DEFAULT_TIME_BUCKETS", "get_regions_meta",
                   # ISSUE 8: the fleet observability plane
                   "GET /v1/health", "FleetCollector", "SLOEngine",
                   "SLORule", "for_seconds", "log_json", "metrics_text",
                   "ZipfWorkload", "LoadGenerator", "open-loop",
                   "counter-reset", "fleet_families", "dump_json",
                   "verify_reader", "bench_loadgen", "local_fallback",
                   "up_fraction"]:
        assert needle in obs_doc, f"observability.md lost coverage: {needle}"


def test_obs_doc_slo_rule_table_matches_rule_types(obs_doc):
    """The SLO rule table must name every rule kind the engine knows
    with its exact contract line, and nothing the engine does not."""
    from repro.obs import slo
    assert "## SLO rules" in obs_doc
    section = obs_doc.split("## SLO rules", 1)[1].split("\n## ", 1)[0]
    rows = {}
    for kind, contract in re.findall(r"^\| `([a-z_]+)` \| (.+) \|$",
                                     section, flags=re.MULTILINE):
        rows[kind] = contract.replace("\\|", "|")
    for kind, doc in slo.RULE_TYPES.items():
        assert rows.get(kind) == doc, \
            f"rule table row for {kind!r} missing or stale\n" \
            f"  doc:    {rows.get(kind)!r}\n  engine: {doc!r}"
    for kind in rows:
        assert kind in slo.RULE_TYPES, \
            f"doc names unknown SLO rule kind {kind!r}"


def test_obs_doc_references_fleet_apis():
    import inspect

    from repro import obs, serving

    for attr in ("FleetCollector", "Scrape", "SLOEngine", "SLORule",
                 "RULE_TYPES", "ParsedFamily", "ParsedHistogram",
                 "quantile_from_buckets", "expo"):
        assert hasattr(obs, attr)
    for attr in ("LoadGenerator", "LoadReport", "ZipfWorkload",
                 "client_fetch"):
        assert hasattr(serving, attr)
    for attr in ("health", "metrics_text", "metrics"):
        assert hasattr(serving.RegionClient, attr)
    assert hasattr(serving.RegionServer, "health")
    assert hasattr(serving.ShardedRegionRouter, "health")
    assert "log_json" in inspect.signature(serving.serve).parameters
    for method in ("poll", "counter_delta", "counter_rate", "quantile",
                   "gauge", "fleet_families", "snapshot", "dump_json",
                   "up_fraction"):
        assert hasattr(obs.FleetCollector, method)
    for method in ("evaluate", "firing", "passed", "verdict", "report"):
        assert hasattr(obs.SLOEngine, method)


def test_serving_doc_covers_observability_surface(serving_doc):
    for needle in ["GET /v1/metrics", "request_id", "trace",
                   "X-Repro-Request-Id", "observability.md",
                   "RegionAPIError"]:
        assert needle in serving_doc, f"serving.md lost coverage: {needle}"


def test_obs_doc_references_live_apis():
    import inspect

    from repro import obs, serving
    from repro.serving.client import RegionAPIError  # noqa: F401
    from repro.serving.sharded import ShardedRegionRouter

    for attr in ("REGISTRY", "set_enabled", "is_enabled", "trace",
                 "root_span", "new_request_id", "REQUEST_ID_HEADER",
                 "MetricsRegistry", "DEFAULT_TIME_BUCKETS"):
        assert hasattr(obs, attr)
    for attr in ("regions_ex", "metrics"):
        assert hasattr(serving.RegionClient, attr)
    assert hasattr(ShardedRegionRouter, "get_regions_meta")
    assert "verbose" in inspect.signature(serving.serve).parameters
    from repro.io.writer import TACZWriter
    assert hasattr(TACZWriter, "obs_summary")


def test_docs_reference_live_apis(serving_doc):
    """Spot-check that the APIs the guide names still exist."""
    from repro import io as repro_io
    from repro import serving
    from repro.io.parallel import MultiPartReader
    from repro.io.reader import TACZReader
    from repro.serving.sharded import ShardedRegionRouter
    import inspect
    for attr in ("SubBlockCache", "DecodePlanner", "RegionServer",
                 "ShardMap", "ShardedRegionRouter", "RegionClient",
                 "serve"):
        assert hasattr(serving, attr)
    for attr in ("subblock_keys", "level_signature", "read_level_box",
                 "read_roi", "decode_subblocks"):
        assert hasattr(TACZReader, attr)
    from repro.core import entropy
    for name in ("auto", "numpy", "batched", "pallas"):
        assert name in entropy.ENGINE_NAMES
    assert "entropy_engine" in inspect.signature(
        serving.RegionServer.__init__).parameters
    for attr in ("open_snapshot", "write_multipart", "ParallelTACZWriter",
                 "MultiPartReader"):
        assert hasattr(repro_io, attr)
    for attr in ("open_parts", "partition", "part_names"):
        assert hasattr(MultiPartReader, attr)
    assert "load_balance" in inspect.signature(
        ShardedRegionRouter.__init__).parameters
