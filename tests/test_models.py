"""Per-arch smoke tests (assignment deliverable f) + family-level
correctness: decode == train consistency, chunked == sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.layers import init_from_specs
from repro.models.model import (forward, init_decode_state, model_specs,
                                param_counts)

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.input_mode == "tokens":
        return dict(tokens=jax.random.randint(k, (B, S), 0, cfg.vocab_size))
    return dict(embeds=jax.random.normal(k, (B, S, cfg.d_model), jnp.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_from_specs(model_specs(cfg), KEY)
    B, S = 2, 32
    logits, aux = forward(params, cfg, **_inputs(cfg, B, S), mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One real optimizer step on CPU: loss finite, params change."""
    from repro.configs import RunConfig
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import embedding_batches, lm_batches
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.train import init_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig

    cfg = smoke_config(arch)
    run = RunConfig(microbatches=1, remat="layer")
    mesh = make_smoke_mesh()
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=4)
    stream = (lm_batches(cfg, shape) if cfg.input_mode == "tokens"
              else embedding_batches(cfg, shape))
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1)
    step, rules, opt_cfg = make_train_step(cfg, run, mesh, opt_cfg)
    params, opt_state = init_train_state(cfg, run, mesh, KEY, opt_cfg)
    before = np.asarray(params["lm_head"], np.float32).copy()
    params, opt_state, metrics = jax.jit(step)(params, opt_state, next(stream))
    assert np.isfinite(float(metrics["loss"]))
    assert not np.allclose(before, np.asarray(params["lm_head"], np.float32))


@pytest.mark.parametrize("arch,tol", [
    ("deepseek_7b", 1e-2), ("starcoder2_3b", 1e-2), ("qwen1_5_32b", 1e-2),
    ("musicgen_medium", 1e-2), ("internvl2_76b", 1e-2), ("llama3_405b", 1e-2),
    ("rwkv6_7b", 1e-4),
    ("zamba2_2_7b", 2e-2),
    ("granite_moe_1b_a400m", 1e-2),
    ("qwen3_moe_30b_a3b", 1e-2)])
def test_decode_matches_train_logits(arch, tol):
    """Serve-path correctness: decode at position S-1 == train logits there.

    The decode path intentionally uses a different attention algorithm
    (single masked einsum) than train/prefill (online-softmax flash scan) —
    mathematically identical, so agreement is to bf16 numerics, not bits.
    MoE additionally needs drop-free capacity for comparability (capacity
    semantics differ between batch sizes)."""
    cfg = smoke_config(arch)
    if cfg.n_experts:
        cfg = replace(cfg, capacity_factor=64.0)
    params = init_from_specs(model_specs(cfg), KEY)
    B, S = 2, 24
    inp = _inputs(cfg, B, S, seed=1)
    full, _ = forward(params, cfg, **inp, mode="train")
    pre_inp = {k: v[:, :S - 1] for k, v in inp.items()}
    dec_inp = {k: v[:, S - 1:] for k, v in inp.items()}
    _, aux = forward(params, cfg, **pre_inp, mode="prefill")
    state = aux["state"]
    if cfg.family == "hybrid":
        state = {"mamba": state["mamba"],
                 "kv": jax.tree.map(
                     lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 1),
                                           (0, 0), (0, 0))), state["kv"])}
    elif cfg.family != "ssm":
        state = jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
            state)
    dec, _ = forward(params, cfg, **dec_inp, mode="decode", state=state,
                     cache_len=jnp.int32(S - 1))
    a = np.asarray(full[:, -1].astype(jnp.float32))
    b = np.asarray(dec[:, 0].astype(jnp.float32))
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel <= tol + 1e-9, rel


def test_rwkv_chunked_matches_sequential():
    """WKV6 chunked form == step-by-step recurrence (fp32 oracle)."""
    from repro.models.rwkv import init_rwkv_state, rwkv6_apply, rwkv6_specs

    cfg = replace(smoke_config("rwkv6_7b"), dtype="float32")
    specs = rwkv6_specs(cfg)
    params = init_from_specs(specs, KEY)
    B, S = 2, 40
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    out_chunk, st_chunk = rwkv6_apply(params, x, cfg, mode="train", chunk=8)
    # sequential: decode one token at a time
    st = init_rwkv_state(cfg, B)
    st = jax.tree.map(lambda a: a.astype(jnp.float32)
                      if a.dtype == jnp.bfloat16 else a, st)
    outs = []
    for t in range(S):
        o, st = rwkv6_apply(params, x[:, t:t + 1], cfg, mode="decode",
                            state=st)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk["wkv"]),
                               np.asarray(st["wkv"]), rtol=2e-3, atol=2e-3)


def test_mamba2_chunked_matches_sequential():
    from repro.models.ssm import init_mamba_state, mamba2_apply, mamba2_specs

    cfg = replace(smoke_config("zamba2_2_7b"), dtype="float32")
    params = init_from_specs(mamba2_specs(cfg), KEY)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    out_chunk, st_chunk = mamba2_apply(params, x, cfg, mode="train", chunk=8)
    st = init_mamba_state(cfg, B)
    st = jax.tree.map(lambda a: a.astype(jnp.float32), st)
    outs = []
    for t in range(S):
        o, st = mamba2_apply(params, x[:, t:t + 1], cfg, mode="decode",
                             state=st)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk["ssm"]),
                               np.asarray(st["ssm"]), rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention

    B, S, H, Hkv, D = 2, 37, 8, 2, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(k1, (B, S, H, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=8)
    # naive reference
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o),
                               rtol=2e-5, atol=2e-5)


def test_param_counts_sane():
    total, active = param_counts(get_config("qwen3_moe_30b_a3b"))
    assert 25e9 < total < 36e9          # ~30B total
    assert 2e9 < active < 5e9           # ~3B active
    t405, _ = param_counts(get_config("llama3_405b"))
    assert 3.7e11 < t405 < 4.4e11
