"""Autotuner, rate–distortion frontiers, and distortion-aware serving
(ISSUE 9).

Covers the whole ``repro.tuning`` → container → serving chain:

  * the distortion-target grammar (``metric{>=,<=,>,<}value``) and the
    cheapest-satisfying selection rule;
  * the ``TACF`` byte section — roundtrip, and every corruption mode
    degrading to ``frontier = None`` + ``frontier_error`` without ever
    breaking the snapshot itself;
  * :class:`~repro.tuning.AutoTuner` — the tuned point meets its target
    *when re-measured from the decoded snapshot* (the acceptance
    criterion), frontier Pareto invariants, memoization, and clean
    ``TargetUnsatisfiable`` failures;
  * variant sets — catalog integrity (CRC, corruption detection),
    selection, and the serving surface: :class:`VariantServer`, the
    HTTP API (including the 400-not-500 contract for unsatisfiable or
    malformed targets), single-snapshot fallback counters, and the
    sharded router answering a distortion target with bytes identical
    to reading the selected variant directly.
"""
import json
import os
import shutil
import threading

import numpy as np
import pytest

from repro import io as tacz
from repro.core import amr, hybrid
from repro.core import metrics as core_metrics
from repro.io import format as fmt
from repro.io import frontier as frt
from repro.io import variants as vrt
from repro.obs import metrics as obsm
from repro.serving import (RegionClient, RegionServer, ShardMap,
                           ShardedRegionRouter, VariantServer, serve)
from repro.serving.client import RegionAPIError
from repro.tuning import AutoTuner, measure_metrics, write_variant_set

BOX = ((0, 20), (4, 28), (8, 24))


@pytest.fixture(scope="module")
def ds():
    return amr.synthetic_amr((32, 32, 32), densities=[0.35, 0.65],
                             refine_block=4, seed=5)


@pytest.fixture(scope="module")
def variant_set(ds, tmp_path_factory):
    """A tuned two-variant set (shared: tuning is the expensive step)."""
    set_dir = os.path.join(str(tmp_path_factory.mktemp("vset")),
                           "snap.taczv")
    write_variant_set(set_dir, ds, {"hi": "psnr>=70", "lo": "psnr>=50"},
                      default="lo")
    return set_dir


# ----------------------------- target grammar ------------------------------


@pytest.mark.parametrize("spec,metric,op,value", [
    ("psnr>=60", "psnr", ">=", 60.0),
    ("ps_error<=1e-2", "ps_error", "<=", 0.01),
    (" psnr_u > 42.5 ", "psnr_u", ">", 42.5),
    ("max_abs_error<0.125", "max_abs_error", "<", 0.125),
])
def test_parse_target(spec, metric, op, value):
    t = frt.parse_target(spec)
    assert (t.metric, t.op, t.value) == (metric, op, value)
    # str() is a valid spec again (the catalog stores this form)
    assert frt.parse_target(str(t)) == t


@pytest.mark.parametrize("bad", [
    "", "psnr", "psnr=60", "psnr==60", "psnr>=", ">=60",
    "psnr>=sixty", "bogus_metric>=3", "psnr >= 60 extra",
])
def test_parse_target_rejects(bad):
    with pytest.raises(ValueError):
        frt.parse_target(bad)


def test_target_satisfies_direction():
    hi = frt.parse_target("psnr>=60")
    assert hi.satisfies({"psnr": 60.0})
    assert not hi.satisfies({"psnr": 59.999})
    assert not hi.satisfies({"ps_error": 0.0})   # metric never measured
    lo = frt.parse_target("ps_error<0.01")
    assert lo.satisfies({"ps_error": 0.0099})
    assert not lo.satisfies({"ps_error": 0.01})


# ----------------------------- frontier model ------------------------------


def _frontier():
    pts = [frt.FrontierPoint(ebs=(8.0,), bits=100,
                             metrics={"psnr": 40.0, "ps_error": 0.1}),
           frt.FrontierPoint(ebs=(2.0,), bits=300,
                             metrics={"psnr": 55.0, "ps_error": 0.02}),
           frt.FrontierPoint(ebs=(0.5,), bits=900,
                             metrics={"psnr": 70.0, "ps_error": 0.004})]
    return frt.Frontier(metric="psnr", points=pts, default=1)


def test_frontier_select_cheapest():
    fr = _frontier()
    assert fr.select("psnr>=50").bits == 300      # not the 900-bit point
    assert fr.select("psnr>=60").bits == 900
    assert fr.select("ps_error<=0.05").bits == 300
    assert fr.default_point.bits == 300


def test_frontier_unsatisfiable_reports_best():
    fr = _frontier()
    with pytest.raises(frt.TargetUnsatisfiable) as ei:
        fr.select("psnr>=90")
    assert ei.value.best == 70.0
    assert "best available psnr=70" in str(ei.value)
    assert ei.value.target.value == 90.0


def test_frontier_best_value_direction():
    fr = _frontier()
    assert fr.best_value("psnr") == 70.0          # higher is better
    assert fr.best_value("ps_error") == 0.004     # lower is better
    assert fr.best_value("psnr_u") is None        # never measured


def test_frontier_from_dict_validation():
    good = _frontier().to_dict()
    assert frt.Frontier.from_dict(good).to_dict() == good
    bad = dict(good, magic="NOPE")
    with pytest.raises(ValueError, match="frontier"):
        frt.Frontier.from_dict(bad)
    with pytest.raises(ValueError, match="version"):
        frt.Frontier.from_dict(dict(good, version=frt.FRONTIER_VERSION + 1))
    with pytest.raises(ValueError, match="default"):
        frt.Frontier.from_dict(dict(good, default=7))


# ------------------------------ TACF section -------------------------------


def test_section_roundtrip():
    fr = _frontier()
    buf = frt.pack_section(fr)
    assert buf[:4] == frt.FRONTIER_MAGIC
    assert frt.parse_section(buf).to_dict() == fr.to_dict()


@pytest.mark.parametrize("mutate,match", [
    (lambda b: b[:frt.SECTION_HEAD_SIZE - 1], "truncated"),
    (lambda b: b[:-3], "truncated"),
    (lambda b: b + b"x", "oversized"),
    (lambda b: b"XXXX" + b[4:], "magic"),
    (lambda b: b[:frt.SECTION_HEAD_SIZE + 5]
        + bytes([b[frt.SECTION_HEAD_SIZE + 5] ^ 0xFF])
        + b[frt.SECTION_HEAD_SIZE + 6:], "CRC"),
])
def test_section_corruption(mutate, match):
    buf = frt.pack_section(_frontier())
    with pytest.raises(ValueError, match=match):
        frt.parse_section(mutate(buf))


# ------------------------- container plumbing ------------------------------


def _section_span(path):
    """(start, end) byte offsets of the TACF gap in a single-file tacz."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        f.seek(size - fmt.FOOTER_SIZE)
        idx_off, idx_len, _crc = fmt.parse_footer(f.read(fmt.FOOTER_SIZE))
    return idx_off + idx_len, size - fmt.FOOTER_SIZE


def test_single_file_frontier_roundtrip(tmp_path, ds):
    res = hybrid.compress_amr(ds, eb=1e-3)
    fr = _frontier()
    plain = os.path.join(str(tmp_path), "plain.tacz")
    withf = os.path.join(str(tmp_path), "withf.tacz")
    tacz.write(plain, res)
    tacz.write(withf, res, frontier=fr)
    with tacz.TACZReader(plain) as rd:
        assert rd.frontier is None and rd.frontier_error is None
        base = [rd.read_level(li) for li in range(rd.n_levels)]
    with tacz.TACZReader(withf) as rd:
        assert rd.frontier_error is None
        assert rd.frontier.to_dict() == fr.to_dict()
        # carrying a frontier never perturbs the payload
        for li, ref in enumerate(base):
            np.testing.assert_array_equal(rd.read_level(li), ref)


def test_corrupt_section_degrades_not_fails(tmp_path, ds):
    """A damaged TACF section costs the frontier, never the data."""
    res = hybrid.compress_amr(ds, eb=1e-3)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res, frontier=_frontier())
    start, end = _section_span(path)
    assert end - start > frt.SECTION_HEAD_SIZE
    with open(path, "r+b") as f:                  # flip a body byte
        f.seek(start + frt.SECTION_HEAD_SIZE + 3)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with tacz.TACZReader(path) as rd:
        assert rd.frontier is None
        assert "CRC" in rd.frontier_error
        recons = [rd.read_level(li) for li in range(rd.n_levels)]
    for lvl, recon in zip(ds.levels, recons):
        err = np.abs(recon - lvl.data)[lvl.mask]
        assert float(err.max()) <= res.levels[0].eb * (1 + 1e-5) \
            or err.size == 0


def test_multipart_frontier_roundtrip(tmp_path, ds):
    res = hybrid.compress_amr(ds, eb=1e-3)
    fr = _frontier()
    path = os.path.join(str(tmp_path), "s.taczd")
    tacz.write_multipart(path, res, parts=2, frontier=fr)
    with tacz.open_snapshot(path) as rd:
        assert rd.frontier.to_dict() == fr.to_dict()
        assert rd.frontier_error is None


# -------------------------------- autotuner --------------------------------


def test_autotune_restated_from_decoded_snapshot(tmp_path, ds):
    """The acceptance criterion: the tuned point's stated metrics hold
    when re-measured from the *decoded file*, not the tuner's memo."""
    tuner = AutoTuner(ds, steps_down=4, steps_up=4)
    tr = tuner.tune("psnr>=60")
    assert tr.target.satisfies(tr.metrics)
    assert tr.frontier.default_point.bits == tr.bits
    assert tr.frontier.default_point.ebs == tr.ebs
    path = os.path.join(str(tmp_path), "tuned.tacz")
    tacz.write(path, tr.result, frontier=tr.frontier)
    recons = tacz.read(path)
    orig = np.concatenate([l.data[l.mask] for l in ds.levels])
    rec = np.concatenate([r[l.mask]
                          for l, r in zip(ds.levels, recons)])
    repsnr = core_metrics.psnr(orig, rec)
    assert repsnr == pytest.approx(tr.metrics["psnr"], abs=1e-6)
    assert repsnr >= 60.0
    remax = float(np.abs(orig - rec).max())
    assert remax == pytest.approx(tr.metrics["max_abs_error"], rel=1e-6)
    # per-level bounds hold at the per-level ebs the tuner chose
    for li, (lvl, recon) in enumerate(zip(ds.levels, recons)):
        err = np.abs(recon - lvl.data)[lvl.mask]
        if err.size:
            assert float(err.max()) <= tr.ebs[li] * (1 + 1e-5)


def test_autotune_frontier_is_pareto(ds):
    tr = AutoTuner(ds, steps_down=3, steps_up=3).tune("psnr>=55")
    pts = tr.frontier.points
    assert pts == sorted(pts, key=lambda p: p.bits)
    default = tr.frontier.default_point
    for a in pts:
        if a is default:      # the written point is force-kept
            continue
        for b in pts:
            if b is a:
                continue
            dominates = (b.bits <= a.bits
                         and b.metrics["psnr"] >= a.metrics["psnr"]
                         and (b.bits < a.bits
                              or b.metrics["psnr"] > a.metrics["psnr"]))
            assert not dominates, (a, b)


def test_autotune_unsatisfiable(ds):
    with pytest.raises(frt.TargetUnsatisfiable) as ei:
        AutoTuner(ds, steps_down=1, steps_up=1).tune("psnr>=500")
    assert ei.value.best is not None


def test_autotune_memo_one_compression_per_level_eb(ds):
    tuner = AutoTuner(ds, steps_down=3, steps_up=3)
    tuner.tune("psnr>=50")
    first = tuner.compressions
    tuner.tune("psnr>=60")    # overlapping ladder → memo reuse
    assert tuner.compressions == len(tuner._level_memo)
    assert tuner.compressions < 2 * first


def test_measure_metrics_keys(ds):
    res = hybrid.compress_amr(ds, eb=1e-3)
    mets = measure_metrics(ds, res)
    assert set(mets) == set(frt.HIGHER_IS_BETTER)
    assert mets["max_abs_error"] <= 1e-3 * (1 + 1e-5)


# ------------------------------ variant sets -------------------------------


def test_variant_set_catalog(variant_set, ds):
    assert vrt.is_variant_set(variant_set)
    cat = vrt.load_catalog(variant_set)
    assert cat["magic"] == vrt.VARIANTS_MAGIC
    assert cat["default"] == "lo"
    assert vrt.variant_names(cat) == ["hi", "lo"] \
        or set(vrt.variant_names(cat)) == {"hi", "lo"}
    for entry in cat["variants"]:
        path = os.path.join(variant_set, entry["file"])
        assert os.path.exists(path)
        with tacz.TACZReader(path) as rd:
            # each variant file carries its own frontier, and its
            # default point is exactly the catalog row
            dp = rd.frontier.default_point
            assert dp.bits == entry["bits"]
            assert list(dp.ebs) == list(entry["ebs"])
            assert frt.parse_target(entry["target"]).satisfies(dp.metrics)


def test_select_variant(variant_set):
    cat = vrt.load_catalog(variant_set)
    assert vrt.select_variant(cat, None)["name"] == "lo"
    assert vrt.select_variant(cat, "psnr>=60")["name"] == "hi"
    assert vrt.select_variant(cat, "psnr>=20")["name"] == "lo"
    with pytest.raises(frt.TargetUnsatisfiable) as ei:
        vrt.select_variant(cat, "psnr>=500")
    assert ei.value.best is not None


def test_catalog_corruption_detected(variant_set, tmp_path):
    clone = os.path.join(str(tmp_path), "clone.taczv")
    shutil.copytree(variant_set, clone)
    cpath = os.path.join(clone, vrt.VARIANTS_NAME)
    body = json.load(open(cpath))
    body["default"] = "hi"        # flip a field without re-stamping CRC
    with open(cpath, "w") as f:
        json.dump(body, f)
    with pytest.raises(ValueError, match="CRC"):
        vrt.load_catalog(clone)
    with open(cpath, "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError, match="corrupt"):
        vrt.load_catalog(clone)


# ------------------------------ serving paths ------------------------------


def test_variant_server_selection_bit_identical(variant_set):
    with VariantServer(variant_set) as vs:
        assert vs.default_variant == "lo"
        crc, name, res = vs.get_regions_ex([BOX], target="psnr>=60")
        assert name == "hi"
        direct = tacz.read_roi(os.path.join(variant_set, "hi.tacz"), BOX)
        for roi, d in zip(res[0], direct):
            np.testing.assert_array_equal(roi.data, d.data)
        # no target → default variant
        _, dname, dres = vs.get_regions_ex([BOX])
        assert dname == "lo"
        lo = tacz.read_roi(os.path.join(variant_set, "lo.tacz"), BOX)
        np.testing.assert_array_equal(dres[0][0].data, lo[0].data)


def test_variant_server_unknown_variant(variant_set):
    with VariantServer(variant_set) as vs:
        with pytest.raises(ValueError, match="unknown variant"):
            vs.get_regions_ex([BOX], variant="nope")


def test_variant_server_unsatisfiable_counts(variant_set):
    before = obsm.VARIANT_UNSATISFIED.labels().value
    with VariantServer(variant_set) as vs:
        with pytest.raises(frt.TargetUnsatisfiable):
            vs.get_regions_ex([BOX], target="psnr>=500")
    assert obsm.VARIANT_UNSATISFIED.labels().value == before + 1


def test_variant_server_fault_hook_forwards(variant_set):
    """fault_hook injected at the set level fires inside every variant's
    inner server — the fleet-test fault-injection surface."""
    with VariantServer(variant_set) as vs:
        calls = []

        def boom():
            calls.append(1)
            raise RuntimeError("injected fault")

        vs.fault_hook = boom
        with pytest.raises(RuntimeError, match="injected fault"):
            vs.get_regions_ex([BOX], target="psnr>=60")
        assert calls
        vs.fault_hook = None
        _, name, _ = vs.get_regions_ex([BOX], target="psnr>=60")
        assert name == "hi"


def _serve_bg(src, **kw):
    httpd = serve(src, port=0, **kw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    return httpd, f"http://127.0.0.1:{httpd.server_address[1]}"


def test_http_variant_set(variant_set):
    """The HTTP wire surface over a variant set: meta block, target
    selection + variant header, explicit pin, and the 400 contract."""
    direct = tacz.read_roi(os.path.join(variant_set, "hi.tacz"), BOX)
    httpd, url = _serve_bg(variant_set, cache_bytes=16 << 20)
    try:
        cli = RegionClient(url)
        meta = cli.meta()
        assert meta["variants"]["default"] == "lo"
        assert {v["name"] for v in meta["variants"]["variants"]} \
            == {"hi", "lo"}
        header, out = cli.regions_ex([BOX], target="psnr>=60")
        assert header["variant"] == "hi"
        for roi, d in zip(out[0], direct):
            np.testing.assert_array_equal(roi.data, d.data)
        # GET single-region path takes the same query params
        roi = cli.region(0, BOX, target="psnr>=60")
        np.testing.assert_array_equal(roi.data, direct[0].data)
        # explicit variant pin
        header, out = cli.regions_ex([BOX], variant="lo")
        assert header["variant"] == "lo"
        # no target → header reports the default variant was used
        header, _ = cli.regions_ex([BOX])
        assert header["variant"] is None or header["variant"] == "lo"
        # unsatisfiable → clean 400 with an explanatory JSON body
        with pytest.raises(RegionAPIError) as ei:
            cli.regions([BOX], target="psnr>=500")
        assert ei.value.code == 400
        assert "best" in ei.value.body_excerpt
        assert "psnr" in ei.value.body_excerpt
        # malformed target → 400, not 500
        with pytest.raises(RegionAPIError) as ei:
            cli.regions([BOX], target="psnr==60")
        assert ei.value.code == 400
        with pytest.raises(RegionAPIError) as ei:
            cli.region(0, BOX, target="psnr>=500")
        assert ei.value.code == 400
    finally:
        httpd.shutdown()
        httpd.server_close()


def test_single_server_target_and_fallback(variant_set, tmp_path, ds):
    """A plain RegionServer honors targets against its own frontier,
    and falls back (counted) when the file has none."""
    hi_path = os.path.join(variant_set, "hi.tacz")
    req0 = obsm.VARIANT_REQUESTS.labels("default").value
    with RegionServer(hi_path) as rs:
        _, name, _ = rs.get_regions_ex([BOX], target="psnr>=60")
        assert name == "default"
        with pytest.raises(frt.TargetUnsatisfiable):
            rs.get_regions_ex([BOX], target="psnr>=500")
        with pytest.raises(ValueError, match="variant set"):
            rs.get_regions_ex([BOX], variant="hi")
    assert obsm.VARIANT_REQUESTS.labels("default").value == req0 + 1
    # frontier-less file: target is unverifiable → serve + count fallback
    plain = os.path.join(str(tmp_path), "plain.tacz")
    tacz.write(plain, hybrid.compress_amr(ds, eb=1e-3))
    fb0 = obsm.VARIANT_FALLBACKS.labels().value
    with RegionServer(plain) as rs:
        _, name, res = rs.get_regions_ex([BOX], target="psnr>=60")
        assert name == "default" and res[0]
    assert obsm.VARIANT_FALLBACKS.labels().value == fb0 + 1


def test_corrupt_frontier_falls_back(tmp_path, ds):
    """In-place TACF corruption (the truncated-section fault fixture):
    the server keeps serving and counts the fallback."""
    res = hybrid.compress_amr(ds, eb=1e-3)
    path = os.path.join(str(tmp_path), "s.tacz")
    tacz.write(path, res, frontier=_frontier())
    start, _end = _section_span(path)
    with open(path, "r+b") as f:                  # truncate the body len
        f.seek(start + 8)
        f.write(b"\xff\xff\xff\x7f")
    fb0 = obsm.VARIANT_FALLBACKS.labels().value
    with RegionServer(path) as rs:
        assert rs.reader.frontier is None
        assert rs.reader.frontier_error
        _, name, out = rs.get_regions_ex([BOX], target="psnr>=60")
        assert name == "default"
        np.testing.assert_array_equal(
            out[0][0].data, tacz.read_roi(path, BOX)[0].data)
    assert obsm.VARIANT_FALLBACKS.labels().value == fb0 + 1


def test_sharded_router_over_variant_set(variant_set):
    """Acceptance criterion: a distortion-target request through the
    sharded router returns bits identical to directly reading the
    selected variant."""
    hi_path = os.path.join(variant_set, "hi.tacz")
    direct = tacz.read_roi(hi_path, BOX)
    smap = ShardMap(["a", "b"], seed=7)
    servers, urls = [], {}
    try:
        for sid in smap.shards:
            vs = VariantServer(variant_set, shard_map=smap, shard_id=sid)
            httpd, url = _serve_bg(vs)
            servers.append(httpd)
            urls[sid] = url
        with ShardedRegionRouter(variant_set, smap, urls,
                                 local_fallback=False) as router:
            crc, name, res = router.get_regions_ex([BOX],
                                                   target="psnr>=60")
            assert name == "hi"
            with tacz.open_snapshot(hi_path) as rd:
                assert crc == rd.index_crc
            for roi, d in zip(res[0], direct):
                np.testing.assert_array_equal(roi.data, d.data)
            assert router.counters["local_fallbacks"] == 0
            # explicit pin routes the other variant's bytes
            _, lname, lres = router.get_regions_ex([BOX], variant="lo")
            assert lname == "lo"
            lo = tacz.read_roi(os.path.join(variant_set, "lo.tacz"), BOX)
            np.testing.assert_array_equal(lres[0][0].data, lo[0].data)
            with pytest.raises(frt.TargetUnsatisfiable):
                router.get_regions_ex([BOX], target="psnr>=500")
            with pytest.raises(ValueError, match="unknown variant"):
                router.get_regions_ex([BOX], variant="nope")
    finally:
        for httpd in servers:
            httpd.shutdown()
            httpd.server_close()
            httpd.region_server.close()
